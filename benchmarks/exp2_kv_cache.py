"""Exp 2 (paper Fig. 6, Table 1, Fig. 7): KV-cache-enabled operators.

(a) Cost-quality trade-off per profile: single-operator queries evaluated
    at every (model, ratio) — the compression ladder (Fig. 6).
(b) Speedup from adding compressed profiles to the search space vs a
    baseline limited to uncompressed precomputed caches (Table 1).
(c) Operator-selection frequency across optimized plans (Fig. 7).
"""
from __future__ import annotations

import collections
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (LG_RATIOS, SM_RATIOS, World,
                               generate_queries, stage_stats_rows)
from repro.core import PlannerConfig, plan_query
from repro.data.synthetic import (TOK_NO, TOK_YES, filter_query_token,
                                  map_query_token, value_token)
from repro.runtime import stage_stats_by_engine


def ladder(world: World, ds_name: str, n_tasks: int = 4) -> List[Dict]:
    """(a): quality + runtime of every profile on single-op queries."""
    ds = world.datasets[ds_name]
    ids = [it.item_id for it in ds.items]
    rows = []
    for size, ratios in (("sm", SM_RATIOS), ("lg", (0.0,) + LG_RATIOS)):
        for ratio in sorted(set(ratios)):
            f1s, rts = [], []
            for task in range(min(n_tasks, ds.n_filter_tasks)):
                t0 = time.perf_counter()
                lo = world.engine.run_filter(
                    size, ratio, ids, [filter_query_token(task)],
                    TOK_YES, TOK_NO)
                rts.append(time.perf_counter() - t0)
                gold_lo = world.engine.run_filter(
                    "lg", 0.0, ids, [filter_query_token(task)],
                    TOK_YES, TOK_NO)
                pred, gold = lo > 0, gold_lo > 0
                tp = (pred & gold).sum()
                prec = tp / max(pred.sum(), 1)
                rec = tp / max(gold.sum(), 1)
                f1s.append(2 * prec * rec / max(prec + rec, 1e-9))
            rows.append({"dataset": ds_name, "model": size, "ratio": ratio,
                         "f1_vs_gold": float(np.mean(f1s)),
                         "runtime_s": float(np.mean(rts))})
    return rows


def speedup_with_compression(world: World, targets=(0.5, 0.7, 0.9),
                             n_queries: int = 3,
                             planner_cfg: PlannerConfig | None = None,
                             sample_frac: float = 0.15) -> List[Dict]:
    """(b): Stretto with the full compression ladder vs Stretto restricted
    to uncompressed precomputed caches (the paper's Table 1 baseline)."""
    planner_cfg = planner_cfg or PlannerConfig(steps=250, restarts=3)
    rows = []
    for ds_name, ds in world.datasets.items():
        for target in targets:
            queries = generate_queries(ds, n_queries, target, seed=71)
            for qi, q in enumerate(queries):
                rt = {}
                est = {}
                sel_counter = collections.Counter()
                stats = []
                kv_by_engine: Dict[str, int] = {}
                for tag, backend in (("full", world.backend),
                                     ("nocomp", world.backend_nocomp)):
                    plan = plan_query(q, ds.items, backend, planner_cfg,
                                      sample_frac=sample_frac)
                    res = world.execute(plan, q, ds.items, backend)
                    rt[tag] = res.runtime_s
                    est[tag] = plan.est_cost
                    stats += stage_stats_rows(
                        f"exp2/{ds_name}/t{target}/q{qi}/{tag}", res, plan)
                    if tag == "full":
                        for s in plan.stages:
                            sel_counter[s.op_name] += 1
                        # KV bytes per engine placement: an exact
                        # partition of the run's total ("" = the
                        # single default engine)
                        for eng, d in stage_stats_by_engine(
                                res.stage_stats).items():
                            kv_by_engine[eng or "default"] = \
                                kv_by_engine.get(eng or "default", 0) \
                                + d["kv_bytes"]
                rows.append({
                    "dataset": ds_name, "target": target, "query": qi,
                    "runtime_full_s": rt["full"],
                    "runtime_nocomp_s": rt["nocomp"],
                    "est_cost_full_s": est["full"],
                    "est_cost_nocomp_s": est["nocomp"],
                    "speedup": rt["nocomp"] / max(rt["full"], 1e-9),
                    "selected_ops": dict(sel_counter),
                    "kv_bytes_by_engine": kv_by_engine,
                    "stage_stats": stats,
                })
    return rows


def summarize(ladder_rows, speedup_rows) -> List[str]:
    out = ["exp2a: compression-ladder profiles (f1 vs gold, runtime)"]
    for r in ladder_rows:
        out.append(f"  {r['model']}-r{r['ratio']:.1f} "
                   f"f1={r['f1_vs_gold']:.3f} t={r['runtime_s']:.2f}s")
    out.append("exp2b: speedup from compressed profiles (vs uncompressed "
               "precomputed caches)")
    for tgt in sorted({r["target"] for r in speedup_rows}):
        sub = [r["speedup"] for r in speedup_rows if r["target"] == tgt]
        out.append(f"  target {tgt}: avg speedup {np.mean(sub):.2f}x "
                   f"(n={len(sub)})")
    sel = collections.Counter()
    for r in speedup_rows:
        sel.update(r["selected_ops"])
    out.append("exp2c: operator selection frequency: " +
               ", ".join(f"{k}:{v}" for k, v in sel.most_common(8)))
    return out
