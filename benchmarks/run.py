"""Benchmark entry point — one harness per paper table/figure.

Default (no args) runs a bounded configuration suitable for CI/CPU
(~10-20 min): 2 datasets at 30% scale, 3 queries per (dataset, target).
``--full`` approaches paper scale (5 datasets, more queries); ``--smoke``
is the CI perf-trajectory job: one tiny dataset, one query per target,
kernel/roofline sections skipped, and the run self-validates that the
written ``stage_stats-<ts>-<sha>.json`` snapshot parses and carries
non-zero measured mean batches (exit 1 otherwise) — so the trajectory
artifact can never silently go empty.

Prints a ``name,us_per_call,derived`` CSV plus human-readable summaries,
including the planned-vs-measured batch drift the measured-feedback loop
is meant to close.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "nogit"
    except (OSError, subprocess.SubprocessError):
        return "nogit"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny self-validating run for the CI trajectory "
                         "artifact")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--out", type=str, default="results/bench")
    args = ap.parse_args()

    import repro
    from benchmarks import (exp1_accuracy_runtime as E1,
                            exp2_kv_cache as E2, exp3_global_local as E3,
                            kernels_bench, roofline)
    from benchmarks.common import build_world

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    if args.smoke:
        scale = args.scale or 0.1
        names = ("movies",)
        nq = 1
        targets = (0.7,)
        cfg = repro.PlannerConfig(steps=120, restarts=2, snapshots=2)
    else:
        scale = args.scale or (1.0 if args.full else 0.25)
        names = None if args.full else ("movies", "artwork")
        nq = 6 if args.full else 2
        targets = (0.5, 0.7, 0.9) if args.full else (0.7, 0.9)
        cfg = repro.PlannerConfig(steps=300 if args.full else 200,
                                  restarts=4 if args.full else 3)

    print(f"# building world (scale={scale}) ...", flush=True)
    world = build_world(scale=scale, dataset_names=names,
                        config=repro.SessionConfig(planner=cfg))

    csv_rows = []
    stage_stats = []   # per-stage StageStats across all experiments: the
    #                    perf-trajectory artifact future PRs diff against

    print("# exp1 (Fig 5): guarantees + runtime vs baselines", flush=True)
    rows1 = E1.run(world, targets=targets, n_queries=nq, planner_cfg=cfg)
    for r in rows1:
        stage_stats += r.pop("stage_stats", [])
    with open(f"{args.out}/exp1.json", "w") as f:
        json.dump(rows1, f, indent=1)
    for line in E1.summarize(rows1):
        print(line)
    for method in ("stretto", "lotus", "pareto"):
        sub = [r for r in rows1 if r["method"] == method]
        if sub:
            csv_rows.append({
                "name": f"exp1_runtime_{method}",
                "us_per_call": float(np.median(
                    [r["runtime_s"] for r in sub])) * 1e6,
                "derived": f"met={np.mean([(r['target_met_recall'] >= 1) & (r['target_met_precision'] >= 1) for r in sub]):.2f}"})

    print("# exp2 (Fig 6/Table 1/Fig 7): KV-cache operators", flush=True)
    first_ds = next(iter(world.datasets))
    lad = E2.ladder(world, first_ds)
    spd = E2.speedup_with_compression(world, targets=targets,
                                      n_queries=max(nq - 1, 1),
                                      planner_cfg=cfg)
    for r in spd:
        stage_stats += r.pop("stage_stats", [])
    with open(f"{args.out}/exp2.json", "w") as f:
        json.dump({"ladder": lad, "speedup": spd}, f, indent=1)
    for line in E2.summarize(lad, spd):
        print(line)
    csv_rows.append({
        "name": "exp2_speedup_with_compression",
        "us_per_call": 0.0,
        "derived": f"avg={np.mean([r['speedup'] for r in spd]):.2f}x"})

    print("# exp3 (Fig 8): global vs local vs independent", flush=True)
    rows3 = E3.run(world, targets=targets, n_queries=max(nq - 1, 1),
                   planner_cfg=cfg)
    for r in rows3:
        stage_stats += r.pop("stage_stats", [])
    with open(f"{args.out}/exp3.json", "w") as f:
        json.dump(rows3, f, indent=1)
    for line in E3.summarize(rows3):
        print(line)

    # perf-trajectory artifact: stage_stats.json is always the latest run
    # (stable name for tooling), and every run ALSO lands in its own
    # timestamped snapshot so the trajectory accumulates across commits
    # instead of being clobbered
    meta = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "git_sha": _git_sha(),
        "dispatcher": os.environ.get("STRETTO_DISPATCHER", "") or "inline",
        "scale": scale,
        "full": bool(args.full),
    }
    with open(f"{args.out}/stage_stats.json", "w") as f:
        json.dump(stage_stats, f, indent=1)
    snap = (f"{args.out}/stage_stats-"
            f"{time.strftime('%Y%m%dT%H%M%S')}-{meta['git_sha']}.json")
    with open(snap, "w") as f:
        json.dump({"meta": meta, "stages": stage_stats}, f, indent=1)
    by_op = {}
    for r in stage_stats:
        d = by_op.setdefault(r["op_name"], dict(wall_s=0.0, n_tuples=0,
                                                kv_bytes=0, n_batches=0))
        d["wall_s"] += r["wall_s"]
        d["n_tuples"] += r["n_tuples"]
        d["kv_bytes"] += r["kv_bytes"]
        d["n_batches"] += r["n_batches"]
    print(f"# stage stats -> {args.out}/stage_stats.json and {snap} "
          f"({len(stage_stats)} stage records, "
          f"dispatcher={meta['dispatcher']})")
    for op, d in sorted(by_op.items()):
        us = d["wall_s"] / max(d["n_tuples"], 1) * 1e6
        mean_b = d["n_tuples"] / max(d["n_batches"], 1)
        csv_rows.append({"name": f"stage_{op}", "us_per_call": us,
                         "derived": f"tuples={d['n_tuples']} "
                                    f"kvMB={d['kv_bytes'] / 1e6:.1f} "
                                    f"batches={d['n_batches']} "
                                    f"meanb={mean_b:.1f}"})

    # planned-vs-measured convergence: how far measured flush batches sat
    # from the planner's expectations, across every stage that recorded a
    # planned_batch (the quantity the measured-feedback loop closes)
    drifts = [r["batch_drift"] for r in stage_stats
              if r.get("batch_drift")]
    if drifts:
        logs = np.abs(np.log2(np.maximum(drifts, 1e-9)))
        print(f"# batch model: {len(drifts)} stages with planned batch, "
              f"median |log2 drift|={np.median(logs):.2f} "
              f"p90={np.percentile(logs, 90):.2f} "
              f"(0 = planner predicted measured flush widths exactly)")
        csv_rows.append({
            "name": "planned_vs_measured_batch",
            "us_per_call": 0.0,
            "derived": f"median_abs_log2_drift={np.median(logs):.3f} "
                       f"n={len(drifts)}"})

    if not args.smoke:
        print("# kernel microbenches", flush=True)
        krows = kernels_bench.run()
        csv_rows.extend(krows)

        print("# roofline (from dry-run artifacts, if present)", flush=True)
        recs = roofline.load("results/dryrun_sp")
        if recs:
            for line in roofline.table(recs)[:40]:
                print(line)
            csv_rows.extend(roofline.csv_rows(recs))
        else:
            print("  (run `python -m repro.launch.dryrun --all --out "
                  "results/dryrun_sp` first)")

    print("\nname,us_per_call,derived")
    for r in csv_rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    print(f"\n# total benchmark wall time: {time.time() - t0:.0f}s")
    world.close()

    if args.smoke:
        _smoke_check(snap)


def _smoke_check(snapshot_path: str) -> None:
    """CI gate: the trajectory snapshot must be parseable and carry real
    measurements — a run that produced an empty or degenerate snapshot
    must fail loudly, not silently upload a useless artifact."""
    with open(snapshot_path) as f:
        snap = json.load(f)
    stages = snap.get("stages", [])
    assert stages, f"{snapshot_path}: no stage records"
    assert all(r.get("n_batches", 0) >= 1 for r in stages), \
        f"{snapshot_path}: stage record with no flushes"
    # every stage row must carry its engine placement (exp2 aggregates
    # KV bytes per engine from it; "" marks single-engine sessions)
    assert all("engine" in r for r in stages), \
        f"{snapshot_path}: stage record missing the engine field"
    mean_batches = [r.get("mean_batch", 0) for r in stages]
    assert any(b > 0 for b in mean_batches), \
        f"{snapshot_path}: all mean_batch zero"
    assert snap.get("meta", {}).get("git_sha"), \
        f"{snapshot_path}: missing meta.git_sha"
    n_planned = sum(1 for r in stages if r.get("planned_batch"))
    print(f"# smoke check ok: {snapshot_path} ({len(stages)} stage "
          f"records, {n_planned} with planned-vs-measured batch, "
          f"max mean_batch={max(mean_batches):.1f})")


if __name__ == "__main__":
    main()
