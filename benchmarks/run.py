"""Benchmark entry point — one harness per paper table/figure.

Default (no args) runs a bounded configuration suitable for CI/CPU
(~10-20 min): 2 datasets at 30% scale, 3 queries per (dataset, target).
``--full`` approaches paper scale (5 datasets, more queries).

Prints a ``name,us_per_call,derived`` CSV plus human-readable summaries.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "nogit"
    except (OSError, subprocess.SubprocessError):
        return "nogit"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--out", type=str, default="results/bench")
    args = ap.parse_args()

    import repro
    from benchmarks import (exp1_accuracy_runtime as E1,
                            exp2_kv_cache as E2, exp3_global_local as E3,
                            kernels_bench, roofline)
    from benchmarks.common import build_world

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    scale = args.scale or (1.0 if args.full else 0.25)
    names = None if args.full else ("movies", "artwork")
    nq = 6 if args.full else 2
    targets = (0.5, 0.7, 0.9) if args.full else (0.7, 0.9)
    cfg = repro.PlannerConfig(steps=300 if args.full else 200,
                              restarts=4 if args.full else 3)

    print(f"# building world (scale={scale}) ...", flush=True)
    world = build_world(scale=scale, dataset_names=names,
                        config=repro.SessionConfig(planner=cfg))

    csv_rows = []
    stage_stats = []   # per-stage StageStats across all experiments: the
    #                    perf-trajectory artifact future PRs diff against

    print("# exp1 (Fig 5): guarantees + runtime vs baselines", flush=True)
    rows1 = E1.run(world, targets=targets, n_queries=nq, planner_cfg=cfg)
    for r in rows1:
        stage_stats += r.pop("stage_stats", [])
    with open(f"{args.out}/exp1.json", "w") as f:
        json.dump(rows1, f, indent=1)
    for line in E1.summarize(rows1):
        print(line)
    for method in ("stretto", "lotus", "pareto"):
        sub = [r for r in rows1 if r["method"] == method]
        if sub:
            import numpy as np
            csv_rows.append({
                "name": f"exp1_runtime_{method}",
                "us_per_call": float(np.median(
                    [r["runtime_s"] for r in sub])) * 1e6,
                "derived": f"met={np.mean([(r['target_met_recall'] >= 1) & (r['target_met_precision'] >= 1) for r in sub]):.2f}"})

    print("# exp2 (Fig 6/Table 1/Fig 7): KV-cache operators", flush=True)
    first_ds = next(iter(world.datasets))
    lad = E2.ladder(world, first_ds)
    spd = E2.speedup_with_compression(world, targets=targets,
                                      n_queries=max(nq - 1, 1),
                                      planner_cfg=cfg)
    for r in spd:
        stage_stats += r.pop("stage_stats", [])
    with open(f"{args.out}/exp2.json", "w") as f:
        json.dump({"ladder": lad, "speedup": spd}, f, indent=1)
    for line in E2.summarize(lad, spd):
        print(line)
    import numpy as np
    csv_rows.append({
        "name": "exp2_speedup_with_compression",
        "us_per_call": 0.0,
        "derived": f"avg={np.mean([r['speedup'] for r in spd]):.2f}x"})

    print("# exp3 (Fig 8): global vs local vs independent", flush=True)
    rows3 = E3.run(world, targets=targets, n_queries=max(nq - 1, 1),
                   planner_cfg=cfg)
    for r in rows3:
        stage_stats += r.pop("stage_stats", [])
    with open(f"{args.out}/exp3.json", "w") as f:
        json.dump(rows3, f, indent=1)
    for line in E3.summarize(rows3):
        print(line)

    # perf-trajectory artifact: stage_stats.json is always the latest run
    # (stable name for tooling), and every run ALSO lands in its own
    # timestamped snapshot so the trajectory accumulates across commits
    # instead of being clobbered
    meta = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "git_sha": _git_sha(),
        "dispatcher": os.environ.get("STRETTO_DISPATCHER", "") or "inline",
        "scale": scale,
        "full": bool(args.full),
    }
    with open(f"{args.out}/stage_stats.json", "w") as f:
        json.dump(stage_stats, f, indent=1)
    snap = (f"{args.out}/stage_stats-"
            f"{time.strftime('%Y%m%dT%H%M%S')}-{meta['git_sha']}.json")
    with open(snap, "w") as f:
        json.dump({"meta": meta, "stages": stage_stats}, f, indent=1)
    by_op = {}
    for r in stage_stats:
        d = by_op.setdefault(r["op_name"], dict(wall_s=0.0, n_tuples=0,
                                                kv_bytes=0, n_batches=0))
        d["wall_s"] += r["wall_s"]
        d["n_tuples"] += r["n_tuples"]
        d["kv_bytes"] += r["kv_bytes"]
        d["n_batches"] += r["n_batches"]
    print(f"# stage stats -> {args.out}/stage_stats.json and {snap} "
          f"({len(stage_stats)} stage records, "
          f"dispatcher={meta['dispatcher']})")
    for op, d in sorted(by_op.items()):
        us = d["wall_s"] / max(d["n_tuples"], 1) * 1e6
        mean_b = d["n_tuples"] / max(d["n_batches"], 1)
        csv_rows.append({"name": f"stage_{op}", "us_per_call": us,
                         "derived": f"tuples={d['n_tuples']} "
                                    f"kvMB={d['kv_bytes'] / 1e6:.1f} "
                                    f"batches={d['n_batches']} "
                                    f"meanb={mean_b:.1f}"})

    print("# kernel microbenches", flush=True)
    krows = kernels_bench.run()
    csv_rows.extend(krows)

    print("# roofline (from dry-run artifacts, if present)", flush=True)
    recs = roofline.load("results/dryrun_sp")
    if recs:
        for line in roofline.table(recs)[:40]:
            print(line)
        csv_rows.extend(roofline.csv_rows(recs))
    else:
        print("  (run `python -m repro.launch.dryrun --all --out "
              "results/dryrun_sp` first)")

    print("\nname,us_per_call,derived")
    for r in csv_rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    print(f"\n# total benchmark wall time: {time.time() - t0:.0f}s")
    world.close()


if __name__ == "__main__":
    main()
