"""Remote-engine loopback overhead smoke: wire cost per flush, and
coalesced-vs-solo wire calls.

Builds one two-tier pool twice against the same corpus — all-local
(fast sm engine + accurate lg gold) and with the fast tier served by an
in-process loopback worker (`EngineSpec(address=...)`) — then measures:

  parity    — the SAME plan executed by both pools must produce
              bit-identical decisions and map values (the subsystem's
              core guarantee; a bench that breaks it fails even
              without --gate)
  overhead  — wall-clock factor of the remote run over the local run,
              plus the member's measured RTT p50/p95 per wire call
              (server time subtracted, so this is pure wire + codec)
  coalesce  — K copies of the query through the QueryScheduler vs K
              solo runs: cross-query flush merging must reach the wire
              as strictly fewer remote calls

and merges the row into the newest BENCH_*.json under a separate
"remote" key (the kernels gate only reads "rows"). With ``--gate`` it
exits non-zero on a parity break, zero saved wire calls, or a loopback
RTT p50 past ``--max-rtt-ms`` — the regression tripwire for protocol
bloat (every frame layer shows up directly in that number).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import EngineSpec, Session, SessionConfig  # noqa: E402
from repro.core import PlannerConfig  # noqa: E402
from repro.data.synthetic import make_dataset  # noqa: E402
from repro.remote import RemoteWorker, start_server  # noqa: E402
from repro.remote.client import remote_members  # noqa: E402
from repro.scheduler import QueryScheduler  # noqa: E402

N_ITEMS = 90          # the planted two-tier workload that mixes engines
FAST_SPEC = dict(models=("sm",), sm_ratios=(0.8, 0.5), lg_ratios=())
PLANNER = PlannerConfig(steps=120, restarts=2, snapshots=2)


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "nogit"
    except Exception:
        return "nogit"


def _session(fast_spec: EngineSpec) -> Session:
    return Session(SessionConfig(
        engines=(fast_spec,
                 EngineSpec("accurate", models=("lg",), sm_ratios=(),
                            lg_ratios=(0.5,), include_cheap=False,
                            cache_dir=tempfile.mkdtemp(
                                prefix="stretto_bench_acc_"))),
        gold_engine="accurate",
        planner=PLANNER, sample_frac=0.35, partition_size=40))


def _frame(sess: Session, items):
    return (sess.frame(items)
            .sem_filter("f1", 1)
            .sem_map("extract v2", 2)
            .with_guarantees(recall=0.7, precision=0.7))


def run_bench(n_queries: int = 4) -> Dict:
    ds = make_dataset("remote-bench", N_ITEMS, seed=7)
    worker = RemoteWorker(
        "fast", cache_dir=tempfile.mkdtemp(prefix="stretto_bench_wrk_"),
        **FAST_SPEC)
    server, _, addr = start_server(worker)
    local = _session(EngineSpec(
        "fast", cache_dir=tempfile.mkdtemp(prefix="stretto_bench_fst_"),
        **FAST_SPEC))
    remote = _session(EngineSpec("fast", address=addr))
    try:
        local.prepare(ds.items)
        remote.prepare(ds.items)
        query = _frame(local, ds.items).to_query()
        plan = local.plan(query, ds.items)
        n_fast = sum(st.engine == "fast" for st in plan.stages)

        t0 = time.monotonic()
        lr = local.run(plan, query, ds.items, dispatcher="inline")
        local_wall = time.monotonic() - t0
        t0 = time.monotonic()
        rr = remote.run(plan, query, ds.items, dispatcher="inline")
        remote_wall = time.monotonic() - t0

        parity = bool(
            np.array_equal(rr.accepted, lr.accepted)
            and set(rr.map_values) == set(lr.map_values)
            and all(np.array_equal(rr.map_values[li], lr.map_values[li])
                    for li in lr.map_values))
        wire = rr.remote or {}

        # coalesced vs solo wire calls through the concurrent scheduler
        member = remote_members(remote.backend)[0]
        frame = _frame(remote, ds.items)
        frame.plan()          # planning profiles over the wire — keep
        #                       those calls out of both measured sides
        before = member.snapshot()["calls"]
        solo = frame.execute(dispatcher="inline")
        solo_calls = member.snapshot()["calls"] - before
        before = member.snapshot()["calls"]
        with QueryScheduler(remote, max_concurrent=n_queries,
                            paused=True) as sched:
            handles = [sched.submit(frame) for _ in range(n_queries)]
            sched.resume()
            results = [h.result(timeout=600) for h in handles]
        sched_calls = member.snapshot()["calls"] - before
        parity = parity and all(
            np.array_equal(r.accepted, solo.accepted) for r in results)

        return {
            "name": "remote_loopback_overhead",
            "n_items": N_ITEMS,
            "n_fast_stages": n_fast,
            "n_queries": n_queries,
            "parity": parity,
            "local_wall_s": local_wall,
            "remote_wall_s": remote_wall,
            "overhead_factor": remote_wall / max(local_wall, 1e-9),
            "wire_calls": wire.get("calls", 0),
            "wire_kb": wire.get("wire_kb", 0.0),
            "rtt_ms_p50": wire.get("rtt_ms_p50", 0.0),
            "rtt_ms_p95": wire.get("rtt_ms_p95", 0.0),
            "fallbacks": wire.get("fallbacks", 0),
            "solo_wire_calls": solo_calls,
            "scheduled_wire_calls": sched_calls,
            "saved_wire_calls": n_queries * solo_calls - sched_calls,
        }
    finally:
        local.close()
        remote.close()
        server.shutdown()
        server.server_close()


def _emit_artifact(row: Dict, out_dir: str) -> str:
    """Merge under "remote" into the newest BENCH_*.json (the artifact
    CI uploads), else write a standalone file."""
    os.makedirs(out_dir, exist_ok=True)
    paths = sorted(glob.glob(os.path.join(out_dir, "BENCH_*.json")))
    if paths:
        path = paths[-1]
        with open(path) as f:
            artifact = json.load(f)
        artifact["remote"] = row
        with open(path, "w") as f:
            json.dump(artifact, f, indent=1)
        return path
    ts = time.strftime("%Y%m%dT%H%M%SZ", time.gmtime())
    path = os.path.join(out_dir, f"BENCH_{ts}-{_git_sha()}.json")
    with open(path, "w") as f:
        json.dump({"schema": "stretto-remote-bench-v1", "ts": ts,
                   "sha": _git_sha(), "remote": row}, f, indent=1)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller run (2 scheduled queries)")
    ap.add_argument("--gate", action="store_true",
                    help="fail on parity break, zero saved wire calls, "
                         "or RTT p50 past --max-rtt-ms")
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--max-rtt-ms", type=float, default=25.0,
                    help="--gate: max loopback RTT p50 per wire call")
    ap.add_argument("--out", default="results/bench",
                    help="artifact directory (merges into the newest "
                         "BENCH_*.json there)")
    args = ap.parse_args(argv)

    n_queries = args.queries or (2 if args.smoke else 4)
    row = run_bench(n_queries)
    print(f"[remote] {row['n_items']} items, {row['n_fast_stages']} fast "
          f"stages over the wire: local {row['local_wall_s']:.2f}s vs "
          f"remote {row['remote_wall_s']:.2f}s "
          f"({row['overhead_factor']:.2f}x), "
          f"{row['wire_calls']} calls / {row['wire_kb']:.1f} KiB, "
          f"rtt p50 {row['rtt_ms_p50']:.2f}ms p95 "
          f"{row['rtt_ms_p95']:.2f}ms")
    print(f"[remote] scheduler: {row['n_queries']}x solo = "
          f"{row['n_queries'] * row['solo_wire_calls']} wire calls, "
          f"scheduled = {row['scheduled_wire_calls']} "
          f"({row['saved_wire_calls']} saved), "
          f"parity={'ok' if row['parity'] else 'BROKEN'}")

    failed = False
    if not row["parity"]:
        print("[remote] FAIL: remote decisions diverged from local")
        failed = True
    if row["n_fast_stages"] == 0 or row["wire_calls"] == 0:
        print("[remote] FAIL: no stage actually went over the wire")
        failed = True
    if args.gate and row["saved_wire_calls"] <= 0:
        print("[remote] FAIL: scheduler saved no wire calls")
        failed = True
    if args.gate and row["rtt_ms_p50"] > args.max_rtt_ms:
        print(f"[remote] FAIL: rtt p50 {row['rtt_ms_p50']:.2f}ms > "
              f"{args.max_rtt_ms:.2f}ms")
        failed = True

    path = _emit_artifact(row, args.out)
    print(f"[remote] wrote {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
