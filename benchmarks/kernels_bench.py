"""Kernel microbenchmarks: jnp-oracle wall time on CPU (the TPU numbers come
from the dry-run roofline; CPU timing here only sanity-checks the wrappers)
plus lowering checks for the Pallas kernels."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=5) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    # decode attention: serving hot loop shapes
    for (B, KV, G, dk, S) in [(8, 8, 4, 128, 2048), (32, 2, 2, 64, 512)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, KV, G, dk), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, KV, dk), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, KV, dk), jnp.float32)
        lens = jnp.full((B,), S, jnp.int32)
        f = jax.jit(lambda *a: ops.decode_attention(*a, backend="ref"))
        dt = _time(f, q, kc, vc, lens)
        flops = 4.0 * B * KV * G * dk * S
        rows.append({"name": f"decode_attn_B{B}_S{S}",
                     "us_per_call": dt * 1e6,
                     "derived": f"{flops / dt / 1e9:.1f}GFLOP/s_cpu_ref"})
    # expected attention scoring
    ks = jax.random.split(key, 3)
    kc = jax.random.normal(ks[0], (4, 1024, 8, 128), jnp.float32)
    mu = jax.random.normal(ks[1], (8, 4, 128), jnp.float32)
    sg = jnp.abs(jax.random.normal(ks[2], (8, 4, 128), jnp.float32))
    f = jax.jit(lambda *a: ops.expected_attention_scores(*a, backend="ref"))
    dt = _time(f, kc, mu, sg)
    rows.append({"name": "expected_attention_4x1024", "us_per_call": dt * 1e6,
                 "derived": "scores"})
    # pallas interpret-mode correctness spot check (1 shape each)
    q = jax.random.normal(key, (1, 2, 2, 64), jnp.float32)
    kc = jax.random.normal(key, (1, 128, 2, 64), jnp.float32)
    vc = jax.random.normal(key, (1, 128, 2, 64), jnp.float32)
    lens = jnp.asarray([100], jnp.int32)
    d = ops.decode_attention(q, kc, vc, lens, backend="interpret")
    r = ref.decode_attention_ref(q, kc, vc, lens)
    err = float(jnp.max(jnp.abs(d - r)))
    rows.append({"name": "decode_attn_pallas_interpret_err",
                 "us_per_call": 0.0, "derived": f"maxerr={err:.2e}"})
    return rows
