"""Kernel microbenchmarks + the roofline-anchored CI perf gate.

Two modes:

  run()     — rows consumed by benchmarks/run.py's CSV (name, us_per_call,
              derived): wall time per variant plus Pallas interpret-mode
              correctness spot checks.

  --gate    — the CI perf gate: times the serving-path attention variants
              (single-query decode, fused multi-token query, int8 KV) on
              both the ref and Pallas(interpret) backends, records
              wall-time-per-tuple against the analytic roofline bound
              (benchmarks/roofline.py), writes a BENCH_<ts>-<sha>.json
              trajectory artifact, and fails (exit != 0) on
                * Pallas lowering/correctness errors (interpret mode), or
                * a >25% wall-time-per-tuple regression on any variant vs
                  the newest previous BENCH_*.json artifact.

Timing blocks every rep (async dispatch would otherwise under-time all
but the last) and takes the min over reps — the least-noise estimator for
a CI runner.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from repro.kernels import ops, ref  # noqa: E402
import roofline  # noqa: E402

# gate shapes: small enough for interpret mode on a CPU runner, big
# enough that per-call wall time dominates dispatch overhead
GATE_B, GATE_S, GATE_KV, GATE_G, GATE_DK = 4, 256, 2, 2, 64
GATE_LQ = 6


def _time(fn, *args, reps: int = 5) -> float:
    """Min wall time per call over `reps`, blocking EVERY rep (async
    dispatch under-times all but the last otherwise)."""
    fn(*args).block_until_ready()          # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _gate_inputs(key, quant: bool = False):
    ks = jax.random.split(key, 3)
    B, S, KV, G, dk = GATE_B, GATE_S, GATE_KV, GATE_G, GATE_DK
    q1 = jax.random.normal(ks[0], (B, KV, G, dk), jnp.float32)
    qm = jnp.broadcast_to(q1[:, None], (B, GATE_LQ, KV, G, dk))
    kc = jax.random.normal(ks[1], (B, S, KV, dk), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, KV, dk), jnp.float32)
    lens = jnp.asarray([S, S - 40, S // 2, 37], jnp.int32)
    if not quant:
        return q1, qm, kc, vc, lens, None, None
    k_s = jnp.max(jnp.abs(kc), -1) / 127.0
    v_s = jnp.max(jnp.abs(vc), -1) / 127.0
    k_q = jnp.round(kc / k_s[..., None]).astype(jnp.int8)
    v_q = jnp.round(vc / v_s[..., None]).astype(jnp.int8)
    return q1, qm, k_q, v_q, lens, k_s, v_s


def _variant_rows(backend: str, reps: int = 5) -> List[Dict]:
    """wall-time-per-tuple + roofline bound for the three serving-path
    attention variants under one kernels backend."""
    key = jax.random.PRNGKey(0)
    B, S, KV, G, dk = GATE_B, GATE_S, GATE_KV, GATE_G, GATE_DK
    q1, qm, kc, vc, lens, _, _ = _gate_inputs(key)
    _, _, k_q, v_q, _, k_s, v_s = _gate_inputs(key, quant=True)
    rows = []

    def row(name, dt, n_q, kv_bytes_per_elem, scale_bytes):
        bound = roofline.decode_bound_s(
            B, S, KV, G, dk, dk, n_q=n_q,
            kv_bytes_per_elem=kv_bytes_per_elem, scale_bytes=scale_bytes)
        per_tuple = dt / B
        rows.append({
            "name": f"{name}_{backend}",
            "us_per_call": dt * 1e6,
            "wall_us_per_tuple": per_tuple * 1e6,
            "roofline_us_per_tuple": bound["bound_s"] / B * 1e6,
            "derived": (f"per_tuple={per_tuple * 1e6:.1f}us;"
                        f"bound={bound['bound_s'] / B * 1e6:.1f}us;"
                        f"dom={bound['dominant']}"),
        })

    f = jax.jit(lambda *a: ops.decode_attention(*a, backend=backend))
    row("decode", _time(f, q1, kc, vc, lens, reps=reps), 1, 4, 0)

    f = jax.jit(lambda *a: ops.decode_query_attention(*a, backend=backend))
    row("fused_query", _time(f, qm, kc, vc, lens, reps=reps), GATE_LQ, 4, 0)

    f = jax.jit(lambda q, k, v, l, ks_, vs_: ops.decode_attention(
        q, k, v, l, backend=backend, k_scale=ks_, v_scale=vs_))
    row("decode_int8", _time(f, q1, k_q, v_q, lens, k_s, v_s, reps=reps),
        1, 1, 4)
    return rows


def _lowering_checks() -> List[Dict]:
    """Pallas interpret-mode vs ref parity on the gate shapes. Any
    lowering error raises; any mismatch reports err > tol for the gate
    to fail on."""
    key = jax.random.PRNGKey(1)
    q1, qm, kc, vc, lens, _, _ = _gate_inputs(key)
    _, _, k_q, v_q, _, k_s, v_s = _gate_inputs(key, quant=True)
    checks = []

    d = ops.decode_attention(q1, kc, vc, lens, backend="interpret")
    r = ref.decode_attention_ref(q1, kc, vc, lens)
    checks.append(("decode", float(jnp.max(jnp.abs(d - r))), 1e-4))

    d = ops.decode_query_attention(qm, kc, vc, lens, backend="interpret")
    r = ref.decode_query_attention_ref(qm, kc, vc, lens)
    checks.append(("fused_query", float(jnp.max(jnp.abs(d - r))), 1e-4))

    d = ops.decode_attention(q1, k_q, v_q, lens, backend="interpret",
                             k_scale=k_s, v_scale=v_s)
    r = ref.decode_attention_ref(q1, k_q.astype(jnp.float32) * k_s[..., None],
                                 v_q.astype(jnp.float32) * v_s[..., None],
                                 lens)
    checks.append(("decode_int8", float(jnp.max(jnp.abs(d - r))), 1e-4))

    return [{"name": f"lowering_{n}", "us_per_call": 0.0, "err": e,
             "tol": t, "ok": e <= t, "derived": f"maxerr={e:.2e}"}
            for n, e, t in checks]


def run() -> List[Dict]:
    """Rows for benchmarks/run.py: ref-backend wall times for every
    serving-path variant, plus the interpret-mode parity spot checks."""
    return _variant_rows("ref") + _lowering_checks()


# ---------------------------------------------------------------------------
# CI perf gate
# ---------------------------------------------------------------------------

def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "nogit"
    except Exception:
        return "nogit"


def _latest_artifact(dirpath: str, exclude: Optional[str] = None
                     ) -> Optional[str]:
    paths = sorted(glob.glob(os.path.join(dirpath, "BENCH_*.json")))
    paths = [p for p in paths if os.path.abspath(p) != exclude]
    return paths[-1] if paths else None


def gate(out_dir: str, baseline_dir: Optional[str] = None,
         max_regression: float = 0.25, reps: int = 5) -> int:
    """Run the perf gate; returns the process exit code."""
    os.makedirs(out_dir, exist_ok=True)
    checks = _lowering_checks()
    rows = _variant_rows("ref", reps=reps) \
        + _variant_rows("interpret", reps=reps)

    artifact = {
        "schema": "stretto-kernels-bench-v1",
        "ts": time.strftime("%Y%m%dT%H%M%SZ", time.gmtime()),
        "sha": _git_sha(),
        "backend_device": jax.default_backend(),
        "peaks": roofline.PEAKS.name,
        "shapes": {"B": GATE_B, "S": GATE_S, "KV": GATE_KV, "G": GATE_G,
                   "dk": GATE_DK, "Lq": GATE_LQ},
        "lowering": checks,
        "rows": rows,
    }

    failed = False
    for c in checks:
        status = "ok" if c["ok"] else "FAIL"
        print(f"[lowering] {c['name']}: {c['derived']} ({status})")
        failed |= not c["ok"]

    print(f"[gate] roofline priced against peak set "
          f"{roofline.PEAKS.name!r} "
          f"({roofline.PEAKS.flops / 1e9:.0f} GFLOP/s, "
          f"{roofline.PEAKS.hbm_bw / 1e9:.0f} GB/s)")
    for r in rows:
        print(f"[perf] {r['name']}: {r['wall_us_per_tuple']:.1f} us/tuple "
              f"(roofline bound {r['roofline_us_per_tuple']:.1f})")

    baseline_dir = baseline_dir or out_dir
    prev_path = _latest_artifact(baseline_dir)
    if prev_path:
        with open(prev_path) as f:
            prev = {r["name"]: r for r in json.load(f).get("rows", [])}
        for r in rows:
            old = prev.get(r["name"])
            if not old or "wall_us_per_tuple" not in old:
                continue
            ratio = r["wall_us_per_tuple"] / max(old["wall_us_per_tuple"],
                                                 1e-9)
            delta_us = r["wall_us_per_tuple"] - old["wall_us_per_tuple"]
            # the absolute floor keeps sub-50us dispatch jitter from
            # tripping the relative threshold on fast variants
            if ratio > 1.0 + max_regression and delta_us > 50.0:
                print(f"[gate] REGRESSION {r['name']}: "
                      f"{old['wall_us_per_tuple']:.1f} -> "
                      f"{r['wall_us_per_tuple']:.1f} us/tuple "
                      f"({(ratio - 1) * 100:.0f}% > "
                      f"{max_regression * 100:.0f}%) vs {prev_path}")
                failed = True
        print(f"[gate] compared against {prev_path}")
    else:
        print("[gate] no previous BENCH_*.json artifact; recording baseline")

    out_path = os.path.join(
        out_dir, f"BENCH_{artifact['ts']}-{artifact['sha']}.json")
    with open(out_path, "w") as f:
        json.dump(artifact, f, indent=1)
    print(f"[gate] wrote {out_path}")
    return 1 if failed else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gate", action="store_true",
                    help="run the CI perf gate (exit != 0 on lowering "
                         "errors or wall-time regressions)")
    ap.add_argument("--out", default="results/bench",
                    help="directory for the BENCH_*.json artifact")
    ap.add_argument("--baseline", default=None,
                    help="directory holding the previous BENCH_*.json "
                         "(default: --out)")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="max tolerated wall-time-per-tuple regression")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args(argv)
    if args.gate:
        return gate(args.out, args.baseline, args.max_regression, args.reps)
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
