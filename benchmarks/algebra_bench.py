"""Semantic algebra smoke bench: sem_join + sem_topk plan/execute/meet.

Plans and executes the two tree-shaped operators end to end on the
planted synthetic corpora through the public Session API:

  join  — two-corpus ``sem_join`` blocked on the shared category column:
          both side cascades plus the pairing cascade planned through
          ONE grouped relaxation (the query-level error budget split
          across the tree's pipelines), executed as three streaming
          cascade runs over blocked survivor pairs
  topk  — ``sem_topk`` rank cut: reject-only cascade with gold-score
          recording and one deterministic global cut at finalize

and records planning/execution wall clock, LLM-tuple counts, the
blocked-pair corpus size against the full cross product, and
recall/precision against the gold tree reference. With ``--gate`` it
exits non-zero when a feasible plan misses its declared recall target
(minus statistical headroom) — the guarantee-met existence proof, not
just an it-parses check.

Artifact flow: the result dict merges into the newest BENCH_*.json in
--out under a separate "algebra" key (the kernels gate's per-row
regression check only reads "rows", so these numbers never trip it), or
a standalone BENCH file when no kernels artifact exists.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import time
from typing import Dict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Session, SessionConfig  # noqa: E402
from repro.core import PlannerConfig  # noqa: E402
from repro.data.synthetic import make_dataset, make_join_corpora  # noqa: E402

SMOKE = dict(n_side=60, n_items=100, k=30,
             planner=PlannerConfig(steps=150, restarts=2, snapshots=3))
FULL = dict(n_side=120, n_items=240, k=60,
            planner=PlannerConfig(steps=400, restarts=3, snapshots=4))


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "nogit"
    except Exception:
        return "nogit"


def bench_join(sess: Session, n_side: int, target: float) -> Dict:
    left, right = make_join_corpora(n_left=n_side, n_right=n_side, seed=5)
    jf = (sess.frame(left.items)
          .sem_filter("left side filter", task_id=1)
          .sem_join(sess.frame(right.items), "same latent value",
                    task_id=3, on="category")
          .with_guarantees(recall=target, precision=target))
    t0 = time.monotonic()
    plan = jf.plan()
    plan_wall = time.monotonic() - t0
    t0 = time.monotonic()
    res = jf.execute()
    exec_wall = time.monotonic() - t0
    m = res.metrics()
    return {
        "n_left": n_side, "n_right": n_side,
        "target_recall": target,
        "feasible": bool(plan.feasible),
        "recall_bound": plan.recall_bound,
        "precision_bound": plan.precision_bound,
        "budget_split": {r: list(v) for r, v in plan.split.items()},
        "est_pairs": plan.est_pairs,
        "pairs_scored": len(res.pair_items),
        "cross_product": n_side * n_side,
        "n_result": m["n_result"], "n_gold": m["n_gold"],
        "recall": m["recall"], "precision": m["precision"],
        "n_llm_tuples": res.n_llm_tuples,
        "plan_wall_s": plan_wall, "exec_wall_s": exec_wall,
    }


def bench_topk(sess: Session, n_items: int, k: int, target: float) -> Dict:
    ds = make_dataset("alg-bench", n_items, seed=9)
    fr = (sess.frame(ds.items)
          .sem_topk("rank by topic 2", task_id=2, k=k)
          .with_guarantees(recall=target, precision=target))
    t0 = time.monotonic()
    plan = fr.plan()
    plan_wall = time.monotonic() - t0
    t0 = time.monotonic()
    res = fr.execute()
    exec_wall = time.monotonic() - t0
    m = res.metrics()
    return {
        "n_items": n_items, "k": k,
        "target_recall": target,
        "feasible": bool(plan.feasible),
        "recall_bound": plan.recall_bound,
        "n_accepted": int(res.accepted.sum()),
        "recall": m["recall"], "precision": m["precision"],
        "n_llm_tuples": res.n_llm_tuples,
        "plan_wall_s": plan_wall, "exec_wall_s": exec_wall,
    }


def run_bench(smoke: bool, target: float) -> Dict:
    p = SMOKE if smoke else FULL
    with Session(SessionConfig(planner=p["planner"], sample_frac=0.3,
                               sm_ratios=(0.5, 0.0), lg_ratios=(0.5,),
                               include_cheap=True)) as sess:
        join = bench_join(sess, p["n_side"], target)
        topk = bench_topk(sess, p["n_items"], p["k"], target)
    return {"name": "algebra_join_topk", "mode": "smoke" if smoke else
            "full", "join": join, "topk": topk}


def _emit_artifact(row: Dict, out_dir: str) -> str:
    """Merge under "algebra" into the newest BENCH_*.json (the same
    artifact CI uploads), else write a standalone file."""
    os.makedirs(out_dir, exist_ok=True)
    paths = sorted(glob.glob(os.path.join(out_dir, "BENCH_*.json")))
    if paths:
        path = paths[-1]
        with open(path) as f:
            artifact = json.load(f)
        artifact["algebra"] = row
        with open(path, "w") as f:
            json.dump(artifact, f, indent=1)
        return path
    ts = time.strftime("%Y%m%dT%H%M%SZ", time.gmtime())
    path = os.path.join(out_dir, f"BENCH_{ts}-{_git_sha()}.json")
    with open(path, "w") as f:
        json.dump({"schema": "stretto-algebra-bench-v1", "ts": ts,
                   "sha": _git_sha(), "algebra": row}, f, indent=1)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpora + fast annealer (CI mode)")
    ap.add_argument("--gate", action="store_true",
                    help="fail when a feasible plan misses its declared "
                         "recall target minus --headroom")
    ap.add_argument("--target", type=float, default=0.6,
                    help="declared recall/precision target")
    ap.add_argument("--headroom", type=float, default=0.1,
                    help="--gate: statistical slack below the declared "
                         "target before failing")
    ap.add_argument("--out", default="results/bench",
                    help="artifact directory (merges into the newest "
                         "BENCH_*.json there)")
    args = ap.parse_args(argv)

    row = run_bench(args.smoke, args.target)
    j, t = row["join"], row["topk"]
    print(f"[algebra] join {j['n_left']}x{j['n_right']}: "
          f"{j['pairs_scored']} of {j['cross_product']} pairs scored, "
          f"recall {j['recall']:.3f} / precision {j['precision']:.3f} "
          f"(target {j['target_recall']:.2f}, feasible={j['feasible']}), "
          f"split over {len(j['budget_split'])} pipelines, "
          f"plan {j['plan_wall_s']:.1f}s exec {j['exec_wall_s']:.1f}s")
    print(f"[algebra] topk k={t['k']}/{t['n_items']}: "
          f"{t['n_accepted']} accepted, recall {t['recall']:.3f} "
          f"(target {t['target_recall']:.2f}, feasible={t['feasible']}), "
          f"plan {t['plan_wall_s']:.1f}s exec {t['exec_wall_s']:.1f}s")

    failed = False
    floor = args.target - args.headroom
    for label, r in (("join", j), ("topk", t)):
        if args.gate and r["feasible"] and r["recall"] < floor:
            print(f"[algebra] FAIL: {label} recall {r['recall']:.3f} < "
                  f"{floor:.3f} (declared {args.target:.2f} - headroom)")
            failed = True
    if len(j["budget_split"]) < 2:
        print("[algebra] FAIL: join budget split covers "
              f"{len(j['budget_split'])} pipeline(s), expected >= 2")
        failed = True

    path = _emit_artifact(row, args.out)
    print(f"[algebra] wrote {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
