"""Shared benchmark world, built on the declarative Session API.

A `World` is a `repro.Session` (engine lifecycle, profile building,
backend + dispatcher resolution, gold memoization) plus the paper's
evaluation corpora and query generator (§6.1: templates with 2-4 semantic
placeholders). Experiments execute plans via `world.execute(...)` /
`world.gold(...)`, which route through the session's streaming-runtime
defaults — the same single execution path the public API uses."""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.api import Session, SessionConfig
from repro.core import Query, SemFilter, SemMap
from repro.core.physical import PhysicalPlan
from repro.data.synthetic import Dataset, paper_datasets
from repro.runtime import DEFAULT_COALESCE, RuntimeResult

SM_RATIOS = (0.8, 0.5, 0.0)
LG_RATIOS = (0.8, 0.6, 0.3)
ALL_RATIOS = tuple(sorted({0.0, *SM_RATIOS, *LG_RATIOS}))

# streaming defaults for benchmark executions: bounded working set with
# engine-friendly coalesced batches (late cascade stages accumulate
# eligible tuples across partitions until COALESCE are pending). The
# coalesce width is the runtime's shared default, which is also what the
# planner's batch-aware cost model amortizes fixed per-call costs over.
PARTITION_SIZE = 256
COALESCE = DEFAULT_COALESCE


@dataclass
class World:
    session: Session
    datasets: Dict[str, Dataset]
    backend_nocomp: object      # Exp 2 baseline: uncompressed ladder only

    @property
    def engine(self):
        return self.session.engine

    @property
    def backend(self):
        """Full compression ladder."""
        return self.session.backend

    @property
    def reference(self):
        """Gold (lg @ 0.0) — the quality reference."""
        return self.session.reference

    def execute(self, plan: PhysicalPlan, query: Query, items,
                backend=None) -> RuntimeResult:
        """All benchmark executions go through the session's streaming
        runtime defaults (PARTITION_SIZE / COALESCE)."""
        return self.session.run(plan, query, items, backend)

    def gold(self, query: Query, items) -> RuntimeResult:
        """Gold execution via the reference backend, memoized per
        (corpus, query) by the session."""
        return self.session.gold(query, items)

    def close(self):
        self.session.close()


def build_world(scale: float = 0.3, cache_dir: str | None = None,
                dataset_names: Sequence[str] | None = None,
                config: Optional[SessionConfig] = None) -> World:
    datasets = paper_datasets(scale)
    if dataset_names:
        datasets = {k: v for k, v in datasets.items() if k in dataset_names}
    if config is None:
        config = SessionConfig()
    # keep every caller-declared field; override only the benchmark's
    # fixed world shape (ladder, ratios, streaming execution defaults)
    base = replace(
        config,
        cache_dir=cache_dir if cache_dir is not None else config.cache_dir,
        profile_ratios=ALL_RATIOS, prefill_batch=48,
        sm_ratios=SM_RATIOS, lg_ratios=LG_RATIOS,
        partition_size=PARTITION_SIZE, coalesce=COALESCE)
    session = Session(base)
    t0 = time.time()
    for name, ds in datasets.items():
        session.prepare(ds.items)
        print(f"[world] cache profiles built for {name} "
              f"({len(ds.items)} items, {time.time() - t0:.0f}s elapsed)")
    backend_nocomp = session.backend_for(sm_ratios=(0.0,), lg_ratios=(),
                                         include_cheap=True)
    return World(session, datasets, backend_nocomp)


def generate_queries(ds: Dataset, n_queries: int, target: float,
                     seed: int = 0) -> List[Query]:
    """Paper-style templates: 2-4 semantic operator slots, filled from the
    dataset's filter/map pools, shuffled, non-empty guaranteed by
    construction (planted labels are balanced)."""
    rng = np.random.default_rng(seed)
    out = []
    templates = [("f", "f"), ("f", "m"), ("f", "f", "m"),
                 ("f", "m", "m"), ("f", "f", "f"), ("f", "f", "m", "m")]
    for qi in range(n_queries):
        t = templates[qi % len(templates)]
        nodes = []
        f_pool = list(rng.permutation(ds.n_filter_tasks))
        m_pool = list(rng.permutation(ds.n_map_tasks))
        for slot in t:
            if slot == "f" and f_pool:
                k = int(f_pool.pop())
                nodes.append(SemFilter(f"filter task {k}", k))
            elif m_pool:
                k = int(m_pool.pop())
                nodes.append(SemMap(f"map task {k}", k))
        rng.shuffle(nodes)
        out.append(Query(nodes, target_recall=target,
                         target_precision=target))
    return out


def stage_stats_rows(tag: str, result: RuntimeResult,
                     plan: Optional[PhysicalPlan] = None) -> List[Dict]:
    """Flatten a result's StageStats for the perf-trajectory artifact,
    tagged with the dispatch configuration that executed them (per-stage
    mean batch size rides along in as_dict).

    When the plan that produced the result is supplied, each row also
    records the planner's expectations next to the measurement —
    ``planned_batch`` / ``planned_cost_per_tuple_s`` and the
    ``batch_drift`` ratio (measured mean flush / planned expected flush)
    — so the trajectory shows the measure -> plan loop converging instead
    of only what execution did."""
    planned = {}
    if plan is not None:
        planned = {(st.logical_idx, st.stage, st.op_name): st
                   for st in plan.stages}
    rows = []
    for s in result.stage_stats:
        row = {"tag": tag, "dispatcher": result.dispatcher,
               "n_workers": result.n_workers, **s.as_dict()}
        st = planned.get((s.logical_idx, s.stage, s.op_name))
        if st is not None and st.exp_batch:
            row["planned_batch"] = round(st.exp_batch, 2)
            row["planned_cost_per_tuple_s"] = st.cost
            row["batch_drift"] = round(
                s.mean_batch / max(st.exp_batch, 1e-9), 3)
        rows.append(row)
    return rows
