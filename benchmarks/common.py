"""Shared benchmark world: datasets, engine with cache profiles, registry,
query generation (paper §6.1: templates with 2-4 semantic placeholders),
and gold-plan execution."""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from repro.cache.store import CacheStore
from repro.core import Query, RelFilter, SemFilter, SemMap, execute_plan
from repro.core.physical import PhysicalPlan, PhysicalPlanStage
from repro.data.synthetic import (Dataset, make_dataset, make_planted_params,
                                  paper_datasets, planted_config)
from repro.serving.engine import ServingEngine
from repro.serving.operators import make_registry

SM_RATIOS = (0.8, 0.5, 0.0)
LG_RATIOS = (0.8, 0.6, 0.3)
ALL_RATIOS = sorted({0.0, *SM_RATIOS, *LG_RATIOS})


@dataclass
class World:
    datasets: Dict[str, Dataset]
    engine: ServingEngine
    registry: object
    registry_nocomp: object     # Exp 2 baseline: uncompressed caches only


def build_world(scale: float = 0.3, cache_dir: str | None = None,
                dataset_names: Sequence[str] | None = None) -> World:
    datasets = paper_datasets(scale)
    if dataset_names:
        datasets = {k: v for k, v in datasets.items() if k in dataset_names}
    store = CacheStore(cache_dir or tempfile.mkdtemp(prefix="stretto_cache_"))
    eng = ServingEngine(store)
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        eng.register_model(size, cfg, make_planted_params(cfg, seed=1))
    t0 = time.time()
    for name, ds in datasets.items():
        for size in ("sm", "lg"):
            eng.build_profiles(size, ds.items, ratios=ALL_RATIOS,
                               prefill_batch=48)
        print(f"[world] cache profiles built for {name} "
              f"({len(ds.items)} items, {time.time() - t0:.0f}s elapsed)")
    registry = make_registry(eng, sm_ratios=SM_RATIOS, lg_ratios=LG_RATIOS)
    registry_nocomp = make_registry(eng, sm_ratios=(0.0,), lg_ratios=())
    return World(datasets, eng, registry, registry_nocomp)


def generate_queries(ds: Dataset, n_queries: int, target: float,
                     seed: int = 0) -> List[Query]:
    """Paper-style templates: 2-4 semantic operator slots, filled from the
    dataset's filter/map pools, shuffled, non-empty guaranteed by
    construction (planted labels are balanced)."""
    rng = np.random.default_rng(seed)
    out = []
    templates = [("f", "f"), ("f", "m"), ("f", "f", "m"),
                 ("f", "m", "m"), ("f", "f", "f"), ("f", "f", "m", "m")]
    for qi in range(n_queries):
        t = templates[qi % len(templates)]
        nodes = []
        f_pool = list(rng.permutation(ds.n_filter_tasks))
        m_pool = list(rng.permutation(ds.n_map_tasks))
        for slot in t:
            if slot == "f" and f_pool:
                k = int(f_pool.pop())
                nodes.append(SemFilter(f"filter task {k}", k))
            elif m_pool:
                k = int(m_pool.pop())
                nodes.append(SemMap(f"map task {k}", k))
        rng.shuffle(nodes)
        out.append(Query(nodes, target_recall=target,
                         target_precision=target))
    return out


def gold_plan_for(query: Query, registry) -> PhysicalPlan:
    stages = []
    for li, op in enumerate(query.semantic_ops):
        ops = registry(op)
        stages.append(PhysicalPlanStage(
            li, 0, ops[-1].name, 0.0, 0.0,
            isinstance(op, SemMap), True, 1.0))
    return PhysicalPlan(stages, list(query.relational_ops), 0.0, 1.0, 1.0,
                        True)


def execute_gold(query: Query, items, registry):
    return execute_plan(gold_plan_for(query, registry), query, items,
                        registry)
