"""Shared benchmark world: datasets, engine with cache profiles, runtime
backends, query generation (paper §6.1: templates with 2-4 semantic
placeholders), and gold-plan execution through the streaming runtime."""
from __future__ import annotations

import tempfile
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.cache.store import CacheStore
from repro.core import Query, SemFilter, SemMap
from repro.core.physical import PhysicalPlan
from repro.data.synthetic import (Dataset, make_dataset, make_planted_params,
                                  paper_datasets, planted_config)
from repro.runtime import (DEFAULT_COALESCE, KVCacheBackend,
                           ReferenceBackend, RuntimeResult, gold_plan_for)
from repro.runtime import run_plan as _run_plan
from repro.serving.engine import ServingEngine

SM_RATIOS = (0.8, 0.5, 0.0)
LG_RATIOS = (0.8, 0.6, 0.3)
ALL_RATIOS = sorted({0.0, *SM_RATIOS, *LG_RATIOS})

# streaming defaults for benchmark executions: bounded working set with
# engine-friendly coalesced batches (late cascade stages accumulate
# eligible tuples across partitions until COALESCE are pending). The
# coalesce width is the runtime's shared default, which is also what the
# planner's batch-aware cost model amortizes fixed per-call costs over.
PARTITION_SIZE = 256
COALESCE = DEFAULT_COALESCE


@dataclass
class World:
    datasets: Dict[str, Dataset]
    engine: ServingEngine
    backend: KVCacheBackend           # full compression ladder
    backend_nocomp: KVCacheBackend    # Exp 2 baseline: uncompressed only
    reference: ReferenceBackend       # gold (lg @ 0.0) — quality reference


def build_world(scale: float = 0.3, cache_dir: str | None = None,
                dataset_names: Sequence[str] | None = None) -> World:
    datasets = paper_datasets(scale)
    if dataset_names:
        datasets = {k: v for k, v in datasets.items() if k in dataset_names}
    store = CacheStore(cache_dir or tempfile.mkdtemp(prefix="stretto_cache_"))
    eng = ServingEngine(store)
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        eng.register_model(size, cfg, make_planted_params(cfg, seed=1))
    t0 = time.time()
    for name, ds in datasets.items():
        for size in ("sm", "lg"):
            eng.build_profiles(size, ds.items, ratios=ALL_RATIOS,
                               prefill_batch=48)
        print(f"[world] cache profiles built for {name} "
              f"({len(ds.items)} items, {time.time() - t0:.0f}s elapsed)")
    backend = KVCacheBackend(eng, sm_ratios=SM_RATIOS, lg_ratios=LG_RATIOS)
    backend_nocomp = KVCacheBackend(eng, sm_ratios=(0.0,), lg_ratios=(),
                                    include_cheap=True)
    return World(datasets, eng, backend, backend_nocomp,
                 ReferenceBackend(eng))


def generate_queries(ds: Dataset, n_queries: int, target: float,
                     seed: int = 0) -> List[Query]:
    """Paper-style templates: 2-4 semantic operator slots, filled from the
    dataset's filter/map pools, shuffled, non-empty guaranteed by
    construction (planted labels are balanced)."""
    rng = np.random.default_rng(seed)
    out = []
    templates = [("f", "f"), ("f", "m"), ("f", "f", "m"),
                 ("f", "m", "m"), ("f", "f", "f"), ("f", "f", "m", "m")]
    for qi in range(n_queries):
        t = templates[qi % len(templates)]
        nodes = []
        f_pool = list(rng.permutation(ds.n_filter_tasks))
        m_pool = list(rng.permutation(ds.n_map_tasks))
        for slot in t:
            if slot == "f" and f_pool:
                k = int(f_pool.pop())
                nodes.append(SemFilter(f"filter task {k}", k))
            elif m_pool:
                k = int(m_pool.pop())
                nodes.append(SemMap(f"map task {k}", k))
        rng.shuffle(nodes)
        out.append(Query(nodes, target_recall=target,
                         target_precision=target))
    return out


def execute(plan: PhysicalPlan, query: Query, items, backend,
            partition_size: Optional[int] = PARTITION_SIZE,
            coalesce: Optional[int] = COALESCE) -> RuntimeResult:
    """All benchmark executions go through the streaming runtime."""
    return _run_plan(plan, query, items, backend,
                     partition_size=partition_size, coalesce=coalesce)


def execute_gold(query: Query, items, backend) -> RuntimeResult:
    """Gold execution; pass World.reference to pin the gold-only backend,
    or any backend whose candidate lists end in the gold operator."""
    return execute(gold_plan_for(query, backend), query, items, backend)


def stage_stats_rows(tag: str, result: RuntimeResult) -> List[Dict]:
    """Flatten a result's StageStats for the perf-trajectory artifact,
    tagged with the dispatch configuration that executed them (per-stage
    mean batch size rides along in as_dict)."""
    return [{"tag": tag, "dispatcher": result.dispatcher,
             "n_workers": result.n_workers, **s.as_dict()}
            for s in result.stage_stats]
