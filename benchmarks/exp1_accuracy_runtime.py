"""Exp 1 (paper Fig. 5): global quality guarantees + runtime vs baselines.

For each dataset x query x target we plan with Stretto / Lotus(SupG) /
Pareto-Cascades, execute on the full corpus, and report the Target-Met
metric (achieved / target, >= 1 means met) and measured runtime.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import World, generate_queries, stage_stats_rows
from repro.core import PlannerConfig, evaluate_vs_gold, plan_query
from repro.core.baselines import plan_lotus, plan_pareto_cascades


def run(world: World, targets=(0.5, 0.7, 0.9), n_queries: int = 4,
        planner_cfg: PlannerConfig | None = None,
        sample_frac: float = 0.15) -> List[Dict]:
    planner_cfg = planner_cfg or PlannerConfig(steps=250, restarts=3)
    rows = []
    for ds_name, ds in world.datasets.items():
        for target in targets:
            queries = generate_queries(ds, n_queries, target,
                                       seed=hash(ds_name) % 1000)
            for qi, q in enumerate(queries):
                gold = world.gold(q, ds.items)
                for method, planner in (
                        ("stretto", lambda q: plan_query(
                            q, ds.items, world.backend, planner_cfg,
                            sample_frac=sample_frac)),
                        ("lotus", lambda q: plan_lotus(
                            q, ds.items, world.backend,
                            sample_frac=sample_frac)),
                        ("pareto", lambda q: plan_pareto_cascades(
                            q, ds.items, world.backend,
                            sample_frac=sample_frac))):
                    t0 = time.perf_counter()
                    plan = planner(q)
                    res = world.execute(plan, q, ds.items)
                    m = evaluate_vs_gold(res, gold, q.semantic_ops)
                    rows.append({
                        "dataset": ds_name, "query": qi, "target": target,
                        "method": method,
                        "recall": m["recall"], "precision": m["precision"],
                        "target_met_recall": m["recall"] / target,
                        "target_met_precision": m["precision"] / target,
                        "runtime_s": res.runtime_s,
                        "exec_wall_s": res.wall_s,
                        "gold_runtime_s": gold.runtime_s,
                        "gold_wall_s": gold.wall_s,
                        "plan_time_s": plan.planning_time_s,
                        # planned-vs-measured cost: does the planner's
                        # full-corpus estimate track measured reality?
                        "est_cost_s": plan.est_cost,
                        "cost_model_error": res.runtime_s
                        / max(plan.est_cost, 1e-9),
                        "feasible": plan.feasible,
                        "n_llm_tuples": res.n_llm_tuples,
                        "n_partitions": res.n_partitions,
                        "wall_s": time.perf_counter() - t0,
                        "stage_stats": stage_stats_rows(
                            f"exp1/{ds_name}/t{target}/q{qi}/{method}",
                            res, plan),
                    })
    return rows


def summarize(rows: List[Dict]) -> List[str]:
    out = ["exp1: Target-Met (5th pct / median) and runtime by method"]
    for method in ("stretto", "lotus", "pareto"):
        sub = [r for r in rows if r["method"] == method]
        if not sub:
            continue
        tmr = np.array([r["target_met_recall"] for r in sub])
        tmp_ = np.array([r["target_met_precision"] for r in sub])
        rt = np.array([r["runtime_s"] for r in sub])
        grt = np.array([r["gold_runtime_s"] for r in sub])
        frac_met = float(np.mean((tmr >= 1.0) & (tmp_ >= 1.0)))
        out.append(
            f"  {method:8s} met={frac_met:.2f} "
            f"tm_recall_p5={np.percentile(tmr, 5):.3f} "
            f"tm_prec_p5={np.percentile(tmp_, 5):.3f} "
            f"runtime_med={np.median(rt):.2f}s "
            f"speedup_vs_gold={np.median(grt / np.maximum(rt, 1e-9)):.2f}x")
    stre = [r for r in rows if r["method"] == "stretto"]
    lot = [r for r in rows if r["method"] == "lotus"]
    if stre and lot:
        sp = np.median(np.array([l["runtime_s"] for l in lot])
                       / np.maximum([s["runtime_s"] for s in stre], 1e-9))
        out.append(f"  stretto speedup vs lotus (median): {sp:.2f}x")
    return out
