"""Roofline table from the dry-run artifacts (results/dryrun_*)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load(out_dir: str = "results/dryrun_sp") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs: List[Dict]) -> List[str]:
    out = [f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'dominant':>10s} "
           f"{'useful':>6s} {'mem_GB':>7s}"]
    for r in recs:
        if not r.get("ok"):
            out.append(f"{r['arch']:24s} {r['shape']:12s} FAILED: "
                       f"{r.get('error', '')[:80]}")
            continue
        rf = r["roofline"]
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{rf['compute_s']:10.3e} {rf['memory_s']:10.3e} "
            f"{rf['collective_s']:10.3e} {rf['dominant']:>10s} "
            f"{r['useful_flops_ratio']:6.2f} "
            f"{r['per_device_bytes']['total'] / 1e9:7.1f}")
    return out


def csv_rows(recs: List[Dict]) -> List[Dict]:
    rows = []
    for r in recs:
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            "us_per_call": rf["bound_s"] * 1e6,
            "derived": (f"dom={rf['dominant']};useful="
                        f"{r['useful_flops_ratio']:.2f}"),
        })
    return rows


if __name__ == "__main__":
    for line in table(load()):
        print(line)
