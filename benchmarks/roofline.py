"""Roofline table from the dry-run artifacts (results/dryrun_*), plus
analytic decode-attention bounds for the kernels perf gate."""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import resolve_peaks  # noqa: E402

# The peak set the kernels perf gate measures against: the shared
# CI-CPU defaults from launch/mesh.py (the single source of hardware
# peak numbers), with the STRETTO_ROOFLINE_GFLOPS / _BW_GBS env
# overrides applied — a TPU run exports those to gate against real HBM
# bandwidth. PEAKS.name records which set priced the report.
PEAKS = resolve_peaks()


def decode_bound_s(B: int, S: int, KV: int, G: int, dk: int, dv: int,
                   n_q: int = 1, kv_bytes_per_elem: int = 4,
                   scale_bytes: int = 0) -> Dict[str, float]:
    """Analytic roofline bound (seconds per call) for (fused) flash-decode
    over a cached context.

    The kernel streams the whole K/V cache once per call regardless of
    how many query tokens ride along — that is exactly why the fused
    multi-token path wins over n_q sequential dispatches, and why int8
    (kv_bytes_per_elem=1 + per-token scale_bytes) halves-plus the memory
    time. FLOPs scale with n_q; bytes for q/out are negligible next to
    the cache stream but included.
    """
    kv_bytes = B * S * KV * (dk + dv) * kv_bytes_per_elem
    kv_bytes += B * S * KV * 2 * scale_bytes          # k_scale + v_scale
    qo_bytes = B * n_q * KV * G * (dk + dv) * 4
    flops = 2.0 * B * n_q * KV * G * S * (dk + dv)
    mem_s = (kv_bytes + qo_bytes) / PEAKS.hbm_bw
    compute_s = flops / PEAKS.flops
    return {"mem_s": mem_s, "compute_s": compute_s,
            "bound_s": max(mem_s, compute_s),
            "dominant": "memory" if mem_s >= compute_s else "compute",
            "peaks": PEAKS.name}


def load(out_dir: str = "results/dryrun_sp") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs: List[Dict]) -> List[str]:
    out = [f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'dominant':>10s} "
           f"{'useful':>6s} {'mem_GB':>7s}"]
    for r in recs:
        if not r.get("ok"):
            out.append(f"{r['arch']:24s} {r['shape']:12s} FAILED: "
                       f"{r.get('error', '')[:80]}")
            continue
        rf = r["roofline"]
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{rf['compute_s']:10.3e} {rf['memory_s']:10.3e} "
            f"{rf['collective_s']:10.3e} {rf['dominant']:>10s} "
            f"{r['useful_flops_ratio']:6.2f} "
            f"{r['per_device_bytes']['total'] / 1e9:7.1f}")
    return out


def csv_rows(recs: List[Dict]) -> List[Dict]:
    rows = []
    for r in recs:
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            "us_per_call": rf["bound_s"] * 1e6,
            "derived": (f"dom={rf['dominant']};useful="
                        f"{r['useful_flops_ratio']:.2f}"),
        })
    return rows


if __name__ == "__main__":
    for line in table(load()):
        print(line)
