"""Concurrent scheduler throughput smoke: sequential vs scheduled q/s.

Runs K copies of one synthetic cascade query (sleep-backed operators
whose flush cost mimics an accelerator-bound engine: fixed dispatch
overhead plus per-tuple time) two ways against one Session:

  sequential — K solo .execute() calls back to back
  scheduled  — K queries admitted concurrently through QueryScheduler,
               so their flushes coalesce into merged "engine" calls and
               the fixed dispatch overhead amortizes across queries

and records wall clock, queries/s, and the hub's merge counters
(n_flushes folded into n_calls, saved_calls). Decisions must stay
bit-identical between the two paths; with ``--gate`` it also exits
non-zero when scheduled throughput fails to beat sequential by
``--min-speedup`` — the existence proof that cross-query coalescing
pays, not just that it parses.

Artifact flow: the result dict merges into the newest BENCH_*.json in
--out under a separate "scheduler" key (the kernels gate's per-row
regression check only reads "rows", so these numbers never trip it), or
a standalone BENCH file when no kernels artifact exists.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import time
from typing import Dict, Sequence

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Session  # noqa: E402
from repro.core import PlannerConfig  # noqa: E402
from repro.runtime import OracleBackend  # noqa: E402
from repro.scheduler import QueryScheduler  # noqa: E402

N_ITEMS = 384
N_QUERIES = 6
# flush cost model: fixed dispatch overhead + per-tuple decode time.
# time.sleep releases the GIL; merging K flushes into one call pays the
# fixed overhead once instead of K times, which is the effect measured.
FIXED_S = 0.02
PER_TUPLE_S = 0.00005


class _Item:
    __slots__ = ("item_id",)

    def __init__(self, i: int):
        self.item_id = i


class _SleepFilter:
    uses_llm = True

    def __init__(self, name: str, gold: bool = False):
        self.name = name
        self.is_gold = gold

    def run_filter(self, items: Sequence[_Item], op) -> np.ndarray:
        time.sleep(FIXED_S + PER_TUPLE_S * len(items))
        idx = np.asarray([it.item_id for it in items], np.float64)
        return np.asarray(
            3.0 * np.sin(idx * 12.9898 + op.task_id * 78.233), np.float32)

    def run_map(self, items, op):
        raise NotImplementedError


def _registry(op):
    return [_SleepFilter("sleep-cheap"), _SleepFilter("sleep-gold",
                                                      gold=True)]


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "nogit"
    except Exception:
        return "nogit"


def run_bench(n_queries: int = N_QUERIES) -> Dict:
    sess = Session(backend=OracleBackend(_registry),
                   planner=PlannerConfig(steps=40, restarts=1,
                                         snapshots=2),
                   sample_frac=0.25)
    items = [_Item(i) for i in range(N_ITEMS)]
    frame = (sess.frame(items)
             .sem_filter("bench filter", task_id=1)
             .with_guarantees(recall=0.7, precision=0.7))
    frame.plan()                               # planning outside the clock

    t0 = time.monotonic()
    seq = [frame.execute() for _ in range(n_queries)]
    seq_wall = time.monotonic() - t0

    t0 = time.monotonic()
    with QueryScheduler(sess, max_concurrent=n_queries,
                        paused=True) as sched:
        handles = [sched.submit(frame) for _ in range(n_queries)]
        sched.resume()
        results = [h.result(timeout=300) for h in handles]
        stats = sched.stats()
    sched_wall = time.monotonic() - t0

    parity = all(np.array_equal(r.accepted, seq[0].accepted)
                 for r in results + seq)
    return {
        "name": "scheduler_concurrent_vs_sequential",
        "n_queries": n_queries,
        "n_items": N_ITEMS,
        "sequential_wall_s": seq_wall,
        "scheduled_wall_s": sched_wall,
        "sequential_qps": n_queries / max(seq_wall, 1e-9),
        "scheduled_qps": n_queries / max(sched_wall, 1e-9),
        "speedup": seq_wall / max(sched_wall, 1e-9),
        "parity": parity,
        "n_flushes": stats["n_flushes"],
        "n_calls": stats["n_calls"],
        "n_merged_calls": stats["n_merged_calls"],
        "saved_calls": stats["saved_calls"],
    }


def _emit_artifact(row: Dict, out_dir: str) -> str:
    """Merge under "scheduler" into the newest BENCH_*.json (the same
    artifact CI uploads), else write a standalone file."""
    os.makedirs(out_dir, exist_ok=True)
    paths = sorted(glob.glob(os.path.join(out_dir, "BENCH_*.json")))
    if paths:
        path = paths[-1]
        with open(path) as f:
            artifact = json.load(f)
        artifact["scheduler"] = row
        with open(path, "w") as f:
            json.dump(artifact, f, indent=1)
        return path
    ts = time.strftime("%Y%m%dT%H%M%SZ", time.gmtime())
    path = os.path.join(out_dir, f"BENCH_{ts}-{_git_sha()}.json")
    with open(path, "w") as f:
        json.dump({"schema": "stretto-scheduler-bench-v1", "ts": ts,
                   "sha": _git_sha(), "scheduler": row}, f, indent=1)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gate", action="store_true",
                    help="fail on a parity break or if scheduled "
                         "throughput does not beat sequential")
    ap.add_argument("--queries", type=int, default=N_QUERIES)
    ap.add_argument("--out", default="results/bench",
                    help="artifact directory (merges into the newest "
                         "BENCH_*.json there)")
    ap.add_argument("--min-speedup", type=float, default=1.3,
                    help="--gate: min q/s speedup of scheduled over "
                         "sequential")
    args = ap.parse_args(argv)

    row = run_bench(args.queries)
    print(f"[scheduler] {row['n_queries']} queries x {row['n_items']} "
          f"items: sequential {row['sequential_qps']:.2f} q/s, "
          f"scheduled {row['scheduled_qps']:.2f} q/s "
          f"({row['speedup']:.2f}x), {row['n_flushes']} flushes -> "
          f"{row['n_calls']} calls ({row['saved_calls']} saved), "
          f"parity={'ok' if row['parity'] else 'BROKEN'}")

    failed = False
    if not row["parity"]:
        print("[scheduler] FAIL: scheduled decisions diverged from "
              "sequential")
        failed = True
    if args.gate and row["speedup"] < args.min_speedup:
        print(f"[scheduler] FAIL: speedup {row['speedup']:.2f}x < "
              f"{args.min_speedup:.2f}x over sequential")
        failed = True
    if args.gate and row["saved_calls"] <= 0:
        print("[scheduler] FAIL: no flushes were coalesced")
        failed = True

    path = _emit_artifact(row, args.out)
    print(f"[scheduler] wrote {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
