"""Dispatcher scaling smoke: inline vs threads vs mesh wall clock.

Runs one synthetic two-filter cascade (sleep-backed operators whose
flush cost mimics an accelerator-bound engine: fixed dispatch overhead
plus per-tuple time, released-GIL sleep so parallel dispatchers really
overlap) under each dispatcher spec and records, per spec:

  wall_s             — elapsed execution (RuntimeResult.wall_s)
  runtime_s          — summed operator time (total work; ~constant
                       across dispatchers, which is exactly why wall_s,
                       not runtime_s, is the scaling metric)
  wall_us_per_tuple  — wall_s over the corpus
  speedup_vs_inline  — inline wall_s / this wall_s

and asserts decisions stay bit-identical to inline before reporting
anything. With ``--gate`` it exits non-zero on a parity break or when
the parallel dispatchers fail to beat inline wall clock.

Artifact flow: rows are merged into the newest BENCH_*.json in --out
under a separate "dispatch" key (the kernels gate's per-row regression
check only reads "rows", so dispatch smoke numbers never trip it), or
written to a standalone BENCH file when no kernels artifact exists.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.logical import Query, SemFilter  # noqa: E402
from repro.core.physical import (PhysicalPlan,  # noqa: E402
                                 PhysicalPlanStage)
from repro.runtime.executor import run_plan  # noqa: E402

SPECS = ("inline", "threads:4", "mesh:8")
N_ITEMS = 512
# flush cost model: fixed dispatch overhead + per-tuple decode time.
# time.sleep releases the GIL, so a thread/mesh scatter genuinely
# overlaps "engine" time the way jax device execution does.
FIXED_S = 0.004
PER_TUPLE_S = 0.0002


class _SleepOperator:
    """Deterministic planted-score operator with accelerator-like cost."""

    uses_llm = True
    is_gold = False

    def __init__(self, name: str, seed: int, gold: bool = False):
        self.name = name
        self.seed = seed
        self.is_gold = gold

    def run_filter(self, items: Sequence[int], op) -> np.ndarray:
        time.sleep(FIXED_S + PER_TUPLE_S * len(items))
        rng = np.random.default_rng(self.seed)
        table = rng.normal(0.0, 4.0, N_ITEMS).astype(np.float32)
        return table[np.asarray(items)]

    def run_map(self, items, op):
        raise NotImplementedError

    def cost_model(self) -> float:
        return PER_TUPLE_S


def _registry(op):
    return [_SleepOperator(f"cheap-{op.task_id}", seed=op.task_id),
            _SleepOperator(f"gold-{op.task_id}", seed=op.task_id,
                           gold=True)]


def _plan_and_query():
    ops = [SemFilter("bench filter a", task_id=0),
           SemFilter("bench filter b", task_id=1)]
    query = Query(nodes=ops, target_recall=0.9, target_precision=0.9)
    stages = []
    for li, _ in enumerate(ops):
        stages.append(PhysicalPlanStage(
            logical_idx=li, stage=0, op_name=f"cheap-{li}",
            thr_hi=2.0, thr_lo=-2.0, is_map=False, is_gold=False,
            cost=PER_TUPLE_S))
        stages.append(PhysicalPlanStage(
            logical_idx=li, stage=1, op_name=f"gold-{li}",
            thr_hi=0.0, thr_lo=0.0, is_map=False, is_gold=True,
            cost=4 * PER_TUPLE_S))
    return PhysicalPlan(stages=stages, relational=[], est_cost=0.0,
                        recall_bound=0.9, precision_bound=0.9,
                        feasible=True, planning_time_s=0.0), query


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "nogit"
    except Exception:
        return "nogit"


def run_specs(specs: Sequence[str] = SPECS) -> List[Dict]:
    plan, query = _plan_and_query()
    items = list(range(N_ITEMS))
    rows: List[Dict] = []
    baseline = None
    for spec in specs:
        # warmup: the executor's decision kernel jit-compiles once per
        # (device, flush shape), so a mesh:8 first run pays 8
        # compilations a steady-state scatter never sees — run the full
        # corpus once un-timed (same shard/flush shapes), time run two
        run_plan(plan, query, items, _registry,
                 partition_size=64, dispatcher=spec)
        r = run_plan(plan, query, items, _registry,
                     partition_size=64, dispatcher=spec)
        if baseline is None:
            baseline = r
        parity = bool(np.array_equal(r.accepted, baseline.accepted))
        rows.append({
            "name": f"dispatch_{spec.replace(':', '')}",
            "spec": spec,
            "wall_s": r.wall_s,
            "runtime_s": r.runtime_s,
            "wall_us_per_tuple": r.wall_s / N_ITEMS * 1e6,
            "speedup_vs_inline": baseline.wall_s / max(r.wall_s, 1e-9),
            "parity_vs_inline": parity,
            "n_workers": r.n_workers,
        })
    return rows


def _emit_artifact(rows: List[Dict], out_dir: str) -> str:
    """Merge rows under "dispatch" into the newest kernels BENCH_*.json
    (same artifact the CI uploads), else write a standalone file."""
    os.makedirs(out_dir, exist_ok=True)
    paths = sorted(glob.glob(os.path.join(out_dir, "BENCH_*.json")))
    if paths:
        path = paths[-1]
        with open(path) as f:
            artifact = json.load(f)
        artifact["dispatch"] = rows
        with open(path, "w") as f:
            json.dump(artifact, f, indent=1)
        return path
    ts = time.strftime("%Y%m%dT%H%M%SZ", time.gmtime())
    path = os.path.join(out_dir, f"BENCH_{ts}-{_git_sha()}.json")
    with open(path, "w") as f:
        json.dump({"schema": "stretto-dispatch-bench-v1", "ts": ts,
                   "sha": _git_sha(), "dispatch": rows}, f, indent=1)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gate", action="store_true",
                    help="fail on parity breaks or if parallel "
                         "dispatchers do not beat inline wall clock")
    ap.add_argument("--out", default="results/bench",
                    help="artifact directory (rows merge into the newest "
                         "kernels BENCH_*.json there)")
    ap.add_argument("--min-speedup", type=float, default=1.15,
                    help="--gate: min wall_s speedup over inline required "
                         "of every parallel dispatcher")
    args = ap.parse_args(argv)

    rows = run_specs()
    failed = False
    for r in rows:
        print(f"[dispatch] {r['spec']:>10s}: wall_s={r['wall_s']:.3f} "
              f"runtime_s={r['runtime_s']:.3f} "
              f"speedup={r['speedup_vs_inline']:.2f}x "
              f"parity={'ok' if r['parity_vs_inline'] else 'BROKEN'}")
        if not r["parity_vs_inline"]:
            print(f"[dispatch] FAIL {r['spec']}: decisions diverged "
                  f"from inline")
            failed = True
        if args.gate and r["spec"] != "inline" \
                and r["speedup_vs_inline"] < args.min_speedup:
            print(f"[dispatch] FAIL {r['spec']}: wall_s speedup "
                  f"{r['speedup_vs_inline']:.2f}x < "
                  f"{args.min_speedup:.2f}x over inline")
            failed = True

    path = _emit_artifact(rows, args.out)
    print(f"[dispatch] wrote {path}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
