"""Exp 3 (paper Fig. 8): global vs local vs independence-assuming
optimization — all gradient-based, same search space, different loss."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import World, generate_queries, stage_stats_rows
from repro.core import PlannerConfig, evaluate_vs_gold, plan_query
from repro.core.baselines import plan_stretto_independent, plan_stretto_local


def run(world: World, targets=(0.7, 0.9), n_queries: int = 3,
        planner_cfg: PlannerConfig | None = None,
        sample_frac: float = 0.15) -> List[Dict]:
    planner_cfg = planner_cfg or PlannerConfig(steps=250, restarts=3)
    rows = []
    for ds_name, ds in world.datasets.items():
        for target in targets:
            queries = generate_queries(ds, n_queries, target, seed=29)
            for qi, q in enumerate(queries):
                gold = world.gold(q, ds.items)
                for method, planner in (
                        ("global", lambda q: plan_query(
                            q, ds.items, world.backend, planner_cfg,
                            sample_frac=sample_frac)),
                        ("local", lambda q: plan_stretto_local(
                            q, ds.items, world.backend, planner_cfg,
                            sample_frac=sample_frac)),
                        ("independent", lambda q: plan_stretto_independent(
                            q, ds.items, world.backend, planner_cfg,
                            sample_frac=sample_frac))):
                    plan = planner(q)
                    res = world.execute(plan, q, ds.items)
                    m = evaluate_vs_gold(res, gold, q.semantic_ops)
                    rows.append({
                        "dataset": ds_name, "target": target, "query": qi,
                        "method": method, "recall": m["recall"],
                        "precision": m["precision"],
                        "met": (m["recall"] >= target
                                and m["precision"] >= target),
                        "runtime_s": res.runtime_s,
                        "exec_wall_s": res.wall_s,
                        "est_cost_s": plan.est_cost,
                        "stage_stats": stage_stats_rows(
                            f"exp3/{ds_name}/t{target}/q{qi}/{method}",
                            res, plan),
                    })
    return rows


def summarize(rows: List[Dict]) -> List[str]:
    out = ["exp3: global vs local vs independence ablation"]
    for method in ("global", "local", "independent"):
        sub = [r for r in rows if r["method"] == method]
        if not sub:
            continue
        out.append(
            f"  {method:12s} met={np.mean([r['met'] for r in sub]):.2f} "
            f"runtime_med={np.median([r['runtime_s'] for r in sub]):.2f}s")
    g = np.median([r["runtime_s"] for r in rows if r["method"] == "global"])
    l = np.median([r["runtime_s"] for r in rows if r["method"] == "local"])
    if g and l:
        out.append(f"  local/global runtime ratio: {l / max(g, 1e-9):.2f}x")
    return out
