"""End-to-end serving driver: batched semantic-operator requests over
precomputed KV-cache profiles (the paper's system kind).

A `Session` owns the offline phase (cache store, model registration,
profile building for the ladder); the request loop then drives the
serving engine directly — this example measures the raw serving layer
(throughput per compression profile), one level below the SemFrame query
API that `examples/quickstart.py` shows.

    PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro
from repro.cache.store import Profile
from repro.data.synthetic import (N_VALUES, TOK_NO, TOK_YES,
                                  filter_query_token, make_dataset,
                                  map_query_token, value_token)

RATIOS = (0.0, 0.5, 0.8)


def main():
    ds = make_dataset("serve", 300, seed=9)
    config = repro.SessionConfig(memory_budget_bytes=5e8,
                                 profile_ratios=RATIOS)
    with repro.Session(config) as sess:
        t0 = time.time()
        sess.prepare(ds.items)                   # offline phase
        engine = sess.engine
        print(f"offline: caches for {len(ds.items)} items x "
              f"{len(config.models)} models x {len(RATIOS)} ratios "
              f"in {time.time() - t0:.1f}s")
        for size in config.models:
            for r in RATIOS:
                mb = engine.store.storage_bytes(Profile(size, r)) / 1e6
                print(f"  profile {size}-r{r}: {mb:.1f} MB on disk")

        ids = [it.item_id for it in ds.items]
        labels = np.array([it.labels[1] for it in ds.items])
        print("\nserving 6 batched filter requests across the ladder:")
        for size in config.models:
            for r in RATIOS:
                t0 = time.time()
                lo = engine.run_filter(size, r, ids,
                                       [filter_query_token(1)],
                                       TOK_YES, TOK_NO)
                dt = time.time() - t0
                acc = ((lo > 0) == labels).mean()
                print(f"  {size}-r{r}: {len(ids) / dt:7.0f} items/s  "
                      f"acc={acc:.3f}")

        print("\nbatched map request (gold profile):")
        t0 = time.time()
        vals, conf = engine.run_map("lg", 0.0, ids, [map_query_token(2)],
                                    [value_token(v) for v in range(N_VALUES)])
        dt = time.time() - t0
        want = np.array([value_token(it.map_vals[2]) for it in ds.items])
        print(f"  {len(ids) / dt:.0f} items/s, value acc vs latent "
              f"{np.mean(vals == want):.3f}")


if __name__ == "__main__":
    main()
