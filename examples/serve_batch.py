"""End-to-end serving driver: batched semantic-operator requests over
precomputed KV-cache profiles (the paper's system kind).

Simulates a query workload against a corpus: builds the cache repository
once (offline), then serves a stream of filter/map requests at several
compression profiles, reporting throughput and the runtime-vs-quality
ladder the optimizer navigates.

    PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.cache.store import CacheStore, Profile
from repro.data.synthetic import (N_VALUES, TOK_NO, TOK_YES,
                                  filter_query_token, make_dataset,
                                  make_planted_params, map_query_token,
                                  planted_config, value_token)
from repro.serving.engine import ServingEngine


def main():
    ds = make_dataset("serve", 300, seed=9)
    engine = ServingEngine(CacheStore(tempfile.mkdtemp()),
                           memory_budget_bytes=5e8)
    t0 = time.time()
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        engine.register_model(size, cfg, make_planted_params(cfg, seed=1))
        engine.build_profiles(size, ds.items, ratios=[0.0, 0.5, 0.8])
    t_offline = time.time() - t0
    print(f"offline: caches for {len(ds.items)} items x 2 models x 3 "
          f"ratios in {t_offline:.1f}s")
    for size in ("sm", "lg"):
        for r in (0.0, 0.5, 0.8):
            mb = engine.store.storage_bytes(Profile(size, r)) / 1e6
            print(f"  profile {size}-r{r}: {mb:.1f} MB on disk")

    ids = [it.item_id for it in ds.items]
    labels = np.array([it.labels[1] for it in ds.items])
    print("\nserving 6 batched filter requests across the ladder:")
    for size in ("sm", "lg"):
        for r in (0.0, 0.5, 0.8):
            t0 = time.time()
            lo = engine.run_filter(size, r, ids, [filter_query_token(1)],
                                   TOK_YES, TOK_NO)
            dt = time.time() - t0
            acc = ((lo > 0) == labels).mean()
            print(f"  {size}-r{r}: {len(ids) / dt:7.0f} items/s  "
                  f"acc={acc:.3f}")

    print("\nbatched map request (gold profile):")
    t0 = time.time()
    vals, conf = engine.run_map("lg", 0.0, ids, [map_query_token(2)],
                                [value_token(v) for v in range(N_VALUES)])
    dt = time.time() - t0
    want = np.array([value_token(it.map_vals[2]) for it in ds.items])
    print(f"  {len(ids) / dt:.0f} items/s, value acc vs latent "
          f"{np.mean(vals == want):.3f}")


if __name__ == "__main__":
    main()
