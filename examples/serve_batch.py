"""Concurrent serving example: overlapping SemFrame queries through the
QueryScheduler, sharing one Session's engine pool.

A `Session` owns the offline phase (cache store, model registration,
profile building for the ladder); the scheduler then admits many
declarative queries at once — flushes from different queries that target
the same (engine, operator) coalesce into merged engine calls, tiered
tenants get weighted-fair shares and device-cache treatment, and each
result carries its own scheduler telemetry.

    PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro
from repro.cache.store import Profile
from repro.data.synthetic import make_dataset

RATIOS = (0.0, 0.5, 0.8)


def main():
    ds = make_dataset("serve", 200, seed=9)
    config = repro.SessionConfig(
        memory_budget_bytes=5e8,
        profile_ratios=RATIOS,
        sm_ratios=RATIOS, lg_ratios=RATIOS,
        tenants=(repro.TenantSpec("analytics", tier="premium"),
                 repro.TenantSpec("adhoc"),
                 repro.TenantSpec("backfill", tier="cold")))
    with repro.Session(config) as sess:
        t0 = time.time()
        sess.prepare(ds.items)                   # offline phase
        print(f"offline: caches for {len(ds.items)} items x "
              f"{len(config.models)} models x {len(RATIOS)} ratios "
              f"in {time.time() - t0:.1f}s")
        for size in config.models:
            for r in RATIOS:
                mb = sess.engine.store.storage_bytes(
                    Profile(size, r)) / 1e6
                print(f"  profile {size}-r{r}: {mb:.1f} MB on disk")

        # overlapping declarative queries: three tenants, six queries —
        # identical queries coalesce their engine flushes when admitted
        # together
        frames = [
            (sess.frame(ds.items)
             .sem_filter(f"filter task {t}", task_id=t)
             .with_guarantees(recall=0.7, precision=0.7))
            for t in (1, 1, 2, 2, 3, 1)
        ]
        tenants = ("analytics", "adhoc", "analytics",
                   "backfill", "adhoc", "analytics")
        print(f"\nsubmitting {len(frames)} overlapping queries:")
        t0 = time.time()
        with sess.scheduler(max_concurrent=len(frames)) as sched:
            sched.pause()                  # admit the batch all at once
            handles = [sched.submit(f, tenant=tn)
                       for f, tn in zip(frames, tenants)]
            sched.resume()
            for h in handles:
                res = h.result(timeout=600)
                s = res.sched
                print(f"  q{s.query_id} [{s.tenant}/{s.tier}]: "
                      f"{int(res.accepted.sum())}/{len(ds.items)} "
                      f"accepted, wait={s.queue_wait_s * 1e3:.0f}ms, "
                      f"shared_batches={s.shared_batches}")
            stats = sched.stats()
        wall = time.time() - t0
        print(f"\n{len(frames)} queries in {wall:.1f}s "
              f"({len(frames) / max(wall, 1e-9):.2f} q/s): "
              f"{stats['n_flushes']} flushes -> {stats['n_calls']} "
              f"engine calls ({stats['saved_calls']} saved by "
              f"cross-query coalescing)")
        for name, t in sorted(stats["tenants"].items()):
            if t["n_queries"]:
                print(f"  {name} ({t['tier']}, w={t['weight']}): "
                      f"{t['n_queries']} queries, vtime={t['vtime']:.0f}, "
                      f"warm_batches={t['warm_batches']}, "
                      f"evictions={t['evictions']}")


if __name__ == "__main__":
    main()
