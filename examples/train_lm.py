"""Train a reduced-config LM for a few hundred steps with the full
fault-tolerant loop: checkpointing, resume, straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py --arch granite-8b --steps 200
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import lm_batches
from repro.models import init_params
from repro.training.loop import LoopConfig, run_training
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"[train_lm] {cfg.name}: {cfg.n_params / 1e6:.2f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, lr=1e-3, remat=False))

    embeds_dim = cfg.d_model if cfg.frontend != "none" else None
    raw = lm_batches(cfg.vocab_size, args.batch, args.seq,
                     embeds_dim=embeds_dim)

    def stream():
        for b in raw:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    params, opt, rep = run_training(
        step_fn, params, opt, stream(),
        LoopConfig(total_steps=args.steps, ckpt_every=50,
                   ckpt_dir=ckpt_dir))
    print(f"[train_lm] loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f} "
          f"over {rep.steps_run} steps; ckpts={len(rep.ckpts)} "
          f"stragglers={rep.straggler_events}")
    assert rep.losses[-1] < rep.losses[0], "loss must decrease"
    print(f"[train_lm] checkpoints in {ckpt_dir} (resume by rerunning)")


if __name__ == "__main__":
    main()
