"""Quickstart: Stretto end to end through the declarative API.

One `Session` owns the whole engine lifecycle (cache store, planted
models, KV-cache profile building — the paper's offline phase, backend
and dispatcher resolution); a lazy `SemFrame` declares the query and its
end-to-end quality guarantees once. `explain()` shows the planned
cascade before anything runs, `execute()` runs it through the streaming
runtime, `metrics()` lazily compares against the gold reference, and
`stream()` delivers per-partition results incrementally.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro
from repro.data.synthetic import make_dataset


def main():
    ds = make_dataset("quickstart", 200, seed=3)
    config = repro.SessionConfig(
        profile_ratios=(0.0, 0.3, 0.5, 0.8),     # offline cache ladder
        sm_ratios=(0.8, 0.5, 0.0),               # cascade candidates
        lg_ratios=(0.8, 0.5, 0.3),
        planner=repro.PlannerConfig(steps=200, restarts=3),
        sample_frac=0.25,
        partition_size=64,                       # streaming execution
    )
    with repro.Session(config) as sess:
        # --- a semantic query with global quality targets, declared once
        frame = (sess.frame(ds)
                 .sem_filter("mentions topic 1", task_id=1)
                 .sem_map("extract field 2", task_id=2)
                 .with_guarantees(recall=0.75, precision=0.75))

        # --- EXPLAIN: the planned cascade, before anything executes ----
        print(frame.explain())

        # --- execute through the streaming runtime ---------------------
        # (the first execution pays jit compilation for every selected
        # operator/batch shape; re-running warm measures steady state,
        # which is what the planner's profiled costs model)
        frame.execute()
        res = frame.execute()
        m = res.metrics()                        # lazy gold comparison
        print(f"quality vs gold: precision={m['precision']:.3f} "
              f"recall={m['recall']:.3f} (targets 0.75)")
        print(f"runtime: {res.runtime_s:.2f}s operator time, "
              f"{res.wall_s:.2f}s elapsed "
              f"-> speedup {res.speedup_vs_gold():.2f}x vs gold "
              f"({res.n_partitions} partitions)")

        # --- EXPLAIN ANALYZE: planned vs measured, side by side --------
        print(res.explain_analyze())

        # --- streaming: consume partitions as they settle --------------
        print("streaming the same query, 50 tuples per partition:")
        stream = frame.stream(partition_size=50)
        for part in stream:
            print(f"  partition {part.index} [{part.lo}:{part.hi}) "
                  f"-> {int(part.accepted.sum())} accepted "
                  f"({stream.progress:.0%} settled, "
                  f"{sum(s.n_llm_calls for s in stream.stage_stats)} "
                  f"LLM calls so far)")


if __name__ == "__main__":
    main()
