"""Quickstart: Stretto end to end through the declarative API.

One `Session` owns the whole engine lifecycle (cache store, planted
models, KV-cache profile building — the paper's offline phase, backend
and dispatcher resolution); a lazy `SemFrame` declares the query and its
end-to-end quality guarantees once. `explain()` shows the planned
cascade before anything runs, `execute()` runs it through the streaming
runtime, `metrics()` lazily compares against the gold reference, and
`stream()` delivers per-partition results incrementally.

    PYTHONPATH=src python examples/quickstart.py           # one engine
    PYTHONPATH=src python examples/quickstart.py --pool    # two-tier pool
    PYTHONPATH=src python examples/quickstart.py --remote  # wire-served tier

``--pool`` declares a heterogeneous engine pool instead of the flat
single-engine config: a "fast" tier serving the small model's compression
ladder and an "accurate" tier serving the large model (and the gold
reference). The planner places every cascade stage on one engine —
EXPLAIN grows an `engine` column, and EXPLAIN ANALYZE reports measured
per-engine cost and KV bytes that sum exactly to the session totals.

``--remote`` serves the fast tier from a real worker subprocess on
127.0.0.1 (`EngineSpec(address=...)`): the same plan decides
bit-identically to the all-local pool, EXPLAIN ANALYZE grows a
``remote:`` wire-telemetry footer — then the worker is SIGKILLed
mid-stream and the run completes on the gold engine via the
``on_unavailable="fallback"`` degradation policy.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro
from repro.data.synthetic import make_dataset


def single_engine_config() -> "repro.SessionConfig":
    return repro.SessionConfig(
        profile_ratios=(0.0, 0.3, 0.5, 0.8),     # offline cache ladder
        sm_ratios=(0.8, 0.5, 0.0),               # cascade candidates
        lg_ratios=(0.8, 0.5, 0.3),
        planner=repro.PlannerConfig(steps=200, restarts=3),
        sample_frac=0.25,
        partition_size=64,                       # streaming execution
    )


def pool_config() -> "repro.SessionConfig":
    """A two-tier engine pool: cheap sm tier + accurate lg tier (which
    also owns the gold reference operator)."""
    return repro.SessionConfig(
        engines=(
            repro.EngineSpec("fast", models=("sm",),
                             sm_ratios=(0.8, 0.5, 0.0), lg_ratios=()),
            repro.EngineSpec("accurate", models=("lg",),
                             sm_ratios=(), lg_ratios=(0.5, 0.3),
                             include_cheap=False),
        ),
        gold_engine="accurate",
        planner=repro.PlannerConfig(steps=200, restarts=3),
        sample_frac=0.25,
        partition_size=64,
    )


def run_remote(ds) -> None:
    """The --pool topology with the fast tier behind a real subprocess
    worker: bit-parity with all-local, the EXPLAIN ANALYZE wire footer,
    and graceful degradation when the worker is SIGKILLed mid-run."""
    import signal

    import numpy as np

    from repro.remote.client import remote_members
    from repro.remote.testing import spawn_worker

    local_cfg = pool_config()
    print("launching loopback worker (builds its ladder on first sync)...")
    proc, addr = spawn_worker(name="fast", models=("sm",),
                              sm_ratios=(0.8, 0.5, 0.0), lg_ratios=())
    remote_cfg = repro.SessionConfig(
        engines=(repro.EngineSpec("fast", address=addr),
                 local_cfg.engines[1]),         # same accurate/gold tier
        gold_engine="accurate",
        planner=repro.PlannerConfig(steps=200, restarts=3),
        sample_frac=0.25, partition_size=64)
    try:
        with repro.Session(local_cfg) as ls, \
                repro.Session(remote_cfg) as rs:
            frame = (ls.frame(ds)
                     .sem_filter("mentions topic 1", task_id=1)
                     .sem_map("extract field 2", task_id=2)
                     .with_guarantees(recall=0.75, precision=0.75))
            query = frame.to_query()
            plan = ls.plan(query, ds.items)

            # --- parity: one plan, two pools, identical bits -----------
            lr = ls.run(plan, query, ds.items, dispatcher="inline")
            rr = rs.run(plan, query, ds.items, dispatcher="inline")
            same = (np.array_equal(rr.accepted, lr.accepted)
                    and all(np.array_equal(rr.map_values[li],
                                           lr.map_values[li])
                            for li in lr.map_values))
            print(f"decisions bit-identical to all-local: {same}")
            assert same, "remote parity broke"
            w = rr.remote
            print(f"wire: {w['calls']} calls, {w['wire_kb']:.1f} KiB, "
                  f"rtt p50 {w['rtt_ms_p50']:.2f}ms "
                  f"p95 {w['rtt_ms_p95']:.2f}ms")

            # --- EXPLAIN ANALYZE grows the remote footer ---------------
            res = (rs.frame(ds)
                   .sem_filter("mentions topic 1", task_id=1)
                   .sem_map("extract field 2", task_id=2)
                   .with_guarantees(recall=0.75, precision=0.75)
                   .execute())
            print(res.explain_analyze())

            # --- SIGKILL mid-stream: degrade onto the gold engine ------
            member = remote_members(rs.backend)[0]
            gen = rs.iter_run(plan, query, ds.items, partition_size=50,
                              coalesce=1, dispatcher="inline")
            next(gen)                        # first partition on the wire
            os.kill(proc.pid, signal.SIGKILL)
            proc.wait()
            print("worker SIGKILLed mid-stream; draining on the gold "
                  "fallback...")
            try:
                while True:
                    next(gen)
            except StopIteration as stop:
                result = stop.value
            snap = member.snapshot()
            print(f"degraded run completed: "
                  f"{int(result.accepted.sum())} accepted, "
                  f"fallbacks={snap['fallbacks']}, "
                  f"retries={snap['retries']}")
            assert snap["fallbacks"] > 0, "no flush fell back to gold"
    finally:
        proc.kill()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", action="store_true",
                    help="declare a two-tier heterogeneous engine pool")
    ap.add_argument("--remote", action="store_true",
                    help="serve the fast tier from a loopback worker "
                         "subprocess, then SIGKILL it mid-run")
    args = ap.parse_args()
    ds = make_dataset("quickstart", 200, seed=3)
    if args.remote:
        run_remote(ds)
        return
    config = pool_config() if args.pool else single_engine_config()
    with repro.Session(config) as sess:
        # --- a semantic query with global quality targets, declared once
        frame = (sess.frame(ds)
                 .sem_filter("mentions topic 1", task_id=1)
                 .sem_map("extract field 2", task_id=2)
                 .with_guarantees(recall=0.75, precision=0.75))

        # --- EXPLAIN: the planned cascade, before anything executes ----
        print(frame.explain())

        # --- execute through the streaming runtime ---------------------
        # (the first execution pays jit compilation for every selected
        # operator/batch shape; re-running warm measures steady state,
        # which is what the planner's profiled costs model)
        frame.execute()
        res = frame.execute()
        m = res.metrics()                        # lazy gold comparison
        print(f"quality vs gold: precision={m['precision']:.3f} "
              f"recall={m['recall']:.3f} (targets 0.75)")
        print(f"runtime: {res.runtime_s:.2f}s operator time, "
              f"{res.wall_s:.2f}s elapsed "
              f"-> speedup {res.speedup_vs_gold():.2f}x vs gold "
              f"({res.n_partitions} partitions)")

        # --- EXPLAIN ANALYZE: planned vs measured, side by side --------
        print(res.explain_analyze())

        if args.pool:
            # per-engine measured totals partition the run exactly
            for eng, d in sorted(res.engine_totals().items()):
                print(f"engine {eng}: {d['n_tuples']} tuples, "
                      f"{d['n_llm_calls']} LLM calls, "
                      f"{d['kv_bytes'] / 1e6:.1f} MB KV loaded")

        # --- streaming: consume partitions as they settle --------------
        print("streaming the same query, 50 tuples per partition:")
        stream = frame.stream(partition_size=50)
        for part in stream:
            print(f"  partition {part.index} [{part.lo}:{part.hi}) "
                  f"-> {int(part.accepted.sum())} accepted "
                  f"({stream.progress:.0%} settled, "
                  f"{sum(s.n_llm_calls for s in stream.stage_stats)} "
                  f"LLM calls so far)")


if __name__ == "__main__":
    main()
