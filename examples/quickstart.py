"""Quickstart: Stretto end to end in ~60 lines.

Builds a small planted corpus, precomputes compressed KV-cache profiles
(the paper's offline phase), plans a 2-operator semantic query under global
quality targets with the gradient optimizer, executes the cascade plan
through the streaming runtime (KV-cache backend, partitioned corpus,
per-stage telemetry), and compares quality + runtime against the gold
reference backend.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cache.store import CacheStore
from repro.core import (PlannerConfig, Query, SemFilter, SemMap,
                        evaluate_vs_gold, plan_query)
from repro.data.synthetic import (make_dataset, make_planted_params,
                                  planted_config)
from repro.runtime import (KVCacheBackend, ReferenceBackend, gold_plan_for,
                           run_plan)
from repro.serving.engine import ServingEngine


def main():
    # --- corpus + engine with KV-cache profiles (offline phase) ----------
    ds = make_dataset("quickstart", 200, seed=3)
    engine = ServingEngine(CacheStore(tempfile.mkdtemp()))
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        engine.register_model(size, cfg, make_planted_params(cfg, seed=1))
        engine.build_profiles(size, ds.items, ratios=[0.0, 0.3, 0.5, 0.8])
    backend = KVCacheBackend(engine, sm_ratios=(0.8, 0.5, 0.0),
                             lg_ratios=(0.8, 0.5, 0.3))
    reference = ReferenceBackend(engine)
    print("offline phase done: cache ladder built for 2 models x 4 ratios")

    # --- a semantic query with global quality targets ---------------------
    q = Query([SemFilter("mentions topic 1", 1),
               SemMap("extract field 2", 2)],
              target_recall=0.75, target_precision=0.75)

    # gold reference: the same plan shape, resolved by the gold-only backend
    gold = run_plan(gold_plan_for(q, reference), q, ds.items, reference)

    # --- Stretto: plan + execute through the streaming runtime ------------
    plan = plan_query(q, ds.items, backend,
                      PlannerConfig(steps=200, restarts=3),
                      sample_frac=0.25)
    print(plan.describe())
    res = run_plan(plan, q, ds.items, backend, partition_size=64)
    m = evaluate_vs_gold(res, gold, q.semantic_ops)
    print(f"quality vs gold: precision={m['precision']:.3f} "
          f"recall={m['recall']:.3f} (targets {q.target_precision})")
    print(f"runtime: {res.runtime_s:.2f}s vs gold {gold.runtime_s:.2f}s "
          f"-> speedup {gold.runtime_s / max(res.runtime_s, 1e-9):.2f}x "
          f"({res.n_partitions} partitions)")
    print("per-stage telemetry:")
    for st in res.stage_stats:
        print(f"  {st.op_name:12s} tuples={st.n_tuples:4d} "
              f"batches={st.n_batches} wall={st.wall_s * 1e3:7.1f}ms "
              f"kv={st.kv_bytes / 1e6:6.1f}MB llm_calls={st.n_llm_calls}")


if __name__ == "__main__":
    main()
