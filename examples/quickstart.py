"""Quickstart: Stretto end to end in ~60 lines.

Builds a small planted corpus, precomputes compressed KV-cache profiles
(the paper's offline phase), plans a 2-operator semantic query under global
quality targets with the gradient optimizer, executes the cascade plan, and
compares quality + runtime against the gold plan.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.cache.store import CacheStore
from repro.core import (PlannerConfig, Query, SemFilter, SemMap,
                        evaluate_vs_gold, execute_plan, plan_query)
from repro.core.physical import PhysicalPlan, PhysicalPlanStage
from repro.data.synthetic import (make_dataset, make_planted_params,
                                  planted_config)
from repro.serving.engine import ServingEngine
from repro.serving.operators import make_registry


def main():
    # --- corpus + engine with KV-cache profiles (offline phase) ----------
    ds = make_dataset("quickstart", 200, seed=3)
    engine = ServingEngine(CacheStore(tempfile.mkdtemp()))
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        engine.register_model(size, cfg, make_planted_params(cfg, seed=1))
        engine.build_profiles(size, ds.items, ratios=[0.0, 0.3, 0.5, 0.8])
    registry = make_registry(engine)
    print("offline phase done: cache ladder built for 2 models x 4 ratios")

    # --- a semantic query with global quality targets ---------------------
    q = Query([SemFilter("mentions topic 1", 1),
               SemMap("extract field 2", 2)],
              target_recall=0.75, target_precision=0.75)

    # gold reference (largest model, no compression, on everything)
    gold_stages = []
    for li, op in enumerate(q.semantic_ops):
        ops = registry(op)
        gold_stages.append(PhysicalPlanStage(
            li, 0, ops[-1].name, 0.0, 0.0,
            op.__class__.__name__ == "SemMap", True, 1.0))
    gold_plan = PhysicalPlan(gold_stages, [], 0.0, 1.0, 1.0, True)
    gold = execute_plan(gold_plan, q, ds.items, registry)

    # --- Stretto: plan + execute ------------------------------------------
    plan = plan_query(q, ds.items, registry,
                      PlannerConfig(steps=200, restarts=3),
                      sample_frac=0.25)
    print(plan.describe())
    res = execute_plan(plan, q, ds.items, registry)
    m = evaluate_vs_gold(res, gold, q.semantic_ops)
    print(f"quality vs gold: precision={m['precision']:.3f} "
          f"recall={m['recall']:.3f} (targets {q.target_precision})")
    print(f"runtime: {res.runtime_s:.2f}s vs gold {gold.runtime_s:.2f}s "
          f"-> speedup {gold.runtime_s / max(res.runtime_s, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
