"""Remote engine members: wire protocol, config validation, loopback
parity, and degradation policies.

Four invariant families:

Protocol — frames round-trip (json floor, zlib past the compression
threshold, msgpack when both peers import it), version/magic mismatches
raise ProtocolError, clean EOF at a frame boundary is distinguishable
from a mid-frame truncation, semantic operators survive the wire with
their exact subclass, and the corpus hash is order-independent.

Validation — a remote EngineSpec is checked at construction: malformed
addresses, address + device / dispatcher affinity, unknown degradation
policies, and a remote gold engine all fail with a clear ValueError
before any socket is opened.

Parity — the load-bearing guarantee: a pool with one member served over
a 127.0.0.1 worker produces bit-identical decisions / map values /
per-engine StageStats to the all-local pool, for the SAME plan, across
inline and threads dispatchers, solo and through the concurrent
scheduler (where cross-query coalescing must also reduce wire calls).

Robustness — SIGKILL a real worker subprocess mid-run: under
on_unavailable="fallback" the run completes on the gold engine with
fallback counters > 0; under "fail" it raises RemoteEngineError without
poisoning the session (gold execution still works afterwards).
"""
import os
import signal
import socket
import threading

import numpy as np
import pytest

from repro.api import EngineSpec, Session, SessionConfig
from repro.core import PlannerConfig
from repro.core.logical import SemAgg, SemFilter, SemJoin, SemMap, SemTopK
from repro.data.synthetic import make_dataset
from repro.remote import (RemoteEngineError, RemoteEngineMember,
                          RemoteWorker, start_server)
from repro.remote import protocol as proto
from repro.remote.client import remote_members, remote_run_info
from repro.remote.testing import spawn_worker
from repro.runtime import gold_plan_for
from repro.scheduler import QueryScheduler

FAST = PlannerConfig(steps=120, restarts=2, snapshots=2)

# the worker's identity — the local "fast" spec and every worker in this
# module use exactly these values, which is what makes scores bit-equal
FAST_SPEC = dict(models=("sm",), sm_ratios=(0.8, 0.5), lg_ratios=())


# ---------------------------------------------------------------------------
# protocol units (no worker)
# ---------------------------------------------------------------------------

def test_frame_roundtrip_json_and_zlib():
    small = {"verb": "health", "n": 3, "xs": [1.5, -2.25]}
    frame = proto.encode_frame(small)
    msg, enc = proto.decode_frame(frame[:proto.HEADER.size],
                                  frame[proto.HEADER.size:])
    assert msg == small and enc == "json"
    # a frame past COMPRESS_MIN gets zlib'd and still round-trips
    big = {"verb": "sync", "items": [[i, list(range(40))]
                                     for i in range(300)]}
    frame = proto.encode_frame(big)
    flags = proto.HEADER.unpack(frame[:proto.HEADER.size])[2]
    assert flags & proto.FLAG_ZLIB
    assert len(frame) < len(str(big))
    msg, _ = proto.decode_frame(frame[:proto.HEADER.size],
                                frame[proto.HEADER.size:])
    assert msg == big


@pytest.mark.skipif(not proto.HAVE_MSGPACK, reason="msgpack not installed")
def test_frame_roundtrip_msgpack():
    obj = {"verb": "score_filter", "item_ids": list(range(64)),
           "scores": [0.125, -3.5]}
    frame = proto.encode_frame(obj, encoding="msgpack")
    flags = proto.HEADER.unpack(frame[:proto.HEADER.size])[2]
    assert flags & proto.FLAG_MSGPACK
    msg, enc = proto.decode_frame(frame[:proto.HEADER.size],
                                  frame[proto.HEADER.size:])
    assert msg == obj and enc == "msgpack"


def test_frame_rejects_bad_version_and_magic():
    payload = b"{}"
    bad_ver = proto.HEADER.pack(proto.MAGIC, proto.PROTOCOL_VERSION + 1,
                                0, len(payload))
    with pytest.raises(proto.ProtocolError, match="version"):
        proto.decode_frame(bad_ver, payload)
    bad_magic = proto.HEADER.pack(b"XX", proto.PROTOCOL_VERSION,
                                  0, len(payload))
    with pytest.raises(proto.ProtocolError, match="magic"):
        proto.decode_frame(bad_magic, payload)
    with pytest.raises(proto.ProtocolError, match="encoding"):
        proto.encode_frame({}, encoding="bson")


def test_send_recv_eof_vs_truncation():
    a, b = socket.socketpair()
    try:
        proto.send_msg(a, {"verb": "health"})
        msg, enc, nbytes = proto.recv_msg(b)
        assert msg == {"verb": "health"} and enc == "json" and nbytes > 0
        # clean EOF at a frame boundary: (None, "", 0), no exception
        a.close()
        assert proto.recv_msg(b) == (None, "", 0)
    finally:
        b.close()
    # a connection dropped MID-frame must raise, not read garbage
    a, b = socket.socketpair()
    try:
        frame = proto.encode_frame({"verb": "stats"})
        a.sendall(frame[:proto.HEADER.size + 1])
        a.close()
        with pytest.raises(proto.ProtocolError, match="mid-frame"):
            proto.recv_msg(b)
    finally:
        b.close()


def test_sem_codec_roundtrips_exact_subclass():
    ops = (SemFilter("f", 1), SemFilter("f", 1, modality="image"),
           SemMap("m", 2, out_column="v"),
           SemTopK("t", 3, k=5),
           SemAgg("a", 4, group_by="g", how="mode"),
           SemJoin("j", 5, on="col"))
    for op in ops:
        back = proto.sem_from_wire(proto.sem_to_wire(op))
        assert type(back) is type(op)
        assert back == op
    with pytest.raises(proto.ProtocolError):
        proto.sem_to_wire(object())
    with pytest.raises(proto.ProtocolError):
        proto.sem_from_wire({"kind": "reduce"})


def test_corpus_hash_order_independent_content_sensitive():
    pairs = [(1, (3, 4, 5)), (2, (6, 7)), (3, ())]
    h = proto.corpus_hash(pairs)
    assert proto.corpus_hash(reversed(pairs)) == h
    assert proto.corpus_hash([(1, (3, 4, 9)), (2, (6, 7)), (3, ())]) != h
    assert proto.corpus_hash([(1, (3, 4, 5)), (2, (6, 7))]) != h
    with pytest.raises(proto.ProtocolError, match="item_id"):
        proto.items_to_wire([{"not": "an item"}])


# ---------------------------------------------------------------------------
# config validation (satellite: remote specs are checked at construction)
# ---------------------------------------------------------------------------

def test_engine_spec_remote_validation():
    ok = EngineSpec("r", address="127.0.0.1:9410")
    assert ok.on_unavailable == "fallback"
    with pytest.raises(ValueError, match="host:port"):
        EngineSpec("r", address="no-port-here")
    with pytest.raises(ValueError, match="device"):
        EngineSpec("r", address="127.0.0.1:9410", device=0)
    with pytest.raises(ValueError, match="dispatcher"):
        EngineSpec("r", address="127.0.0.1:9410", dispatcher=2)
    with pytest.raises(ValueError, match="on_unavailable"):
        EngineSpec("r", address="127.0.0.1:9410", on_unavailable="retry")
    with pytest.raises(ValueError, match="timeout_s"):
        EngineSpec("r", address="127.0.0.1:9410", timeout_s=0.0)
    with pytest.raises(ValueError, match="remote_retries"):
        EngineSpec("r", address="127.0.0.1:9410", remote_retries=-1)


def test_remote_gold_engine_rejected():
    # a lone spec IS the gold engine — it cannot be remote
    with pytest.raises(ValueError, match="gold"):
        SessionConfig(engines=(EngineSpec("r", address="127.0.0.1:9410"),))
    with pytest.raises(ValueError, match="gold"):
        SessionConfig(
            engines=(EngineSpec("local"),
                     EngineSpec("r", address="127.0.0.1:9410")),
            gold_engine="r")
    # remote non-gold next to a local gold is the supported shape
    cfg = SessionConfig(
        engines=(EngineSpec("r", address="127.0.0.1:9410"),
                 EngineSpec("local")),
        gold_engine="local")
    assert cfg.resolved_engines()[0].address is not None


def test_member_constructor_validation():
    with pytest.raises(ValueError, match="host:port"):
        RemoteEngineMember("x", "nohost")
    with pytest.raises(ValueError, match="on_unavailable"):
        RemoteEngineMember("x", "127.0.0.1:9410", on_unavailable="punt")


# ---------------------------------------------------------------------------
# warm/evict no-op safety (satellite: never-built rungs must not crash)
# ---------------------------------------------------------------------------

def test_warm_evict_noop_on_unbuilt_rungs(tmp_path):
    worker = RemoteWorker("noop", cache_dir=str(tmp_path), **FAST_SPEC)
    eng = worker.engine
    # cold engine, nothing built: warm/evict are no-ops, not crashes
    assert eng.warm("sm", 0.5, [1, 2, 3]) == 0
    assert eng.warm("sm", 0.5, []) == 0
    assert eng.warm("unknown-model", 0.5, [1]) == 0
    assert eng.evict() == 0
    assert eng.evict("sm", 0.5) == 0
    # the wire verbs take the same path (item_ids None -> synced corpus,
    # which is empty before the first sync)
    assert worker.handle({"verb": "warm", "model": "sm", "ratio": 0.5}) \
        == {"ok": True, "batches": 0}
    assert worker.handle({"verb": "evict", "model": None, "ratio": None}) \
        == {"ok": True, "dropped": 0}
    # partially built rung: ids outside the built subset are skipped,
    # not KeyError'd; a never-built ratio stays a no-op
    items = make_dataset("warm", 12, seed=1).items
    eng.build_profiles("sm", items[:6], ratios=[0.5], prefill_batch=4)
    all_ids = [it.item_id for it in items]
    assert eng.warm("sm", 0.5, all_ids) >= 0
    assert eng.warm("sm", 0.8, all_ids) == 0
    assert eng.evict("sm", 0.8) == 0


# ---------------------------------------------------------------------------
# loopback world: one in-process worker + the local twin of its spec
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world(tmp_path_factory):
    ds = make_dataset("remote", 90, seed=7)
    worker = RemoteWorker(
        "fast", cache_dir=str(tmp_path_factory.mktemp("worker")),
        **FAST_SPEC)
    server, _, addr = start_server(worker)
    yield ds, worker, addr
    server.shutdown()
    server.server_close()


def _accurate(tmp_path_factory, tag):
    return EngineSpec("accurate", models=("lg",),
                      sm_ratios=(), lg_ratios=(0.5,), include_cheap=False,
                      cache_dir=str(tmp_path_factory.mktemp(tag)))


def _session(tmp_path_factory, fast_spec, tag):
    return Session(SessionConfig(
        engines=(fast_spec, _accurate(tmp_path_factory, tag)),
        gold_engine="accurate",
        planner=FAST, sample_frac=0.35, partition_size=40))


@pytest.fixture(scope="module")
def sessions(world, tmp_path_factory):
    ds, _, addr = world
    local = _session(
        tmp_path_factory,
        EngineSpec("fast", cache_dir=str(tmp_path_factory.mktemp("fl")),
                   **FAST_SPEC),
        "al")
    remote = _session(tmp_path_factory,
                      EngineSpec("fast", address=addr), "ar")
    local.prepare(ds.items)
    remote.prepare(ds.items)
    yield ds, local, remote
    local.close()
    remote.close()


def _frame(sess, ds):
    return (sess.frame(ds.items)
            .sem_filter("f1", 1)
            .sem_map("extract v2", 2)
            .with_guarantees(recall=0.7, precision=0.7))


def test_session_builds_no_local_engine_for_remote_spec(sessions):
    ds, local, remote = sessions
    assert set(local.engines) == {"fast", "accurate"}
    assert set(remote.engines) == {"accurate"}          # no local slot
    members = remote_members(remote.backend)
    assert [m.engine_name for m in members] == ["fast"]
    with pytest.raises(ValueError, match="remote"):
        remote.backend_for(engine="fast")
    h = members[0].health()
    assert h["ok"] and h["n_items"] == len(ds.items)
    assert h["corpus_hash"] == members[0]._synced_hash


def test_catalog_matches_local_candidates(sessions):
    """The worker's catalog must reproduce the local engine's ladder —
    names, gold flag, and cost numbers — or pool ordering (and therefore
    planning) would diverge between the two sessions."""
    ds, local, remote = sessions
    for op in (SemFilter("f1", 1), SemMap("extract v2", 2)):
        lc = local.backend.candidates(op)
        rc = remote.backend.candidates(op)
        assert [c.name for c in rc] == [c.name for c in lc]
        assert [c.is_gold for c in rc] == [c.is_gold for c in lc]
        assert [c.cost_model() for c in rc] == [c.cost_model() for c in lc]
        assert [getattr(c, "engine_name", None) for c in rc] \
            == [getattr(c, "engine_name", None) for c in lc]


def test_every_fast_operator_scores_bit_identically(sessions):
    ds, local, remote = sessions
    op = SemFilter("f1", 1)
    batch = ds.items[:32]
    for cand in local.backend.candidates(op):
        ls = local.backend.score_filter(op, cand.name, batch)
        rs = remote.backend.score_filter(op, cand.name, batch)
        np.testing.assert_array_equal(rs, ls)
        assert rs.dtype == np.float32
    mop = SemMap("extract v2", 2)
    for cand in local.backend.candidates(mop):
        lv, lcf = local.backend.run_map(mop, cand.name, batch)
        rv, rcf = remote.backend.run_map(mop, cand.name, batch)
        np.testing.assert_array_equal(rv, lv)
        np.testing.assert_array_equal(rcf, lcf)


@pytest.mark.parametrize("dispatcher", ["inline", "threads:2"])
def test_same_plan_parity_local_vs_remote(sessions, dispatcher):
    """THE parity pin: one plan, two pools (one wired through the
    loopback worker) — decisions, map values, and per-engine StageStats
    must be bit-identical, and the remote run's wire telemetry must
    show real calls with zero fallbacks."""
    ds, local, remote = sessions
    query = _frame(local, ds).to_query()
    plan = local.plan(query, ds.items)
    engines = {st.engine for st in plan.stages}
    assert engines == {"fast", "accurate"}   # else the test is vacuous
    lr = local.run(plan, query, ds.items, dispatcher=dispatcher)
    rr = remote.run(plan, query, ds.items, dispatcher=dispatcher)
    np.testing.assert_array_equal(rr.accepted, lr.accepted)
    assert set(rr.map_values) == set(lr.map_values)
    for li in lr.map_values:
        np.testing.assert_array_equal(rr.map_values[li], lr.map_values[li])
    key = lambda sg: (sg.logical_idx, sg.stage, sg.op_name)
    mine = {key(sg): sg for sg in rr.stage_stats}
    ref = {key(sg): sg for sg in lr.stage_stats}
    assert set(mine) == set(ref)
    for k, sg in mine.items():
        assert sg.engine == ref[k].engine
        assert sg.n_tuples == ref[k].n_tuples
        assert sg.n_llm_calls == ref[k].n_llm_calls
        assert sg.n_batches == ref[k].n_batches
        # per-engine KV telemetry survives the wire exactly
        assert sg.kv_bytes == ref[k].kv_bytes
    assert lr.remote is None                 # all-local run: no footer
    assert rr.remote is not None
    assert rr.remote["calls"] > 0
    assert rr.remote["fallbacks"] == 0 and rr.remote["errors"] == 0
    assert set(rr.remote["engines"]) == {"fast"}
    assert rr.remote["rtt_ms_p95"] >= rr.remote["rtt_ms_p50"] >= 0.0


def test_remote_plans_identically_and_explains_wire_footer(sessions):
    """Planning THROUGH the remote catalog (costs from the wire,
    profiling scores over the wire) lands on the same plan as the
    all-local session, and EXPLAIN ANALYZE grows the remote footer."""
    ds, local, remote = sessions
    local_plan = _frame(local, ds).plan()
    res = _frame(remote, ds).execute(dispatcher="inline")
    rplan = res.explain_analyze()
    assert [st.op_name for st in local_plan.stages] \
        == [s.op_name for s in rplan.stages]
    text = rplan.render()
    assert "remote:" in text and "calls=" in text and "rtt_ms" in text
    assert "remote fast:" in text
    # the all-local session never grows the footer
    ltext = _frame(local, ds).execute(dispatcher="inline") \
        .explain_analyze().render()
    assert "remote:" not in ltext


def test_scheduler_coalesces_remote_wire_calls(sessions):
    """K concurrent copies through the QueryScheduler: decisions stay
    bit-identical to solo, and cross-query flush merging reaches the
    wire — fewer remote calls than K solo runs would issue."""
    ds, _, remote = sessions
    member = remote_members(remote.backend)[0]
    frame = _frame(remote, ds)
    before = member.snapshot()
    solo = frame.execute(dispatcher="inline")
    solo_calls = member.snapshot()["calls"] - before["calls"]
    assert solo_calls > 0                    # fast stages really remote
    frame.plan()
    K = 3
    before = member.snapshot()
    with QueryScheduler(remote, max_concurrent=K, paused=True) as sched:
        handles = [sched.submit(frame) for _ in range(K)]
        sched.resume()
        results = [h.result(timeout=300) for h in handles]
        stats = sched.stats()
    sched_calls = member.snapshot()["calls"] - before["calls"]
    for r in results:
        np.testing.assert_array_equal(r.accepted, solo.accepted)
        for li in solo.map_values:
            np.testing.assert_array_equal(r.map_values[li],
                                          solo.map_values[li])
    assert stats["n_merged_calls"] >= 1
    # the hub's merged groups became single wire calls
    assert sched_calls < K * solo_calls


def test_remote_run_info_snapshot_math():
    a = {"engine": "e", "calls": 2, "retries": 0, "fallbacks": 0,
         "errors": 0, "bytes_sent": 1024, "bytes_recv": 1024,
         "rtt_count": 2, "rtt_total_s": 0.004, "rtt_recent": [0.001, 0.003]}
    assert remote_run_info({"e": a}, {"e": dict(a)}) is None  # no delta
    b = dict(a, calls=5, rtt_count=5, bytes_recv=3072,
             rtt_recent=[0.001, 0.003, 0.002, 0.002, 0.010])
    info = remote_run_info({"e": a}, {"e": b})
    assert info["calls"] == 3 and info["engines"]["e"]["calls"] == 3
    assert info["wire_kb"] == pytest.approx(2.0)
    assert info["rtt_ms_p50"] == pytest.approx(2.0)
    assert info["rtt_ms_p95"] == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# robustness: a real worker subprocess, SIGKILLed mid-run
# ---------------------------------------------------------------------------

def test_worker_crash_fallback_and_fail_policies(tmp_path_factory):
    ds = make_dataset("remote", 90, seed=7)
    proc, addr = spawn_worker(name="fast", **FAST_SPEC)
    fb_sess = _session(
        tmp_path_factory,
        EngineSpec("fast", address=addr, remote_retries=1,
                   on_unavailable="fallback"), "fb")
    fail_sess = _session(
        tmp_path_factory,
        EngineSpec("fast", address=addr, remote_retries=0,
                   on_unavailable="fail"), "ff")
    try:
        query = _frame(fb_sess, ds).to_query()
        # plan (and thereby fetch + memoize the catalog) while alive;
        # the second session's sync is an idempotent hash check
        fb_plan = fb_sess.plan(query, ds.items)
        fail_plan = fail_sess.plan(query, ds.items)
        assert {st.engine for st in fb_plan.stages} \
            == {"fast", "accurate"}

        # --- fallback: SIGKILL between partitions of a streaming run ---
        # coalesce=1 keeps flushes per-partition (the default threshold
        # would buffer the whole run's remote work into the first
        # partition's settle, leaving nothing to fail after the kill)
        member = remote_members(fb_sess.backend)[0]
        gen = fb_sess.iter_run(fb_plan, query, ds.items, partition_size=30,
                               coalesce=1, dispatcher="inline")
        next(gen)                            # partition 1 over the wire
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30)
        result = None
        try:
            while True:
                next(gen)
        except StopIteration as stop:
            result = stop.value
        assert result is not None
        assert result.accepted.shape == (len(ds.items),)
        snap = member.snapshot()
        assert snap["fallbacks"] > 0         # flushes re-routed to gold
        assert snap["retries"] > 0           # transport retries happened
        # degraded decisions remain exact where the fallback IS gold:
        # every fallback flush scored with the gold operator, so the
        # result set is still a valid decision vector over the corpus
        assert result.accepted.dtype == bool

        # --- fail: same dead worker, policy raises, session survives ---
        with pytest.raises(RemoteEngineError) as ei:
            fail_sess.run(fail_plan, query, ds.items, dispatcher="inline")
        assert ei.value.transport and ei.value.engine == "fast"
        # the session is not poisoned: gold execution (local engines
        # only) still completes for the same query
        gold = fail_sess.gold(query, ds.items)
        assert gold.accepted.shape == (len(ds.items),)
        gp = gold_plan_for(query, fail_sess.backend)
        again = fail_sess.run(gp, query, ds.items, dispatcher="inline")
        assert again.accepted.shape == (len(ds.items),)
        assert again.remote is None          # gold plan: no wire calls
    finally:
        proc.kill()
        fb_sess.close()
        fail_sess.close()


def test_application_errors_are_never_masked_by_fallback(world):
    """A worker-reported error (unknown operator) is a misconfiguration,
    not an outage — it must raise even under on_unavailable='fallback'."""
    ds, _, addr = world
    member = RemoteEngineMember("fast", addr, on_unavailable="fallback")
    try:
        member.sync(ds.items)
        op = SemFilter("f1", 1)
        with pytest.raises(RemoteEngineError) as ei:
            member._wire_filter(op, "no-such-op", ds.items[:4])
        assert not ei.value.transport
        assert "no-such-op" in str(ei.value)
    finally:
        member.close()


def test_circuit_breaker_opens_and_fails_fast(world):
    """After breaker_threshold consecutive transport failures the
    breaker fails fast (no connect attempt) until the reset window."""
    # a port with nothing behind it: reserve then release
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    dead = f"127.0.0.1:{probe.getsockname()[1]}"
    probe.close()
    member = RemoteEngineMember("gone", dead, retries=0, backoff_s=0.0,
                                breaker_threshold=2, breaker_reset_s=60.0,
                                on_unavailable="fail")
    for _ in range(2):
        with pytest.raises(RemoteEngineError, match="unreachable"):
            member.health()
    with pytest.raises(RemoteEngineError, match="circuit open"):
        member.health()
    assert member.snapshot()["errors"] == 2  # breaker trips count no call
