"""Gradient planner behavior on controlled synthetic pipelines."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import relaxation as R
from repro.core.optimizer import PlannerConfig, optimize_query

CFG = PlannerConfig(steps=200, restarts=3, snapshots=3)


def _world(seed=0, N=300):
    rng = np.random.default_rng(seed)
    true = rng.random(N) < 0.4
    gold = np.where(true, 3.0, -3.0) + rng.normal(0, 0.3, N)
    cheap = np.where(true, 1.0, -1.0) + rng.normal(0, 0.8, N)
    mid = np.where(true, 2.0, -2.0) + rng.normal(0, 0.5, N)
    data = R.PipelineData(
        scores=jnp.asarray(np.stack([cheap, mid, gold]), jnp.float32),
        costs=jnp.asarray([0.01, 0.1, 1.0]), is_map=False)
    return data, (gold > 0).astype(np.float32)


def test_cost_monotone_in_target():
    data, g = _world()
    costs = []
    for tgt in (0.6, 0.9):
        plan = optimize_query([data], g, tgt, tgt, CFG)
        assert plan.feasible
        costs.append(plan.est_cost)
    assert costs[0] <= costs[1] + 1e-6      # looser target -> cheaper plan


def test_bounds_exceed_targets_when_feasible():
    data, g = _world()
    plan = optimize_query([data], g, 0.8, 0.8, CFG)
    assert plan.feasible
    assert plan.recall_bound >= 0.8
    assert plan.precision_bound >= 0.8


def test_infeasible_falls_back_to_gold():
    data, g = _world(N=40)       # tiny sample: 0.99 is uncertifiable
    plan = optimize_query([data], g, 0.99, 0.99, CFG)
    assert not plan.feasible
    assert plan.selected[0][-1]            # gold on
    assert not plan.selected[0][:-1].any()  # everything else off


def test_cascade_beats_gold_only_cost():
    data, g = _world()
    plan = optimize_query([data], g, 0.7, 0.7, CFG)
    gold_cost = 300 * 1.0
    assert plan.feasible
    assert plan.est_cost < 0.5 * gold_cost


def test_batch_aware_cost_shifts_plan():
    """Fixed-cost-dominated pipeline: a proxy op with near-gold scores
    and a negligible *marginal* cost, but a large per-call fixed cost and
    a memory cap of one tuple per batch (so the fixed cost cannot be
    amortized). The scalar cost model sees only the marginal cost and
    loves the op; the batch-size-aware model prices it above gold and
    must drop it — same scores, same targets, provably different plan."""
    rng = np.random.default_rng(3)
    N = 400
    true = rng.random(N) < 0.4
    gold = np.where(true, 3.0, -3.0) + rng.normal(0, 0.3, N)
    trap = np.where(true, 2.5, -2.5) + rng.normal(0, 0.3, N)
    g = (gold > 0).astype(np.float32)
    scores = jnp.asarray(np.stack([trap, gold]), jnp.float32)
    marginal = jnp.asarray([0.001, 1.0])

    scalar = R.PipelineData(scores=scores, costs=marginal, is_map=False)
    plan_scalar = optimize_query([scalar], g, 0.8, 0.8, CFG)
    assert plan_scalar.feasible
    assert plan_scalar.selected[0][0], \
        "scalar cost model should exploit the cheap-looking proxy"

    aware = R.PipelineData(
        scores=scores, costs=marginal, is_map=False,
        fixed=jnp.asarray([2.0, 0.0]),
        batch_cap=jnp.asarray([1.0, jnp.inf]))
    hint = R.BatchHint(width=64.0, scale=1.0)
    plan_aware = optimize_query([aware], g, 0.8, 0.8, CFG, batch_hint=hint)
    assert plan_aware.feasible
    assert not plan_aware.selected[0][0], \
        "batch-aware cost model must price the unamortizable fixed cost"
    # the batch-aware estimate reflects the true (fixed-inclusive) cost:
    # gold-only on every tuple, not the fantasy 0.001s/t cascade
    assert plan_aware.est_cost > plan_scalar.est_cost


def test_upstream_survival_shrinks_expected_batches():
    """A pipeline sitting behind a selective upstream filter sees fewer
    tuples, so its fixed per-call cost amortizes over smaller flushes:
    the survival-weighted cost must exceed the unweighted one."""
    rng = np.random.default_rng(5)
    N = 200
    true = rng.random(N) < 0.5
    gold = np.where(true, 3.0, -3.0) + rng.normal(0, 0.3, N)
    data = R.PipelineData(
        scores=jnp.asarray(np.stack([gold * 0.8, gold]), jnp.float32),
        costs=jnp.asarray([0.01, 1.0]), is_map=False,
        fixed=jnp.asarray([0.5, 0.5]),
        batch_cap=jnp.asarray([jnp.inf, jnp.inf]))
    params = R.PipelineParams(jnp.asarray([10.0, 10.0]),
                              jnp.asarray([1.0, 0.0]),
                              jnp.asarray([-1.0, 0.0]))
    hint = R.BatchHint(width=256.0, scale=1.0)
    _, cost_full, _ = R.simulate_pipeline(params, data, 0.0, hard=True,
                                          batch_hint=hint)
    survive = jnp.full(N, 0.05)    # upstream filter kills 95%
    _, cost_starved, _ = R.simulate_pipeline(params, data, 0.0, hard=True,
                                             batch_hint=hint,
                                             reach_weight=survive)
    assert float(cost_starved.sum()) > float(cost_full.sum())


def test_batch_hint_defaults_keep_scalar_model_exact():
    """Pipelines without fixed-cost data must be costed identically with
    and without a batch hint (the scalar model is the fixed=None special
    case, bit-for-bit)."""
    data, g = _world()
    params = [R.PipelineParams(jnp.asarray([2.0, 0.0, 10.0]),
                               jnp.asarray([1.0, 0.5, 0.0]),
                               jnp.asarray([-1.0, -0.5, 0.0]))]
    c0 = R.query_counts([data], params, jnp.asarray(g), 0.5)
    c1 = R.query_counts([data], params, jnp.asarray(g), 0.5,
                        batch_hint=R.BatchHint(width=7.0, scale=31.0))
    assert float(c0.cost) == float(c1.cost)
    assert float(c0.tp) == float(c1.tp)


def test_multi_filter_budget_reallocation():
    """One easy + one hard logical filter: the optimizer should spend the
    error budget on the hard one (paper's central motivation)."""
    rng = np.random.default_rng(1)
    N = 300
    t1 = rng.random(N) < 0.5
    t2 = rng.random(N) < 0.5
    easy_gold = np.where(t1, 4.0, -4.0) + rng.normal(0, 0.1, N)
    easy_cheap = np.where(t1, 2.0, -2.0) + rng.normal(0, 0.2, N)  # v good
    hard_gold = np.where(t2, 3.0, -3.0) + rng.normal(0, 0.4, N)
    hard_cheap = np.where(t2, 0.5, -0.5) + rng.normal(0, 1.0, N)  # bad
    d1 = R.PipelineData(jnp.asarray(np.stack([easy_cheap, easy_gold]),
                                    jnp.float32),
                        jnp.asarray([0.01, 1.0]), False)
    d2 = R.PipelineData(jnp.asarray(np.stack([hard_cheap, hard_gold]),
                                    jnp.float32),
                        jnp.asarray([0.01, 1.0]), False)
    g = ((easy_gold > 0) & (hard_gold > 0)).astype(np.float32)
    plan = optimize_query([d1, d2], g, 0.85, 0.85, CFG)
    assert plan.feasible
    # global plan must be cheaper than running both golds on everything
    assert plan.est_cost < 2.0 * N * 0.9
