"""Gradient planner behavior on controlled synthetic pipelines."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import relaxation as R
from repro.core.optimizer import PlannerConfig, optimize_query

CFG = PlannerConfig(steps=200, restarts=3, snapshots=3)


def _world(seed=0, N=300):
    rng = np.random.default_rng(seed)
    true = rng.random(N) < 0.4
    gold = np.where(true, 3.0, -3.0) + rng.normal(0, 0.3, N)
    cheap = np.where(true, 1.0, -1.0) + rng.normal(0, 0.8, N)
    mid = np.where(true, 2.0, -2.0) + rng.normal(0, 0.5, N)
    data = R.PipelineData(
        scores=jnp.asarray(np.stack([cheap, mid, gold]), jnp.float32),
        costs=jnp.asarray([0.01, 0.1, 1.0]), is_map=False)
    return data, (gold > 0).astype(np.float32)


def test_cost_monotone_in_target():
    data, g = _world()
    costs = []
    for tgt in (0.6, 0.9):
        plan = optimize_query([data], g, tgt, tgt, CFG)
        assert plan.feasible
        costs.append(plan.est_cost)
    assert costs[0] <= costs[1] + 1e-6      # looser target -> cheaper plan


def test_bounds_exceed_targets_when_feasible():
    data, g = _world()
    plan = optimize_query([data], g, 0.8, 0.8, CFG)
    assert plan.feasible
    assert plan.recall_bound >= 0.8
    assert plan.precision_bound >= 0.8


def test_infeasible_falls_back_to_gold():
    data, g = _world(N=40)       # tiny sample: 0.99 is uncertifiable
    plan = optimize_query([data], g, 0.99, 0.99, CFG)
    assert not plan.feasible
    assert plan.selected[0][-1]            # gold on
    assert not plan.selected[0][:-1].any()  # everything else off


def test_cascade_beats_gold_only_cost():
    data, g = _world()
    plan = optimize_query([data], g, 0.7, 0.7, CFG)
    gold_cost = 300 * 1.0
    assert plan.feasible
    assert plan.est_cost < 0.5 * gold_cost


def test_multi_filter_budget_reallocation():
    """One easy + one hard logical filter: the optimizer should spend the
    error budget on the hard one (paper's central motivation)."""
    rng = np.random.default_rng(1)
    N = 300
    t1 = rng.random(N) < 0.5
    t2 = rng.random(N) < 0.5
    easy_gold = np.where(t1, 4.0, -4.0) + rng.normal(0, 0.1, N)
    easy_cheap = np.where(t1, 2.0, -2.0) + rng.normal(0, 0.2, N)  # v good
    hard_gold = np.where(t2, 3.0, -3.0) + rng.normal(0, 0.4, N)
    hard_cheap = np.where(t2, 0.5, -0.5) + rng.normal(0, 1.0, N)  # bad
    d1 = R.PipelineData(jnp.asarray(np.stack([easy_cheap, easy_gold]),
                                    jnp.float32),
                        jnp.asarray([0.01, 1.0]), False)
    d2 = R.PipelineData(jnp.asarray(np.stack([hard_cheap, hard_gold]),
                                    jnp.float32),
                        jnp.asarray([0.01, 1.0]), False)
    g = ((easy_gold > 0) & (hard_gold > 0)).astype(np.float32)
    plan = optimize_query([d1, d2], g, 0.85, 0.85, CFG)
    assert plan.feasible
    # global plan must be cheaper than running both golds on everything
    assert plan.est_cost < 2.0 * N * 0.9
