"""Dispatch-layer tests: spec resolution, and hypothesis property tests of
the coalescing-buffer invariants — every input tuple is flushed (scored)
exactly once per stage it reaches, for any partition size, coalesce width
and dispatcher. Uses pure-python recording operators so flush membership
is observable and scores are bit-exact under any batch grouping."""
import threading
import typing

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import Query, RelFilter, SemFilter, SemMap
from repro.core.physical import (PhysicalOperator, PhysicalPlan,
                                 PhysicalPlanStage)
from repro.runtime import (InlineDispatcher, MeshDispatcher,
                           ShardedDispatcher, ThreadPoolDispatcher,
                           as_backend, resolve_dispatcher, run_plan)


# ---------------------------------------------------------------------------
# resolve_dispatcher
# ---------------------------------------------------------------------------

def test_resolve_specs():
    d, owned = resolve_dispatcher("inline")
    assert isinstance(d, InlineDispatcher) and owned
    d, owned = resolve_dispatcher("threads:7")
    assert isinstance(d, ThreadPoolDispatcher) and owned
    assert d.n_workers == 7 and d.max_pending == 14
    d, owned = resolve_dispatcher("sharded:5")
    assert isinstance(d, ShardedDispatcher) and owned
    assert d.n_shards == 5
    inst = ThreadPoolDispatcher(2)
    d, owned = resolve_dispatcher(inst)
    assert d is inst and not owned      # caller keeps ownership
    inst.close()
    with pytest.raises(ValueError):
        resolve_dispatcher("gpu-farm")
    with pytest.raises(TypeError):
        resolve_dispatcher(42)


@pytest.mark.parametrize("spec", ["threads:0", "sharded:0", "threads:-2",
                                  "sharded:-1", "mesh:0", "mesh:-3"])
def test_resolve_rejects_nonpositive_counts(spec):
    """threads:0 / sharded:0 must raise, not silently coerce to the
    defaults — a zero-worker request is a config bug, and masking it
    would make a benchmark 'sharded:0' run report default-shard numbers
    under a zero-shard label."""
    with pytest.raises(ValueError, match="must be positive"):
        resolve_dispatcher(spec)


def test_module_annotations_resolve():
    """Regression: ThreadPoolDispatcher.__init__ annotates with
    typing.Dict, which once wasn't imported — a latent NameError for any
    typing.get_type_hints consumer. Resolving every annotation in the
    module's public classes must not raise."""
    hints = typing.get_type_hints(ThreadPoolDispatcher.__init__)
    assert hints["engine_workers"] == typing.Optional[typing.Dict[str, int]]
    for cls in (InlineDispatcher, ThreadPoolDispatcher, ShardedDispatcher,
                MeshDispatcher):
        typing.get_type_hints(cls.__init__)
        typing.get_type_hints(cls.submit if hasattr(cls, "submit")
                              else cls.map_shards)


def test_resolve_env_default(monkeypatch):
    monkeypatch.delenv("STRETTO_DISPATCHER", raising=False)
    d, _ = resolve_dispatcher(None)
    assert isinstance(d, InlineDispatcher)
    monkeypatch.setenv("STRETTO_DISPATCHER", "threads:3")
    d, owned = resolve_dispatcher(None)
    assert isinstance(d, ThreadPoolDispatcher) and d.n_workers == 3
    d.close()


def test_shard_bounds_cover_corpus():
    d = ShardedDispatcher(3)
    for n in (0, 1, 2, 3, 7, 99):
        bounds = d.shard_bounds(n)
        covered = [i for lo, hi in bounds for i in range(lo, hi)]
        assert covered == list(range(n))


# ---------------------------------------------------------------------------
# pure-python recording world (no engine): observable flush membership
# ---------------------------------------------------------------------------

class _Item:
    __slots__ = ("idx", "row")

    def __init__(self, idx: int):
        self.idx = idx
        self.row = {"grp": idx % 3}


def _score(idx, task_id, scale=3.0):
    """Deterministic pseudo-random score from the tuple id alone — makes
    decisions independent of batch grouping by construction."""
    return np.float32(
        scale * np.sin(np.asarray(idx, np.float64) * 12.9898
                       + task_id * 78.233))


class _RecordingFilter(PhysicalOperator):
    uses_llm = False

    def __init__(self, name, task_id, log, lock, is_gold=False):
        self.name = name
        self.task_id = task_id
        self.log = log
        self.lock = lock
        self.is_gold = is_gold

    def run_filter(self, items, op):
        idx = [it.idx for it in items]
        with self.lock:
            self.log.setdefault(self.name, []).extend(idx)
        return _score(idx, self.task_id)


class _RecordingMap(PhysicalOperator):
    uses_llm = False

    def __init__(self, name, task_id, log, lock, is_gold=False):
        self.name = name
        self.task_id = task_id
        self.log = log
        self.lock = lock
        self.is_gold = is_gold

    def run_filter(self, items, op):
        raise NotImplementedError

    def run_map(self, items, op):
        idx = [it.idx for it in items]
        with self.lock:
            self.log.setdefault(self.name, []).extend(idx)
        return (np.asarray(idx, np.int64) % 5, _score(idx, self.task_id))


def _world():
    """(query, plan, registry, log): a 2-stage filter cascade + a 2-stage
    map cascade behind a relational filter, with every operator logging
    the exact tuples it scored."""
    log = {}
    lock = threading.Lock()
    f_cheap = _RecordingFilter("f-cheap", 1, log, lock)
    f_gold = _RecordingFilter("f-gold", 2, log, lock, is_gold=True)
    m_cheap = _RecordingMap("m-cheap", 3, log, lock)
    m_gold = _RecordingMap("m-gold", 4, log, lock, is_gold=True)
    sf, sm = SemFilter("f", 1), SemMap("m", 3)
    rel = RelFilter("grp", "!=", 0)

    def registry(op):
        return [f_cheap, f_gold] if isinstance(op, SemFilter) \
            else [m_cheap, m_gold]

    q = Query([sf, rel, sm], target_recall=0.8, target_precision=0.8)
    stages = [
        PhysicalPlanStage(0, 0, "f-cheap", 1.0, -1.0, False, False, 0.1),
        PhysicalPlanStage(1, 0, "m-cheap", 1.5, -np.inf, True, False, 0.1),
        PhysicalPlanStage(0, 1, "f-gold", 0.0, 0.0, False, True, 1.0),
        PhysicalPlanStage(1, 1, "m-gold", 0.0, 0.0, True, True, 1.0),
    ]
    plan = PhysicalPlan(stages, [rel], 0.0, 1.0, 1.0, True)
    return q, plan, registry, log


def _expected_flushes(q, plan, items):
    """Reference: run inline over the whole corpus at once; the tuples
    each operator scores are schedule-invariant, so this is the expected
    flush membership for every (partition, coalesce, dispatcher) config."""
    q2, plan2, registry2, log2 = _world()
    rr = run_plan(plan2, q2, items, as_backend(registry2),
                  dispatcher="inline")
    return rr, {name: sorted(idx) for name, idx in log2.items()}


DISPATCHERS = ["inline", "threads:3", "sharded:3", "sharded:1", "mesh:2"]


@pytest.mark.parametrize("dispatcher", DISPATCHERS)
def test_flushed_exactly_once_smoke(dispatcher):
    """Deterministic spot-check of the property below (runs even without
    the optional hypothesis dep)."""
    _check_flush_invariants(n=41, part=7, coalesce=13, dispatcher=dispatcher)


@given(n=st.integers(0, 60), part=st.integers(1, 23),
       coalesce=st.integers(1, 50),
       dispatcher=st.sampled_from(DISPATCHERS))
@settings(max_examples=30, deadline=None)
def test_flushed_exactly_once_property(n, part, coalesce, dispatcher):
    _check_flush_invariants(n, part, coalesce, dispatcher)


def _check_flush_invariants(n, part, coalesce, dispatcher):
    items = [_Item(i) for i in range(n)]
    q, plan, registry, log = _world()
    rr = run_plan(plan, q, items, as_backend(registry),
                  partition_size=part, coalesce=coalesce,
                  dispatcher=dispatcher)
    ref, expected = _expected_flushes(q, plan, items)
    # 1. every tuple a stage reaches is flushed exactly once there —
    #    no duplicates, none lost, regardless of buffering/scatter
    assert set(log.keys()) == set(expected.keys())
    for name, idx in log.items():
        assert len(idx) == len(set(idx)), \
            f"{name} scored a tuple twice ({dispatcher}, part={part}, " \
            f"coalesce={coalesce})"
        assert sorted(idx) == expected[name], \
            f"{name} flush membership drifted ({dispatcher}, part={part}, " \
            f"coalesce={coalesce})"
    # 2. and the results are bit-identical to the inline reference
    np.testing.assert_array_equal(rr.accepted, ref.accepted)
    assert set(rr.map_values) == set(ref.map_values)
    for li in ref.map_values:
        np.testing.assert_array_equal(rr.map_values[li], ref.map_values[li])
    # 3. relational-rejected tuples never reach any stage
    dead = {it.idx for it in items if it.row["grp"] == 0}
    for name, idx in log.items():
        assert not dead & set(idx)


# ---------------------------------------------------------------------------
# close() lifecycle: idempotent, and safe under concurrent submitters
# ---------------------------------------------------------------------------

def _noop_task():
    from repro.runtime.dispatch import FlushTask
    return FlushTask(0, SemFilter("f", 1), "f-cheap", [_Item(0)])


def test_threadpool_close_idempotent():
    d = ThreadPoolDispatcher(2)
    h = d.submit(_noop_task(), lambda t: len(t.items))
    assert h.result() == 1
    d.close()
    d.close()                                   # second close: no-op


def test_threadpool_submit_after_close_raises():
    """Submitting after close must raise a clear error, not spin up an
    orphan worker pool that nothing will ever shut down (the old
    behavior) or hang the submitter."""
    d = ThreadPoolDispatcher(2)
    d.close()
    with pytest.raises(RuntimeError, match="closed"):
        d.submit(_noop_task(), lambda t: len(t.items))


def test_threadpool_concurrent_close_and_submit():
    """Racing close() against submitters from other threads: every
    submit either completes normally or raises RuntimeError — nothing
    hangs, and a double close from two threads is safe."""
    for _ in range(10):
        d = ThreadPoolDispatcher(2)
        errs, done = [], []
        lock = threading.Lock()

        def _submitter():
            try:
                h = d.submit(_noop_task(), lambda t: len(t.items))
                r = h.result()
                with lock:
                    done.append(r)
            except RuntimeError as e:
                with lock:
                    errs.append(e)

        threads = [threading.Thread(target=_submitter) for _ in range(4)]
        threads += [threading.Thread(target=d.close) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
            assert not t.is_alive(), "close/submit race hung"
        assert len(done) + len(errs) == 4
        assert all(r == 1 for r in done)
        # losers of the race get a clear error: either the dispatcher's
        # own message or the pool's shutdown refusal (a submit can grab
        # a pool just before close() shuts it down)
        assert all("closed" in str(e) or "shutdown" in str(e)
                   for e in errs)


def test_sharded_close_idempotent_and_rejects_after():
    d = ShardedDispatcher(2)
    bounds = d.shard_bounds(4)
    assert d.map_shards(lambda i, lo, hi: hi - lo, bounds) == [2, 2]
    d.close()
    d.close()
    with pytest.raises(RuntimeError, match="closed"):
        d.map_shards(lambda i, lo, hi: hi - lo, bounds)


def test_mesh_close_idempotent_and_rejects_after():
    d = MeshDispatcher(2)
    d.close()
    d.close()
    with pytest.raises(RuntimeError, match="closed"):
        d.map_shards(lambda i, lo, hi: hi - lo, d.shard_bounds(4))
