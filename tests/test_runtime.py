"""Runtime subsystem tests: shared decision kernel vs the pre-runtime numpy
rule, and streaming-executor parity against a verbatim copy of the seed
`execute_plan` (bit-identical accepted masks, map values and tuple counts
across partition sizes, including partition >= N: the non-streaming case)."""
import numpy as np
import pytest

from repro.cache.store import CacheStore
from repro.core import PlannerConfig, Query, RelFilter, SemFilter, SemMap
from repro.core.baselines import plan_lotus
from repro.core.executor import _decide, execute_plan
from repro.core.physical import PhysicalPlan, PhysicalPlanStage
from repro.core.planner import plan_query
from repro.data.synthetic import (make_dataset, make_planted_params,
                                  planted_config)
from repro.runtime import (KVCacheBackend, OracleBackend, ReferenceBackend,
                           ShardedDispatcher, ThreadPoolDispatcher,
                           as_backend, decide, gold_decide, gold_plan_for,
                           run_plan)
from repro.serving.engine import ServingEngine
from repro.serving.operators import make_registry

FAST = PlannerConfig(steps=120, restarts=2, snapshots=2)


# ---------------------------------------------------------------------------
# decision kernel vs the seed numpy rule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("is_map", [False, True])
def test_kernel_matches_seed_decide(is_map):
    rng = np.random.default_rng(7)
    scores = rng.normal(scale=3.0, size=500).astype(np.float32)
    cases = [(0.5, -0.5), (-0.2, 0.4),            # normal + crossed
             (0.0, 0.0),                          # boundary ties
             (float("inf"), -float("inf")),       # lotus-style sentinels
             (float("inf"), 0.3), (-1.0, float("-inf"))]
    cases += [(float(rng.normal()), float(rng.normal())) for _ in range(20)]
    for hi, lo in cases:
        acc_np, rej_np = _decide(scores, hi, lo, is_map)
        acc_k, rej_k, uns_k = decide(scores, hi, lo, is_map)
        np.testing.assert_array_equal(acc_k, acc_np, err_msg=f"{hi},{lo}")
        np.testing.assert_array_equal(rej_k, rej_np, err_msg=f"{hi},{lo}")
        np.testing.assert_array_equal(uns_k, ~(acc_np | rej_np))
    # exact score==threshold ties follow the argmax rule, not `>`
    s = np.asarray([1.0, 2.0, 3.0], np.float32)
    acc, rej, uns = decide(s, 2.0, 2.0, False)
    acc_np, rej_np = _decide(s, 2.0, 2.0, False)
    np.testing.assert_array_equal(acc, acc_np)
    np.testing.assert_array_equal(rej, rej_np)


def test_gold_decide():
    s = np.asarray([-1.0, 0.0, 2.0], np.float32)
    acc, rej = gold_decide(s, False)
    np.testing.assert_array_equal(acc, [False, False, True])
    np.testing.assert_array_equal(rej, ~acc)
    acc, rej = gold_decide(s, True)
    assert acc.all() and not rej.any()


# ---------------------------------------------------------------------------
# seed executor, copied verbatim from the pre-runtime core/executor.py —
# the golden reference the streaming runtime must reproduce bit-for-bit
# ---------------------------------------------------------------------------

def _seed_execute_plan(plan, query, items, registry):
    sem_ops = query.semantic_ops
    N = len(items)
    alive = np.ones(N, bool)
    for rel in plan.relational:
        alive &= np.array([rel.apply(getattr(it, "row", {}) or {})
                           for it in items])
    n_logical = len(sem_ops)
    accepted = {li: np.zeros(N, bool) for li in range(n_logical)}
    rejected = {li: np.zeros(N, bool) for li in range(n_logical)}
    unsure = {li: alive.copy() for li in range(n_logical)}
    map_values = {}
    ops_by_name = {}
    for li, op in enumerate(sem_ops):
        for phys in registry(op):
            ops_by_name[(li, phys.name)] = (phys, op)
    stage_counts = []
    n_llm = 0
    for st in plan.stages:
        li = st.logical_idx
        op_obj, sem = ops_by_name[(li, st.op_name)]
        mask = unsure[li].copy()
        for lj in range(n_logical):
            if lj != li and not isinstance(sem_ops[lj], SemMap):
                mask &= ~rejected[lj]
        idx = np.nonzero(mask)[0]
        if idx.size == 0:
            continue
        batch = [items[i] for i in idx]
        if isinstance(sem, SemFilter):
            scores = np.asarray(op_obj.run_filter(batch, sem), np.float32)
            vals = None
        else:
            vals, conf = op_obj.run_map(batch, sem)
            vals = np.asarray(vals)
            scores = np.asarray(conf, np.float32)
        stage_counts.append((st.op_name, int(idx.size)))
        if getattr(op_obj, "uses_llm", True):
            n_llm += int(idx.size)
        if st.is_gold:
            acc = (scores > 0) if not st.is_map else np.ones(len(idx), bool)
            rej = ~acc if not st.is_map else np.zeros(len(idx), bool)
        else:
            acc, rej = _decide(scores, st.thr_hi, st.thr_lo, st.is_map)
        if st.is_map:
            if li not in map_values:
                map_values[li] = np.zeros(N, object)
            commit = acc | (st.is_gold)
            commit_idx = idx[commit]
            map_values[li][commit_idx] = vals[commit]
            unsure[li][commit_idx] = False
        else:
            accepted[li][idx[acc]] = True
            rejected[li][idx[rej]] = True
            unsure[li][idx[acc]] = False
            unsure[li][idx[rej]] = False
    result = alive.copy()
    for li, op in enumerate(sem_ops):
        if isinstance(op, SemFilter):
            result &= accepted[li]
    return result, map_values, stage_counts, n_llm


# ---------------------------------------------------------------------------
# streaming parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world(tmp_path_factory):
    ds = make_dataset("rt", 120, seed=11)
    store = CacheStore(str(tmp_path_factory.mktemp("cache")))
    eng = ServingEngine(store)
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        eng.register_model(size, cfg, make_planted_params(cfg, seed=1))
        eng.build_profiles(size, ds.items, ratios=[0.0, 0.5, 0.8],
                           prefill_batch=48)
    registry = make_registry(eng, sm_ratios=(0.8, 0.0), lg_ratios=(0.5,))
    return ds, eng, registry


def _assert_parity(plan, q, items, registry, partition_sizes):
    ref_acc, ref_maps, ref_counts, ref_llm = _seed_execute_plan(
        plan, q, items, registry)
    for psize in partition_sizes:
        rr = run_plan(plan, q, items, as_backend(registry),
                      partition_size=psize)
        np.testing.assert_array_equal(rr.accepted, ref_acc,
                                      err_msg=f"partition={psize}")
        assert set(rr.map_values) == set(ref_maps)
        for li in ref_maps:
            np.testing.assert_array_equal(rr.map_values[li], ref_maps[li],
                                          err_msg=f"partition={psize}")
        assert rr.n_llm_tuples == ref_llm
        # per-stage tuple counts, in plan order, executed stages only
        got_by_stage = [(s.op_name, s.n_tuples) for s in rr.stage_stats]
        assert got_by_stage == ref_counts, f"partition={psize}"


def test_streaming_parity_planned_query(world):
    ds, eng, registry = world
    q = Query([SemFilter("f1", 1), SemMap("extract v3", 3)],
              target_recall=0.7, target_precision=0.7)
    plan = plan_query(q, ds.items, registry, FAST, sample_frac=0.35)
    _assert_parity(plan, q, ds.items, registry,
                   partition_sizes=[None, len(ds.items) + 40, 32, 11])


def test_streaming_parity_lotus_plan_with_relational(world):
    ds, eng, registry = world
    q = Query([SemFilter("f2", 2), RelFilter("category", "==", "news"),
               SemFilter("f4", 4)],
              target_recall=0.6, target_precision=0.6)
    plan = plan_lotus(q, ds.items, registry, sample_frac=0.35)
    plan = PhysicalPlan(plan.stages, list(q.relational_ops), plan.est_cost,
                        plan.recall_bound, plan.precision_bound,
                        plan.feasible)
    _assert_parity(plan, q, ds.items, registry,
                   partition_sizes=[None, 17, 64])


def test_streaming_parity_gold_plan(world):
    ds, eng, registry = world
    q = Query([SemFilter("f1", 1), SemFilter("f5", 5)],
              target_recall=0.9, target_precision=0.9)
    plan = gold_plan_for(q, registry)
    _assert_parity(plan, q, ds.items, registry,
                   partition_sizes=[None, 30])


def test_compat_shim_matches_runtime(world):
    """core.execute_plan (the shim) must return the seed result shape."""
    ds, eng, registry = world
    q = Query([SemFilter("f1", 1)], target_recall=0.6, target_precision=0.6)
    plan = plan_lotus(q, ds.items, registry, sample_frac=0.35)
    res = execute_plan(plan, q, ds.items, registry)
    ref_acc, _, ref_counts, ref_llm = _seed_execute_plan(
        plan, q, ds.items, registry)
    np.testing.assert_array_equal(res.accepted, ref_acc)
    assert res.n_llm_tuples == ref_llm
    assert [(name, n) for name, _, n in res.stage_times] == ref_counts
    assert res.runtime_s > 0


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

def test_reference_backend_is_gold(world):
    ds, eng, registry = world
    q = Query([SemFilter("f1", 1)], target_recall=0.9, target_precision=0.9)
    ref = ReferenceBackend(eng)
    ops = ref.candidates(q.semantic_ops[0])
    assert len(ops) == 1 and ops[0].is_gold
    plan = PhysicalPlan([PhysicalPlanStage(
        0, 0, ops[0].name, 0.0, 0.0, False, True, 1.0)], [], 0.0, 1.0, 1.0,
        True)
    rr = run_plan(plan, q, ds.items, ref)
    # identical to executing the gold op from the full registry
    gold_name = registry(q.semantic_ops[0])[-1].name
    plan2 = PhysicalPlan([PhysicalPlanStage(
        0, 0, gold_name, 0.0, 0.0, False, True, 1.0)], [], 0.0, 1.0, 1.0,
        True)
    rr2 = run_plan(plan2, q, ds.items, as_backend(registry))
    np.testing.assert_array_equal(rr.accepted, rr2.accepted)


def test_kvcache_backend_telemetry(world):
    ds, eng, registry = world
    backend = KVCacheBackend(eng, sm_ratios=(0.8, 0.0), lg_ratios=(0.5,))
    q = Query([SemFilter("f3", 3)], target_recall=0.6, target_precision=0.6)
    plan = plan_lotus(q, ds.items, backend, sample_frac=0.35)
    rr = run_plan(plan, q, ds.items, backend, partition_size=40)
    llm_stages = [s for s in rr.stage_stats if s.n_llm_calls > 0]
    assert llm_stages, "lotus plan must run at least one LLM stage"
    assert all(s.kv_bytes > 0 for s in llm_stages)
    assert all(s.wall_s > 0 for s in rr.stage_stats)
    assert rr.n_partitions == 3


def test_cross_stage_coalescing_batches_across_partitions(world):
    """With a coalesce threshold above the partition size, stages must
    accumulate eligible tuples across partitions into fewer, larger
    batches — and still produce identical results. Pinned to the inline
    dispatcher: the per-stage batch-count expectations below encode the
    inline flush schedule (async dispatchers keep results identical but
    regroup cohorts)."""
    ds, eng, registry = world
    q = Query([SemFilter("f1", 1), SemFilter("f4", 4)],
              target_recall=0.6, target_precision=0.6)
    plan = plan_lotus(q, ds.items, registry, sample_frac=0.35)
    ref_acc, _, ref_counts, ref_llm = _seed_execute_plan(
        plan, q, ds.items, registry)
    n = len(ds.items)
    fine = run_plan(plan, q, ds.items, as_backend(registry),
                    partition_size=10, coalesce=1, dispatcher="inline")
    coal = run_plan(plan, q, ds.items, as_backend(registry),
                    partition_size=10, coalesce=60, dispatcher="inline")
    for rr in (fine, coal):
        np.testing.assert_array_equal(rr.accepted, ref_acc)
        assert rr.n_llm_tuples == ref_llm
        assert [(s.op_name, s.n_tuples) for s in rr.stage_stats] \
            == ref_counts
    assert fine.n_partitions == coal.n_partitions == (n + 9) // 10
    by_op_fine = {(s.op_name, s.logical_idx): s.n_batches
                  for s in fine.stage_stats}
    for s in coal.stage_stats:
        # every stage coalesces to fewer (or equal) flushes, and no stage
        # flushes once per partition at the 60-tuple threshold
        assert s.n_batches <= by_op_fine[(s.op_name, s.logical_idx)]
        assert s.n_batches <= int(np.ceil(s.n_tuples / 60)) + 1
    assert max(s.n_batches for s in coal.stage_stats) < coal.n_partitions


def test_empty_corpus_and_relational_only(world):
    ds, eng, registry = world
    q = Query([SemFilter("f1", 1)], target_recall=0.6, target_precision=0.6)
    plan = plan_lotus(q, ds.items, registry, sample_frac=0.35)
    for psize in (None, 5):
        rr = run_plan(plan, q, [], as_backend(registry),
                      partition_size=psize)
        assert rr.accepted.shape == (0,) and rr.n_partitions == 0
    # a plan with no semantic stages applies just the relational filters
    rel = RelFilter("category", "==", "news")
    plan0 = PhysicalPlan([], [rel], 0.0, 1.0, 1.0, True)
    rr = run_plan(plan0, Query([rel], 0.5, 0.5), ds.items,
                  as_backend(registry))
    want = np.array([it.row["category"] == "news" for it in ds.items])
    np.testing.assert_array_equal(rr.accepted, want)


def test_oracle_backend_reports_zero_kv_bytes(world):
    """Non-serving backends must report kv_bytes=0 uniformly — the field
    must not drift with whatever engine-backed operators a generic
    registry callable happens to hand out."""
    ds, eng, registry = world
    b = OracleBackend(registry)
    assert b.kv_bytes_loaded() == 0
    q = Query([SemFilter("f3", 3)], target_recall=0.6, target_precision=0.6)
    plan = plan_lotus(q, ds.items, b, sample_frac=0.35)
    rr = run_plan(plan, q, ds.items, b, partition_size=40)
    assert all(s.kv_bytes == 0 for s in rr.stage_stats)
    assert b.kv_bytes_loaded() == 0   # even after executing LLM operators
    # the serving backend over the same engine does meter its cache store
    assert KVCacheBackend(eng).kv_bytes_loaded() > 0


# ---------------------------------------------------------------------------
# dispatchers: async / sharded execution must be bit-identical to inline
# ---------------------------------------------------------------------------

def test_dispatcher_parity_threads_and_sharded(world):
    """ThreadPoolDispatcher and ShardedDispatcher must produce
    bit-identical accepted masks and map values to InlineDispatcher
    across partition sizes and worker/shard counts; per-stage scored
    tuple totals are schedule-invariant too."""
    ds, eng, registry = world
    q = Query([SemFilter("f2", 2), SemMap("extract v3", 3),
               SemFilter("f4", 4)],
              target_recall=0.6, target_precision=0.6)
    plan = plan_lotus(q, ds.items, registry, sample_frac=0.35)
    backend = as_backend(registry)
    ref = run_plan(plan, q, ds.items, backend, partition_size=32,
                   dispatcher="inline")
    ref_totals = {(s.op_name, s.logical_idx, s.stage): s.n_tuples
                  for s in ref.stage_stats}
    for disp in (ThreadPoolDispatcher(1), ThreadPoolDispatcher(3),
                 ShardedDispatcher(2), ShardedDispatcher(4, n_workers=2)):
        for psize in (None, 17, 32):
            rr = run_plan(plan, q, ds.items, backend,
                          partition_size=psize, dispatcher=disp)
            tag = f"{disp.name} psize={psize}"
            np.testing.assert_array_equal(rr.accepted, ref.accepted,
                                          err_msg=tag)
            assert set(rr.map_values) == set(ref.map_values), tag
            for li in ref.map_values:
                np.testing.assert_array_equal(
                    rr.map_values[li], ref.map_values[li], err_msg=tag)
            assert rr.n_llm_tuples == ref.n_llm_tuples, tag
            got = {(s.op_name, s.logical_idx, s.stage): s.n_tuples
                   for s in rr.stage_stats}
            assert got == ref_totals, tag
            assert rr.dispatcher == disp.name
            assert rr.n_workers == disp.n_workers
        disp.close()


def test_dispatcher_env_resolution(world, monkeypatch):
    """STRETTO_DISPATCHER selects the dispatch layer when run_plan gets
    no explicit dispatcher, without changing results."""
    ds, eng, registry = world
    q = Query([SemFilter("f1", 1)], target_recall=0.6, target_precision=0.6)
    plan = plan_lotus(q, ds.items, registry, sample_frac=0.35)
    backend = as_backend(registry)
    ref = run_plan(plan, q, ds.items, backend, partition_size=25,
                   dispatcher="inline")
    for spec, name, workers in (("threads:2", "threads", 2),
                                ("sharded:3", "sharded", 3),
                                ("inline", "inline", 1)):
        monkeypatch.setenv("STRETTO_DISPATCHER", spec)
        rr = run_plan(plan, q, ds.items, backend, partition_size=25)
        assert rr.dispatcher == name
        assert rr.n_workers == workers
        np.testing.assert_array_equal(rr.accepted, ref.accepted)
    monkeypatch.setenv("STRETTO_DISPATCHER", "bogus")
    with pytest.raises(ValueError):
        run_plan(plan, q, ds.items, backend)


def test_as_backend_passthrough(world):
    ds, eng, registry = world
    b = OracleBackend(registry)
    assert as_backend(b) is b
    assert as_backend(registry) is not registry  # wrapped
    with pytest.raises(TypeError):
        as_backend(42)
