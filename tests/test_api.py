"""Declarative API tests: Session/SemFrame must be a zero-cost front door.

Parity: a SemFrame chain compiles to the exact logical Query a hand-built
pipeline constructs, plans to stage-identical PhysicalPlans (operator
timing is faked to a deterministic clock so the two profiling runs measure
identical costs), and executes bit-identically to the internal
plan_query + run_plan path across dispatchers and partition sizes.

Streaming: `.stream()` chunks concatenate to exactly the `.execute()`
result, and partitions are delivered incrementally — the first partition
arrives while later partitions have not yet been scored.
"""
import threading

import numpy as np
import pytest

import repro
from repro.api import EngineSpec, Session, SessionConfig
from repro.core import (PlannerConfig, Query, RelFilter, SemFilter, SemMap,
                        plan_query)
from repro.core.physical import PhysicalOperator
from repro.data.synthetic import make_dataset
from repro.runtime import DEFAULT_COALESCE, OracleBackend, run_plan

FAST = PlannerConfig(steps=120, restarts=2, snapshots=2)


class _FakeClock:
    """Deterministic stand-in for the executor's `time` module: every
    perf_counter() call advances by a fixed quantum, so measured operator
    costs are identical across repeated profiling runs. The quantum is a
    dyadic fraction so accumulation is exact — intervals are bit-equal no
    matter where on the fake timeline they are measured."""

    def __init__(self, quantum: float = 2.0 ** -13):
        self._t = 0.0
        self._quantum = quantum
        self._lock = threading.Lock()

    def perf_counter(self) -> float:
        with self._lock:
            self._t += self._quantum
            return self._t


# ---------------------------------------------------------------------------
# engine-backed session (shared; profiles built once)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world(tmp_path_factory):
    ds = make_dataset("api", 110, seed=5)
    session = Session(SessionConfig(
        cache_dir=str(tmp_path_factory.mktemp("cache")),
        profile_ratios=(0.0, 0.5, 0.8),
        sm_ratios=(0.8, 0.0), lg_ratios=(0.5,),
        planner=FAST, sample_frac=0.35,
        partition_size=40))
    session.prepare(ds.items)
    yield ds, session
    session.close()


def _frame(sess, ds):
    return (sess.frame(ds.items)
            .sem_filter("f1", 1)
            .filter("category", "==", "news")
            .sem_map("extract v3", 3)
            .with_guarantees(recall=0.7, precision=0.7))


# ---------------------------------------------------------------------------
# API <-> core parity
# ---------------------------------------------------------------------------

def test_frame_compiles_to_identical_query(world):
    ds, sess = world
    frame = _frame(sess, ds)
    hand = Query([SemFilter("f1", 1), RelFilter("category", "==", "news"),
                  SemMap("extract v3", 3)],
                 target_recall=0.7, target_precision=0.7)
    assert frame.to_query() == hand
    # frames are immutable: chaining never mutates the ancestor
    base = sess.frame(ds.items).sem_filter("f1", 1)
    strict = base.with_guarantees(recall=0.95)
    assert base.to_query().target_recall == 0.9          # Query default
    assert strict.to_query().target_recall == 0.95
    assert base.nodes == strict.nodes


def test_api_core_parity(world, monkeypatch):
    """SemFrame must plan stage-identically and decide bit-identically to
    the hand-built plan_query + run_plan path, across dispatchers and
    partition sizes."""
    import repro.runtime.executor as executor_mod
    ds, sess = world
    # identical measured costs on both profiling runs -> identical plans
    monkeypatch.setattr(executor_mod, "time", _FakeClock())
    frame = _frame(sess, ds)
    hand_q = frame.to_query()
    hand_plan = plan_query(hand_q, ds.items, sess.backend, FAST,
                           sample_frac=0.35, seed=0,
                           coalesce=DEFAULT_COALESCE)
    api_plan = frame.plan()
    assert api_plan.stages == hand_plan.stages
    assert api_plan.relational == hand_plan.relational
    assert api_plan.feasible == hand_plan.feasible

    for disp in ("inline", "threads:2", "sharded:2"):
        for psize in (None, 23):
            ref = run_plan(hand_plan, hand_q, ds.items, sess.backend,
                           partition_size=psize, dispatcher=disp)
            res = frame.execute(partition_size=psize, dispatcher=disp)
            tag = f"{disp} psize={psize}"
            np.testing.assert_array_equal(res.accepted, ref.accepted,
                                          err_msg=tag)
            assert set(res.map_values) == set(ref.map_values), tag
            for li in ref.map_values:
                np.testing.assert_array_equal(
                    res.map_values[li], ref.map_values[li], err_msg=tag)
            assert res.n_llm_tuples == ref.n_llm_tuples, tag


def test_explain_reports_the_plan(world):
    ds, sess = world
    frame = _frame(sess, ds)
    plan = frame.plan()
    rep = frame.explain()
    assert len(rep.stages) == len(plan.stages)
    assert rep.n_items == len(ds.items)
    assert rep.target_recall == 0.7 and rep.target_precision == 0.7
    assert len(rep.logical) == 3 and len(rep.relational) == 1
    assert rep.recall_bound == plan.recall_bound
    assert rep.feasible == plan.feasible
    # stage rows mirror the physical plan, in execution order
    for row, st in zip(rep.stages, plan.stages):
        assert row.op_name == st.op_name
        assert row.thr_hi == st.thr_hi and row.thr_lo == st.thr_lo
        assert row.kind == ("map" if st.is_map else "filter")
    text = rep.render()
    assert "EXPLAIN" in text and str(rep) == text
    for st in plan.stages:
        assert st.op_name in text
    assert rep.rows()[0]["order"] == 0


def test_execute_uses_session_defaults_and_metrics(world):
    ds, sess = world
    frame = _frame(sess, ds)
    res = frame.execute()
    assert res.n_partitions == int(np.ceil(len(ds.items) / 40))
    m = res.metrics()
    assert set(m) >= {"precision", "recall", "tp", "fp", "fn"}
    assert res.metrics() is m                     # lazy + cached
    # vs= compares against an arbitrary result (self -> perfect score)
    self_m = res.metrics(vs=res)
    assert self_m["precision"] == pytest.approx(1.0)
    assert self_m["recall"] == pytest.approx(1.0)
    # gold is memoized by the session: same RuntimeResult object
    assert sess.gold(frame.to_query(), ds.items) \
        is sess.gold(frame.to_query(), ds.items)
    assert len(res.matches()) == int(res.accepted.sum())
    assert res.speedup_vs_gold() > 0


def test_empty_frame_rejected(world):
    ds, sess = world
    with pytest.raises(ValueError):
        sess.frame(ds.items).execute()
    with pytest.raises(ValueError):
        sess.frame(ds.items).with_guarantees(recall=0.5).explain()


# ---------------------------------------------------------------------------
# .stream(): concatenation parity + incremental delivery
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dispatcher", ["inline", "threads:2", "sharded:2"])
def test_stream_concat_equals_execute(world, dispatcher):
    ds, sess = world
    frame = _frame(sess, ds)
    res = frame.execute(partition_size=25, dispatcher=dispatcher)
    parts = list(frame.stream(partition_size=25, dispatcher=dispatcher))
    # partitions tile the corpus in order
    assert parts[0].lo == 0 and parts[-1].hi == len(ds.items)
    assert all(a.hi == b.lo for a, b in zip(parts, parts[1:]))
    assert [p.index for p in parts] == list(range(len(parts)))
    acc = np.concatenate([p.accepted for p in parts])
    np.testing.assert_array_equal(acc, res.accepted)
    for li in res.map_values:
        got = np.concatenate([p.map_values[li] for p in parts])
        np.testing.assert_array_equal(got, res.map_values[li])
    # the stream's final result equals execute() too
    stream = frame.stream(partition_size=25, dispatcher=dispatcher)
    final = stream.result                         # drains the stream
    np.testing.assert_array_equal(final.accepted, res.accepted)
    assert final.n_partitions == res.n_partitions


# ---------------------------------------------------------------------------
# incremental delivery, observed via a recording backend (no engine)
# ---------------------------------------------------------------------------

class _CountingFilter(PhysicalOperator):
    uses_llm = False

    def __init__(self, name, task_id, counter, is_gold=False):
        self.name = name
        self.task_id = task_id
        self.counter = counter
        self.is_gold = is_gold

    def run_filter(self, items, op):
        self.counter["scored"] += len(items)
        idx = np.asarray([it.item_id for it in items], np.float64)
        return np.asarray(
            3.0 * np.sin(idx * 12.9898 + self.task_id * 78.233), np.float32)


@pytest.fixture()
def counting_session():
    counter = {"scored": 0}
    cheap = _CountingFilter("count-cheap", 1, counter)
    gold = _CountingFilter("count-gold", 2, counter, is_gold=True)
    sess = Session(backend=OracleBackend(lambda op: [cheap, gold]),
                   planner=FAST, sample_frac=0.5)
    return sess, counter


def test_stream_yields_before_final_partition(counting_session):
    """Incremental delivery: the first partition must arrive while later
    partitions still have unscored work left."""
    sess, counter = counting_session
    ds = make_dataset("stream", 60, seed=2)
    frame = (sess.frame(ds.items)
             .sem_filter("count me", task_id=1)
             .with_guarantees(recall=0.7, precision=0.7))
    frame.plan()                                  # profiling happens here
    scored_after_plan = counter["scored"]

    stream = frame.stream(partition_size=10, coalesce=1,
                          dispatcher="inline")
    first = next(stream)
    scored_at_first_yield = counter["scored"]
    parts = [first] + list(stream)
    scored_total = counter["scored"]

    assert first.index == 0 and first.lo == 0
    assert len(parts) == 6
    # partition 0 was delivered before the later partitions were scored
    assert scored_after_plan < scored_at_first_yield < scored_total
    # and the stream result still matches a fresh execute()
    res = frame.execute(partition_size=10, coalesce=1, dispatcher="inline")
    np.testing.assert_array_equal(
        np.concatenate([p.accepted for p in parts]), res.accepted)


def test_stream_close_abandons_execution(counting_session):
    sess, counter = counting_session
    ds = make_dataset("close", 40, seed=4)
    frame = (sess.frame(ds.items)
             .sem_filter("count me", task_id=1)
             .with_guarantees(recall=0.7, precision=0.7))
    frame.plan()
    stream = frame.stream(partition_size=8, coalesce=1, dispatcher="inline")
    next(stream)
    scored_at_close = counter["scored"]
    stream.close()
    assert counter["scored"] == scored_at_close   # nothing ran after close
    with pytest.raises(RuntimeError):
        _ = stream.result


# ---------------------------------------------------------------------------
# exact KV-bytes telemetry (engine-backed; profiles already built)
# ---------------------------------------------------------------------------

def test_kv_bytes_parity_across_dispatchers(world):
    """KV-bytes accounting must be exact under concurrent dispatch: the
    counter is thread-scoped and each tuple's cache shard is loaded
    exactly once per stage that scores it, so per-stage kv_bytes are
    bit-identical across inline / threads / sharded — the old
    process-global counter double-counted overlapping flushes.

    The device-resident profile cache is disabled for this test: cache
    hits intentionally skip loading (and so don't count kv_bytes), which
    would zero out the counters on every run after the first."""
    ds, sess = world
    frame = _frame(sess, ds)
    eng = sess.engine
    dc0 = eng.device_cache
    eng.device_cache = False
    eng.device_cache_clear()
    try:
        by_disp = {}
        for disp in ("inline", "threads:3", "sharded:2"):
            res = frame.execute(partition_size=30, dispatcher=disp)
            by_disp[disp] = {(s.logical_idx, s.stage, s.op_name): s.kv_bytes
                             for s in res.stage_stats}
            # engine-backed LLM stages must actually touch the cache store
            assert sum(by_disp[disp].values()) > 0, disp
        ref = by_disp["inline"]
        for disp in ("threads:3", "sharded:2"):
            assert by_disp[disp] == ref, f"kv_bytes drifted under {disp}"
    finally:
        eng.device_cache = dc0


# ---------------------------------------------------------------------------
# corpus memo keys survive GC (no id() reuse)
# ---------------------------------------------------------------------------

class _KeylessItem:
    """Corpus item without an item_id: exercises the object-token path."""

    def __init__(self, idx):
        self.idx = idx
        self.row = {}
        self.tokens = [idx % 7]


class _IdxFilter(PhysicalOperator):
    """Scores by `it.idx` (no item_id needed), counting scored tuples."""
    uses_llm = False

    def __init__(self, name, task_id, counter, is_gold=False):
        self.name = name
        self.task_id = task_id
        self.counter = counter
        self.is_gold = is_gold

    def run_filter(self, items, op):
        self.counter["scored"] += len(items)
        idx = np.asarray([it.idx for it in items], np.float64)
        return np.asarray(
            3.0 * np.sin(idx * 12.9898 + self.task_id * 78.233), np.float32)


def test_corpus_key_not_recycled_after_gc():
    """Two distinct corpora must never share a memo key, even when GC
    frees the first and CPython hands its object ids to the second —
    id()-based keys silently served corpus A's plan for corpus B."""
    import gc
    counter = {"scored": 0}
    cheap = _IdxFilter("idx-cheap", 1, counter)
    gold = _IdxFilter("idx-gold", 2, counter, is_gold=True)
    sess = Session(backend=OracleBackend(lambda op: [cheap, gold]),
                   planner=FAST, sample_frac=0.5)

    def make_corpus():
        return [_KeylessItem(i) for i in range(24)]

    a = make_corpus()
    key_a = sess._corpus_key(a)
    q = Query([SemFilter("count me", 1)],
              target_recall=0.7, target_precision=0.7)
    sess.plan(q, a)
    scored_after_a = counter["scored"]
    assert scored_after_a > 0

    del a
    gc.collect()
    b = make_corpus()                 # same length, same lead tokens —
    key_b = sess._corpus_key(b)       # ids may be recycled by CPython
    assert key_a != key_b
    sess.plan(q, b)                   # must re-profile, not reuse A's plan
    assert counter["scored"] > scored_after_a
    # stable across repeated calls for the *same* corpus (memo works)
    assert sess._corpus_key(b) == key_b
    assert sess.plan(q, b) is sess.plan(q, b)


def test_object_tokens_stable_per_object():
    sess = Session(backend=OracleBackend(
        lambda op: [_IdxFilter("f", 1, {"scored": 0}, is_gold=True)]))
    items = [_KeylessItem(i) for i in range(4)]
    toks = [sess._object_token(it) for it in items]
    assert len(set(toks)) == len(items)            # distinct objects
    assert toks == [sess._object_token(it) for it in items]  # stable


# ---------------------------------------------------------------------------
# engine pools: config validation (no engine build — cheap)
# ---------------------------------------------------------------------------

def test_legacy_config_compiles_to_default_engine_spec():
    """The back-compat shim: flat fields become exactly one spec named
    "default" carrying every flat value."""
    cfg = SessionConfig(models=("sm",), sm_ratios=(0.5, 0.0),
                        lg_ratios=(0.3,), include_cheap=False,
                        profile_ratios=(0.0, 0.5), prefill_batch=8,
                        memory_budget_bytes=1e9, max_batch=32, model_seed=7,
                        cache_dir="/tmp/nowhere")
    specs = cfg.resolved_engines()
    assert len(specs) == 1
    spec = specs[0]
    assert spec.name == "default"
    assert spec.models == ("sm",)
    assert spec.sm_ratios == (0.5, 0.0) and spec.lg_ratios == (0.3,)
    assert spec.include_cheap is False
    assert spec.profile_ratios == (0.0, 0.5)
    assert spec.prefill_batch == 8
    assert spec.memory_budget_bytes == 1e9 and spec.max_batch == 32
    assert spec.model_seed == 7 and spec.cache_dir == "/tmp/nowhere"
    assert spec.ladder() == cfg.ladder()
    # a single model serves both tiers
    assert spec.sm_model == "sm" and spec.lg_model == "sm"


def test_engine_config_validation():
    # empty pool is an error (omit `engines` for the legacy form)
    with pytest.raises(ValueError, match="no engines"):
        SessionConfig(engines=())
    # duplicate engine names
    with pytest.raises(ValueError, match="duplicate"):
        SessionConfig(engines=(EngineSpec("a"), EngineSpec("a")))
    # gold engine must be declared
    with pytest.raises(ValueError, match="gold_engine"):
        SessionConfig(engines=(EngineSpec("a"),), gold_engine="b")
    # ... also under the legacy shim (only "default" exists)
    with pytest.raises(ValueError, match="gold_engine"):
        SessionConfig(gold_engine="a")
    assert SessionConfig(gold_engine="default").gold_engine == "default"
    # spec-level validation fires at construction, not first use
    with pytest.raises(ValueError, match="non-empty"):
        EngineSpec("")
    with pytest.raises(ValueError, match="'/'"):
        EngineSpec("a/b")
    with pytest.raises(ValueError, match="models"):
        EngineSpec("a", models=())
    with pytest.raises(ValueError, match="cost_scale"):
        EngineSpec("a", cost_scale=-1.0)
    with pytest.raises(ValueError, match="affinity"):
        EngineSpec("a", dispatcher="sharded:2")
    with pytest.raises(ValueError, match="positive"):
        EngineSpec("a", dispatcher=0)


def test_pool_backend_validation():
    from repro.runtime import OracleBackend, PoolBackend

    def reg(op):
        return [_IdxFilter("f", 1, {"scored": 0}, is_gold=True)]

    with pytest.raises(ValueError, match="at least one"):
        PoolBackend([])
    with pytest.raises(ValueError, match="duplicate"):
        PoolBackend([("a", OracleBackend(reg)), ("a", OracleBackend(reg))])
    with pytest.raises(ValueError, match="gold engine"):
        PoolBackend([("a", OracleBackend(reg))], gold="b")

    pool = PoolBackend([("a", OracleBackend(reg))])
    op = SemFilter("f", 1)
    # an operator referencing an unknown engine fails at resolve time
    # with a ValueError naming the pool's engines — not deep in a flush
    with pytest.raises(ValueError, match="unknown engine 'b'"):
        pool.resolve(op, "b/f")
    # unknown op on a known engine stays a KeyError (name typo, not a
    # routing error)
    with pytest.raises(KeyError):
        pool.resolve(op, "a/nope")
    assert pool.member("a") is pool.members["a"]
    with pytest.raises(ValueError, match="unknown engine"):
        pool.member("b")


# ---------------------------------------------------------------------------
# top-level package surface
# ---------------------------------------------------------------------------

def test_repro_reexports():
    assert repro.Session is Session
    assert repro.SessionConfig is SessionConfig
    assert repro.PlannerConfig is PlannerConfig
    assert repro.EngineSpec is EngineSpec
    from repro.api import SemFrame
    assert repro.SemFrame is SemFrame
    assert "Session" in dir(repro)
    with pytest.raises(AttributeError):
        repro.not_a_symbol
