"""Planner + executor end-to-end on the planted corpus, plus baselines."""
import numpy as np
import pytest

from repro.cache.store import CacheStore
from repro.core import (PlannerConfig, Query, RelFilter, SemFilter, SemMap,
                        evaluate_vs_gold, execute_plan, plan_query)
from repro.core.baselines import (plan_lotus, plan_pareto_cascades,
                                  plan_stretto_local)
from repro.core.physical import PhysicalPlan, PhysicalPlanStage
from repro.data.synthetic import (make_dataset, make_planted_params,
                                  planted_config)
from repro.serving.engine import ServingEngine
from repro.serving.operators import make_registry

FAST = PlannerConfig(steps=150, restarts=2, snapshots=3)


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    ds = make_dataset("t", 160, seed=5)
    store = CacheStore(str(tmp_path_factory.mktemp("cache")))
    eng = ServingEngine(store)
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        eng.register_model(size, cfg, make_planted_params(cfg, seed=1))
        eng.build_profiles(size, ds.items, ratios=[0.0, 0.3, 0.5, 0.8],
                           prefill_batch=40)
    registry = make_registry(eng)
    return ds, registry


def _gold_plan(query, registry):
    stages = []
    for li, op in enumerate(query.semantic_ops):
        ops = registry(op)
        stages.append(PhysicalPlanStage(
            li, 0, ops[-1].name, 0.0, 0.0,
            op.__class__.__name__ == "SemMap", True, 1.0))
    return PhysicalPlan(stages, [], 0.0, 1.0, 1.0, True)


def test_plan_and_execute_meets_targets(world):
    ds, registry = world
    q = Query([SemFilter("f1", 1), SemFilter("f4", 4)],
              target_recall=0.7, target_precision=0.7)
    gold = execute_plan(_gold_plan(q, registry), q, ds.items, registry)
    plan = plan_query(q, ds.items, registry, FAST, sample_frac=0.3)
    res = execute_plan(plan, q, ds.items, registry)
    m = evaluate_vs_gold(res, gold, q.semantic_ops)
    if plan.feasible:
        # executed quality should respect the planner's (credible) bounds
        # most of the time; being a statistical guarantee, leave headroom
        assert m["recall"] >= 0.55
        assert m["precision"] >= 0.55
    # cost check on the deterministic LLM-tuple count, not wall clock —
    # in-process timing is load/order sensitive (jit compiles land in the
    # first measured batch) and flakes under -x on shared runners
    assert res.n_llm_tuples <= gold.n_llm_tuples * 1.5


def test_relational_pullup(world):
    ds, registry = world
    q = Query([SemFilter("f2", 2), RelFilter("category", "==", "news")],
              target_recall=0.6, target_precision=0.6)
    plan = plan_query(q, ds.items, registry, FAST, sample_frac=0.3)
    assert len(plan.relational) == 1
    res = execute_plan(plan, q, ds.items, registry)
    cats = np.array([it.row["category"] == "news" for it in ds.items])
    assert not (res.accepted & ~cats).any()


def test_map_pipeline(world):
    ds, registry = world
    q = Query([SemMap("extract v3", 3)], target_recall=0.7,
              target_precision=0.7)
    gold = execute_plan(_gold_plan(q, registry), q, ds.items, registry)
    plan = plan_query(q, ds.items, registry, FAST, sample_frac=0.3)
    res = execute_plan(plan, q, ds.items, registry)
    m = evaluate_vs_gold(res, gold, q.semantic_ops)
    assert m["recall"] > 0.5


def test_lotus_baseline_structure(world):
    ds, registry = world
    q = Query([SemFilter("f1", 1), SemFilter("f2", 2)],
              target_recall=0.7, target_precision=0.7)
    plan = plan_lotus(q, ds.items, registry, sample_frac=0.3)
    # 2 logical ops x (small + gold)
    assert len(plan.stages) == 4
    assert sum(s.is_gold for s in plan.stages) == 2
    res = execute_plan(plan, q, ds.items, registry)
    assert res.accepted.dtype == bool


def test_pareto_baseline_runs(world):
    ds, registry = world
    q = Query([SemFilter("f5", 5)], target_recall=0.6,
              target_precision=0.6)
    plan = plan_pareto_cascades(q, ds.items, registry, sample_frac=0.3)
    res = execute_plan(plan, q, ds.items, registry)
    assert res.runtime_s > 0


def test_stretto_local_ablation(world):
    ds, registry = world
    q = Query([SemFilter("f1", 1), SemFilter("f6", 6)],
              target_recall=0.6, target_precision=0.6)
    plan = plan_stretto_local(q, ds.items, registry, FAST, sample_frac=0.3)
    res = execute_plan(plan, q, ds.items, registry)
    assert res.runtime_s > 0
