import os
import sys

# tests see the real device count (1); only the dry-run forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The device-resident profile cache intentionally skips kv_bytes on hits,
# which would make the suite's many repeated-execution / schedule-parity
# assertions depend on test ordering. Default it off for the suite;
# dedicated device-cache tests enable it explicitly per engine.
os.environ.setdefault("STRETTO_DEVICE_CACHE", "0")
