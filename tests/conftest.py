import gc
import os
import sys

import pytest

# tests see the real device count (1); only the dry-run forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The device-resident profile cache intentionally skips kv_bytes on hits,
# which would make the suite's many repeated-execution / schedule-parity
# assertions depend on test ordering. Default it off for the suite;
# dedicated device-cache tests enable it explicitly per engine.
os.environ.setdefault("STRETTO_DEVICE_CACHE", "0")


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_mmap_growth():
    """Every XLA CPU executable holds ~3 anonymous mappings (code /
    rodata / data), a single engine-heavy module compiles hundreds, and
    the kernel's default vm.max_map_count is 65530 — a full one-process
    suite run ends within a few percent of the ceiling and segfaults in
    LLVM ("Cannot allocate memory") when it crosses. Dropping the
    compiled-executable caches between modules releases those mappings
    (measured: 3054 -> 537 after one module); jitted callables simply
    recompile on next use, so only wall time is affected. Clear only
    when genuinely near the ceiling to keep cross-module cache reuse."""
    yield
    try:
        with open("/proc/self/maps") as f:
            n = sum(1 for _ in f)
    except OSError:                    # non-linux: no limit to manage
        return
    if n > 30_000:
        import jax
        jax.clear_caches()
        gc.collect()
