import os
import sys

# tests see the real device count (1); only the dry-run forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
