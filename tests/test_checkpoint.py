import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as CKPT
from repro.training.loop import LoopConfig, run_training
from repro.training.optimizer import (adamw_init, adamw_update,
                                      compress_grads, decompress_grads)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jax.random.normal(k, (3,)).astype(jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    t = _tree()
    CKPT.save_checkpoint(str(tmp_path), 7, t)
    restored, step = CKPT.restore_checkpoint(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        CKPT.save_checkpoint(str(tmp_path), s, t, keep_last=2)
    assert CKPT.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_corruption_detected(tmp_path):
    t = _tree()
    path = CKPT.save_checkpoint(str(tmp_path), 1, t)
    victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, victim))
    np.save(os.path.join(path, victim), arr + 1)
    with pytest.raises(IOError):
        CKPT.restore_checkpoint(str(tmp_path), t)


def test_no_partial_checkpoint_on_crash(tmp_path):
    """tmp dirs from interrupted writes must never be listed as steps."""
    os.makedirs(tmp_path / ".tmp_ckpt_dead")
    assert CKPT.latest_step(str(tmp_path)) is None


def test_loop_resume_and_failure_injection(tmp_path):
    """Train 10 steps with a ckpt every 4; crash at step 7; rerun: the loop
    resumes from step 4 (not 0) and finishes; injected transient failures
    are retried."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.training.train_step import make_train_step

    cfg = get_config("granite-8b").reduced(n_layers=1, d_model=32,
                                           n_heads=2, n_kv_heads=2,
                                           d_head=16, d_ff=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = make_train_step(cfg, remat=False)
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (2, 16)), jnp.int32)} for _ in range(12)]

    boom = {"armed": True}

    def injector(step):
        if step == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated preemption")

    cfg_loop = LoopConfig(total_steps=10, ckpt_every=4,
                          ckpt_dir=str(tmp_path))
    p1, o1, rep1 = run_training(step_fn, params, opt, batches, cfg_loop,
                                failure_injector=injector)
    assert rep1.steps_run == 10
    assert rep1.retries == 1            # the injected failure was retried

    # second run resumes from the last checkpoint, not from scratch
    p2, o2, rep2 = run_training(step_fn, params, opt, batches,
                                LoopConfig(total_steps=10, ckpt_every=4,
                                           ckpt_dir=str(tmp_path)))
    assert rep2.resumed_from == 8
    assert rep2.steps_run == 2


def test_elastic_restore_with_shardings(tmp_path):
    """Restore under a different device layout (1-device mesh here; the
    same code path reshards to any production mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    CKPT.save_checkpoint(str(tmp_path), 3, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _ = CKPT.restore_checkpoint(str(tmp_path), t,
                                          shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))
    assert restored["w"].sharding == shardings["w"]


def test_gradient_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32)}
    q, scales, resid = compress_grads(g, None)
    assert q["w"].dtype == jnp.int8
    deq = decompress_grads(q, scales)
    err1 = float(jnp.max(jnp.abs(deq["w"] - g["w"])))
    assert err1 < float(scales["w"]) + 1e-6
    # residual carries exactly the quantization error
    np.testing.assert_allclose(np.asarray(resid["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-6)


def test_adamw_decreases_loss():
    rng = np.random.default_rng(0)
    w_true = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    y = x @ w_true
    params = {"w": jnp.zeros(8)}
    opt = adamw_init(params)

    def loss_fn(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    l0 = float(loss_fn(params))
    for _ in range(60):
        g = jax.grad(loss_fn)(params)
        params, opt = adamw_update(g, opt, params, lr=0.05,
                                   weight_decay=0.0)
    assert float(loss_fn(params)) < 0.1 * l0
