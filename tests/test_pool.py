"""Engine pools: PoolBackend routing, planner placement, per-engine
telemetry attribution, and back-compat with the flat single-engine config.

Three invariant families:

Parity — a one-engine PoolBackend must decide bit-identically to the
bare KVCacheBackend it wraps (stage lists equal modulo the ``engine/``
name prefix), across inline / threads / sharded dispatchers; and the
legacy flat SessionConfig must plan + decide identically to the explicit
single-EngineSpec declaration (the shim is a pure compilation step).

Placement — a two-tier pool (fast sm engine + accurate lg engine owning
gold) plans end to end, the plan mixes engines across stages, and EXPLAIN
grows the engine column.

Attribution — per-stage StageStats carry the owning engine; grouping by
it partitions kv_bytes / n_llm_calls / wall_s exactly (verified against
each engine's own CacheStore byte counter), and EXPLAIN ANALYZE reports
the same per-engine totals.
"""
import numpy as np
import pytest

from repro.api import EngineSpec, Session, SessionConfig
from repro.core import PlannerConfig, plan_query
from repro.data.synthetic import make_dataset
from repro.runtime import (DEFAULT_COALESCE, PoolBackend,
                           stage_stats_by_engine, run_plan)

from test_api import _FakeClock

FAST = PlannerConfig(steps=120, restarts=2, snapshots=2)

DISPATCHERS = ("inline", "threads:2", "sharded:2")


# ---------------------------------------------------------------------------
# two-tier pool world: fast sm engine + accurate lg engine (owns gold)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pool_world(tmp_path_factory):
    ds = make_dataset("pool", 90, seed=7)
    session = Session(SessionConfig(
        engines=(
            EngineSpec("fast", models=("sm",),
                       sm_ratios=(0.8, 0.5), lg_ratios=(),
                       cache_dir=str(tmp_path_factory.mktemp("fast"))),
            EngineSpec("accurate", models=("lg",),
                       sm_ratios=(), lg_ratios=(0.5,), include_cheap=False,
                       cache_dir=str(tmp_path_factory.mktemp("accurate"))),
        ),
        gold_engine="accurate",
        planner=FAST, sample_frac=0.35, partition_size=40))
    session.prepare(ds.items)
    yield ds, session
    session.close()


def _frame(sess, ds):
    return (sess.frame(ds.items)
            .sem_filter("f1", 1)
            .sem_map("extract v2", 2)
            .with_guarantees(recall=0.7, precision=0.7))


def test_pool_candidates_contract(pool_world):
    """Union candidates: engine-tagged, unique names, cost-ordered,
    exactly one gold (the gold engine's), last."""
    ds, sess = pool_world
    frame = _frame(sess, ds)
    for op in frame.to_query().semantic_ops:
        cands = sess.backend.candidates(op)
        names = [c.name for c in cands]
        assert len(set(names)) == len(names)
        assert all("/" in n for n in names)
        assert all(c.engine_name in ("fast", "accurate") for c in cands)
        golds = [c for c in cands if c.is_gold]
        assert golds == [cands[-1]]
        assert cands[-1].engine_name == "accurate"
        costs = [c.cost_model() for c in cands[:-1]]
        assert costs == sorted(costs)


def test_plan_mixes_engines_and_explain_column(pool_world):
    ds, sess = pool_world
    frame = _frame(sess, ds)
    plan = frame.plan()
    engines = {st.engine for st in plan.stages}
    # the planted two-tier workload must place stages on both engines
    assert engines == {"fast", "accurate"}
    # gold stages live on the gold engine
    for st in plan.stages:
        assert st.op_name.startswith(st.engine + "/")
        if st.is_gold:
            assert st.engine == "accurate"
    rep = frame.explain()
    assert [s.engine for s in rep.stages] == [st.engine
                                              for st in plan.stages]
    text = rep.render()
    assert "engine" in text and "fast" in text and "accurate" in text
    assert all("engine" in row for row in rep.rows())


def test_per_engine_attribution_sums_exactly(pool_world):
    """Per-stage engine tags partition the run's telemetry exactly: the
    per-engine groups sum to the session totals, and each engine's KV
    bytes match its own cache store's counter delta."""
    ds, sess = pool_world
    frame = _frame(sess, ds)
    stores = {name: eng.store for name, eng in sess.engines.items()}
    before = {name: st.bytes_loaded for name, st in stores.items()}
    res = frame.execute(dispatcher="inline")
    deltas = {name: st.bytes_loaded - before[name]
              for name, st in stores.items()}

    per_engine = res.engine_totals()
    assert set(per_engine) <= {"fast", "accurate"}
    # exact partition of the run totals
    assert sum(d["kv_bytes"] for d in per_engine.values()) \
        == sum(s.kv_bytes for s in res.stage_stats)
    assert sum(d["n_llm_calls"] for d in per_engine.values()) \
        == res.n_llm_tuples
    assert sum(d["n_tuples"] for d in per_engine.values()) \
        == sum(s.n_tuples for s in res.stage_stats)
    # each engine's stage kv_bytes equal its own store's loads
    for name, delta in deltas.items():
        assert per_engine.get(name, {"kv_bytes": 0})["kv_bytes"] == delta
    # the accurate tier did real LLM work in this workload
    assert per_engine["accurate"]["kv_bytes"] > 0
    # every executed stage carries a tag consistent with its op name
    for s in res.stage_stats:
        assert s.op_name.startswith(s.engine + "/")
    # EXPLAIN ANALYZE reports the same per-engine totals
    rep = res.explain_analyze()
    assert {e: (t, k) for e, _, t, _, k in rep.measured_engines} \
        == {e: (d["n_tuples"], d["kv_bytes"])
            for e, d in per_engine.items()}
    text = rep.render()
    assert "engine accurate:" in text and "engine fast:" in text


@pytest.mark.parametrize("dispatcher", DISPATCHERS)
def test_pool_execution_parity_across_dispatchers(pool_world, dispatcher):
    ds, sess = pool_world
    frame = _frame(sess, ds)
    ref = frame.execute(dispatcher="inline")
    res = frame.execute(dispatcher=dispatcher, partition_size=23)
    np.testing.assert_array_equal(res.accepted, ref.accepted)
    for li in ref.map_values:
        np.testing.assert_array_equal(res.map_values[li],
                                      ref.map_values[li])
    # per-(engine, stage) counters are schedule-invariant too
    key = lambda s: (s.engine, s.logical_idx, s.stage, s.op_name)
    ref_kv = {key(s): (s.kv_bytes, s.n_tuples, s.n_llm_calls)
              for s in ref.stage_stats}
    got_kv = {key(s): (s.kv_bytes, s.n_tuples, s.n_llm_calls)
              for s in res.stage_stats}
    assert got_kv == ref_kv


def test_engine_affinity_dispatcher_parity(pool_world):
    """Per-engine thread affinity (EngineSpec.dispatcher) routes flushes
    to dedicated pools without changing a single decision."""
    from repro.runtime import ThreadPoolDispatcher
    ds, sess = pool_world
    frame = _frame(sess, ds)
    ref = frame.execute(dispatcher="inline")
    disp = ThreadPoolDispatcher(2, engine_workers={"fast": 1,
                                                   "accurate": 2})
    res = frame.execute(dispatcher=disp)
    disp.close()
    np.testing.assert_array_equal(res.accepted, ref.accepted)
    for li in ref.map_values:
        np.testing.assert_array_equal(res.map_values[li],
                                      ref.map_values[li])


def test_session_builds_affinity_dispatcher():
    """A 'threads' session default + EngineSpec.dispatcher hints resolve
    to one session-owned ThreadPoolDispatcher with per-engine pools."""
    from repro.runtime import ThreadPoolDispatcher
    cfg = SessionConfig(
        engines=(EngineSpec("a", dispatcher=2),
                 EngineSpec("b", dispatcher="threads:3")),
        dispatcher="threads:2")
    sess = Session(cfg, backend=lambda op: [])   # no engine build needed
    disp = sess._default_dispatcher()
    assert isinstance(disp, ThreadPoolDispatcher)
    assert disp.engine_workers == {"a": 2, "b": 3}
    assert disp.n_workers == 2
    assert sess._default_dispatcher() is disp    # built once, reused
    sess.close()                                  # closes the dispatcher
    # without affinity hints the spec passes through untouched
    sess2 = Session(SessionConfig(dispatcher="threads:2"),
                    backend=lambda op: [])
    assert sess2._default_dispatcher() == "threads:2"
    sess2.close()


def test_flush_tasks_carry_engine_tag(pool_world):
    """Every FlushTask the executor submits is tagged with the stage's
    owning engine — the hook per-engine dispatch affinity routes on."""
    from repro.runtime import InlineDispatcher
    ds, sess = pool_world
    frame = _frame(sess, ds)

    seen = []

    class Recording(InlineDispatcher):
        def submit(self, task, runner):
            seen.append((task.op_name, task.engine))
            return super().submit(task, runner)

    frame.execute(dispatcher=Recording())
    assert seen
    for op_name, engine in seen:
        assert engine in ("fast", "accurate")
        assert op_name.startswith(engine + "/")


# ---------------------------------------------------------------------------
# one-engine pool == bare backend; flat config == explicit single spec
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def single_world(tmp_path_factory):
    ds = make_dataset("pool-single", 70, seed=11)
    session = Session(SessionConfig(
        cache_dir=str(tmp_path_factory.mktemp("single")),
        profile_ratios=(0.0, 0.8),
        sm_ratios=(0.8, 0.0), lg_ratios=(0.8,),
        planner=FAST, sample_frac=0.4, partition_size=30))
    session.prepare(ds.items)
    yield ds, session
    session.close()


def test_one_engine_pool_bit_identical_to_bare_backend(single_world,
                                                       monkeypatch):
    """A PoolBackend wrapping one engine must plan the same cascade
    (modulo the ``default/`` name prefix) and decide bit-identically to
    the bare KVCacheBackend, across all dispatchers."""
    import repro.runtime.executor as executor_mod
    ds, sess = single_world
    monkeypatch.setattr(executor_mod, "time", _FakeClock())
    q = _frame(sess, ds).to_query()
    pool = PoolBackend([("default", sess.backend)])

    bare_plan = plan_query(q, ds.items, sess.backend, FAST,
                           sample_frac=0.4, seed=0,
                           coalesce=DEFAULT_COALESCE)
    pool_plan = plan_query(q, ds.items, pool, FAST,
                           sample_frac=0.4, seed=0,
                           coalesce=DEFAULT_COALESCE)
    assert [("default/" + st.op_name, st.thr_hi, st.thr_lo, st.is_gold)
            for st in bare_plan.stages] \
        == [(st.op_name, st.thr_hi, st.thr_lo, st.is_gold)
            for st in pool_plan.stages]
    assert all(st.engine == "default" for st in pool_plan.stages)
    assert all(st.engine == "" for st in bare_plan.stages)

    for disp in DISPATCHERS:
        ref = run_plan(bare_plan, q, ds.items, sess.backend,
                       partition_size=30, dispatcher=disp)
        got = run_plan(pool_plan, q, ds.items, pool,
                       partition_size=30, dispatcher=disp)
        np.testing.assert_array_equal(got.accepted, ref.accepted,
                                      err_msg=disp)
        for li in ref.map_values:
            np.testing.assert_array_equal(got.map_values[li],
                                          ref.map_values[li], err_msg=disp)
        assert got.n_llm_tuples == ref.n_llm_tuples, disp
        # same telemetry, same attribution (modulo the engine tag)
        assert [(s.n_tuples, s.n_llm_calls, s.kv_bytes)
                for s in got.stage_stats] \
            == [(s.n_tuples, s.n_llm_calls, s.kv_bytes)
                for s in ref.stage_stats], disp


def test_flat_config_plans_identically_to_explicit_spec(single_world,
                                                        tmp_path_factory,
                                                        monkeypatch):
    """The legacy-flat -> EngineSpec shim is a pure compilation step: an
    explicit single-spec SessionConfig plans the same stages and decides
    bit-identically to the flat form (same models, same ladder, same
    unprefixed operator names)."""
    import repro.runtime.executor as executor_mod
    ds, flat_sess = single_world
    monkeypatch.setattr(executor_mod, "time", _FakeClock())
    spec = flat_sess.config.resolved_engines()[0]
    explicit_sess = Session(SessionConfig(
        engines=(EngineSpec(
            "default", models=spec.models,
            sm_ratios=spec.sm_ratios, lg_ratios=spec.lg_ratios,
            include_cheap=spec.include_cheap,
            profile_ratios=spec.profile_ratios,
            prefill_batch=spec.prefill_batch,
            memory_budget_bytes=spec.memory_budget_bytes,
            max_batch=spec.max_batch, model_seed=spec.model_seed,
            cache_dir=str(tmp_path_factory.mktemp("explicit"))),),
        planner=FAST, sample_frac=0.4, partition_size=30))
    try:
        flat = _frame(flat_sess, ds)
        explicit = _frame(explicit_sess, ds)
        fp, ep = flat.plan(), explicit.plan()
        # a single-spec session keeps the bare backend: identical stage
        # lists, unprefixed names, no engine tags
        assert [(st.op_name, st.thr_hi, st.thr_lo, st.is_gold, st.engine)
                for st in fp.stages] \
            == [(st.op_name, st.thr_hi, st.thr_lo, st.is_gold, st.engine)
                for st in ep.stages]
        fr = flat.execute()
        er = explicit.execute()
        np.testing.assert_array_equal(er.accepted, fr.accepted)
        for li in fr.map_values:
            np.testing.assert_array_equal(er.map_values[li],
                                          fr.map_values[li])
        # single-engine runs report one untagged engine bucket
        assert set(stage_stats_by_engine(fr.stage_stats)) == {""}
    finally:
        explicit_sess.close()
