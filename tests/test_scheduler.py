"""Concurrent query scheduler tests.

Parity — N concurrent queries admitted through the QueryScheduler must
produce bit-identical decisions/map values to running each sequentially,
and each query's StageStats must tile exactly (n_tuples / n_llm_calls /
n_batches per query equal to its solo run), across inline and threads
hub execution and 1- vs 2-engine pools. Cross-query coalescing merges
*batches*, never changes *schedules*, so this is the load-bearing
invariant of the whole subsystem.

Coalescing — K concurrent copies of one query must produce strictly
fewer engine attention dispatches than K solo runs (the merged batches
are real), while decisions stay bit-identical to solo.

Fairness / admission — weighted-fair virtual time orders admission
deterministically; the bounded queue raises SchedulerSaturated instead
of buffering unboundedly.

Tenants — premium tenants pre-warm the engines' device LRU (hits on the
first query), cold tenants evict their rungs after each query.
"""
import threading
import time

import numpy as np
import pytest

from repro.api import EngineSpec, Session, SessionConfig
from repro.core import PlannerConfig
from repro.core.physical import PhysicalOperator
from repro.data.synthetic import make_dataset
from repro.runtime import OracleBackend, backend_engines
from repro.scheduler import (QueryScheduler, SchedulerSaturated, TenantSpec,
                             split_ints, validate_tenants)

FAST = PlannerConfig(steps=120, restarts=2, snapshots=2)
# scheduler tests exercise admission/coalescing, not plan quality — a
# tiny annealer keeps per-test planning time negligible
TINY = PlannerConfig(steps=40, restarts=1, snapshots=2)


# ---------------------------------------------------------------------------
# TenantSpec / split_ints units
# ---------------------------------------------------------------------------

def test_tenant_spec_validation():
    t = TenantSpec("acme", tier="premium")
    assert t.fair_weight == 4.0 and t.warms and not t.evicts
    assert TenantSpec("x", tier="cold").evicts
    assert TenantSpec("x", weight=2.5).fair_weight == 2.5
    assert TenantSpec("x", tier="cold", keep_warm=True).warms
    with pytest.raises(ValueError, match="tier"):
        TenantSpec("x", tier="platinum")
    with pytest.raises(ValueError, match="weight"):
        TenantSpec("x", weight=0.0)
    with pytest.raises(ValueError, match="non-empty"):
        TenantSpec("")
    with pytest.raises(ValueError, match="duplicate"):
        validate_tenants((TenantSpec("a"), TenantSpec("a")))
    with pytest.raises(TypeError):
        validate_tenants(("a",))


def test_split_ints_tiles_exactly():
    for total, sizes in ((10, [3, 3, 4]), (7, [5, 5, 5]), (0, [1, 2]),
                         (13, [1]), (5, [0, 5]), (3, [])):
        out = split_ints(total, sizes)
        assert sum(out) == (total if sizes and sum(sizes) else 0)
        assert len(out) == len(sizes)
        assert all(v >= 0 for v in out)


def test_session_config_validates_tenants():
    cfg = SessionConfig(tenants=(TenantSpec("a"), TenantSpec("b")))
    assert [t.name for t in cfg.tenants] == ["a", "b"]
    with pytest.raises(ValueError, match="duplicate"):
        SessionConfig(tenants=(TenantSpec("a"), TenantSpec("a")))


# ---------------------------------------------------------------------------
# recording-operator world (no engine): fast, observable flushes
# ---------------------------------------------------------------------------

class _LogFilter(PhysicalOperator):
    uses_llm = True

    def __init__(self, name, task_id, log, lock, is_gold=False):
        self.name = name
        self.task_id = task_id
        self.log = log
        self.lock = lock
        self.is_gold = is_gold

    def run_filter(self, items, op):
        idx = np.asarray([it.item_id for it in items], np.float64)
        with self.lock:
            self.log.append(len(items))
        return np.asarray(
            3.0 * np.sin(idx * 12.9898 + op.task_id * 78.233), np.float32)


def _oracle_session():
    log, lock = [], threading.Lock()
    cheap = _LogFilter("cheap", 1, log, lock)
    gold = _LogFilter("gold", 2, log, lock, is_gold=True)
    sess = Session(backend=OracleBackend(lambda op: [cheap, gold]),
                   planner=TINY, sample_frac=0.5)
    return sess, log


def _frames(sess, ds, tasks=(1, 1, 2, 1)):
    return [(sess.frame(ds.items)
             .sem_filter(f"f{t}", task_id=t)
             .with_guarantees(recall=0.7, precision=0.7))
            for t in tasks]


@pytest.mark.parametrize("execute", ["inline", "threads:2"])
def test_concurrent_parity_oracle(execute):
    """N concurrent queries == their sequential runs, bit for bit, with
    exactly-tiling per-query stats, under both hub execution modes."""
    sess, log = _oracle_session()
    ds = make_dataset("sched-par", 90, seed=3)
    frames = _frames(sess, ds)
    solo = [f.execute() for f in frames]
    for f in frames:
        f.plan()                       # memoize plans: drivers admit fast
    with QueryScheduler(sess, max_concurrent=4, paused=True,
                        execute=execute) as sched:
        handles = [sched.submit(f) for f in frames]
        sched.resume()
        results = [h.result(timeout=120) for h in handles]
        stats = sched.stats()
    for r, s in zip(results, solo):
        np.testing.assert_array_equal(r.accepted, s.accepted)
        assert set(r.map_values) == set(s.map_values)
        for li in s.map_values:
            np.testing.assert_array_equal(r.map_values[li],
                                          s.map_values[li])
        # per-query stats tile exactly: counts identical to the solo run
        key = lambda sg: (sg.logical_idx, sg.stage, sg.op_name)
        mine = {key(sg): sg for sg in r.stage_stats}
        ref = {key(sg): sg for sg in s.stage_stats}
        assert set(mine) == set(ref)
        for k, sg in mine.items():
            assert sg.n_tuples == ref[k].n_tuples
            assert sg.n_llm_calls == ref[k].n_llm_calls
            assert sg.n_batches == ref[k].n_batches
    # the hub really executed every flush exactly once
    assert stats["n_flushes"] >= stats["n_calls"] > 0


def test_concurrent_copies_merge_flushes():
    """K concurrent copies of one query coalesce: fewer merged engine
    calls than total flushes, and every query's flushes ride shared
    batches whose width is the concatenation of the copies."""
    sess, log = _oracle_session()
    ds = make_dataset("sched-merge", 60, seed=5)
    frame = _frames(sess, ds, tasks=(1,))[0]
    solo = frame.execute()
    frame.plan()
    log.clear()
    K = 4
    with QueryScheduler(sess, max_concurrent=K, paused=True) as sched:
        handles = [sched.submit(frame) for _ in range(K)]
        sched.resume()
        results = [h.result(timeout=120) for h in handles]
        stats = sched.stats()
    for r in results:
        np.testing.assert_array_equal(r.accepted, solo.accepted)
    assert stats["n_merged_calls"] >= 1
    assert stats["n_calls"] < stats["n_flushes"]
    assert stats["saved_calls"] == stats["n_flushes"] - stats["n_calls"]
    # per-query telemetry observed the sharing
    assert any(r.sched.shared_batches > 0 for r in results)
    merged = [r for r in results if r.sched.shared_batches]
    for r in merged:
        assert r.sched.shared_width > r.sched.n_batches  # > own width


def test_weighted_fair_admission_order():
    """With one driver slot, admission replays weighted-fair virtual
    time: all tenants start at vtime 0 (arrival order breaks ties), and
    each completed query advances its tenant by tuples/weight — so the
    light tenant's second query waits until the heavy tenant's vtime
    catches up."""
    sess, _ = _oracle_session()
    ds = make_dataset("sched-fair", 40, seed=7)
    frame = _frames(sess, ds, tasks=(1,))[0]
    frame.plan()
    tenants = (TenantSpec("heavy", weight=4.0),
               TenantSpec("light", weight=1.0))
    with QueryScheduler(sess, max_concurrent=1, paused=True,
                        tenants=tenants) as sched:
        # interleaved submissions: h0 l1 h2 l3
        hs = [sched.submit(frame, tenant=t)
              for t in ("heavy", "light", "heavy", "light")]
        sched.resume()
        sched.drain(timeout=120)
        stats = sched.stats()
    order = sorted(range(4), key=lambda i: hs[i].admit_t)
    # q0 (heavy, tie at 0 broken by arrival) then q1 (light, vtime 0);
    # now heavy=n/4 < light=n, so q2 (heavy) before q3 (light)
    assert order == [0, 1, 2, 3]
    n = stats["tenants"]["heavy"]["n_tuples"]
    assert stats["tenants"]["heavy"]["vtime"] == pytest.approx(n / 4.0)
    assert stats["tenants"]["light"]["vtime"] == pytest.approx(
        stats["tenants"]["light"]["n_tuples"] / 1.0)


def test_admission_bounds_and_errors():
    sess, _ = _oracle_session()
    ds = make_dataset("sched-adm", 30, seed=2)
    frame = _frames(sess, ds, tasks=(1,))[0]
    frame.plan()
    with QueryScheduler(sess, max_concurrent=1, max_queue=2,
                        paused=True) as sched:
        h1 = sched.submit(frame)
        h2 = sched.submit(frame)
        with pytest.raises(SchedulerSaturated):
            sched.submit(frame)
        with pytest.raises(ValueError, match="unknown tenant"):
            sched.submit(frame, tenant="nobody")
        other = Session(backend=OracleBackend(
            lambda op: [_LogFilter("c", 1, [], threading.Lock()),
                        _LogFilter("g", 2, [], threading.Lock(),
                                   is_gold=True)]))
        with pytest.raises(ValueError, match="different Session"):
            sched.submit(other.frame(ds.items).sem_filter("f1", 1))
        other.close()
        sched.resume()
        assert h1.result(timeout=120).accepted is not None
        assert h2.result(timeout=120).accepted is not None
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit(frame)


def test_handle_timeout_and_repr():
    sess, _ = _oracle_session()
    ds = make_dataset("sched-to", 30, seed=9)
    frame = _frames(sess, ds, tasks=(1,))[0]
    frame.plan()
    sched = QueryScheduler(sess, paused=True)
    h = sched.submit(frame)
    assert not h.done() and "queued" in repr(h)
    with pytest.raises(TimeoutError):
        h.result(timeout=0.01)
    sched.resume()
    assert h.result(timeout=120) is not None
    assert h.done() and "done" in repr(h)
    sched.close()


def test_query_error_propagates():
    """A failing operator fails that query's handle — it must not hang
    the hub or poison co-admitted queries."""
    boom = {"on": False}

    class _Bomb(_LogFilter):
        def run_filter(self, items, op):
            if boom["on"]:
                raise RuntimeError("operator exploded")
            return super().run_filter(items, op)

    log, lock = [], threading.Lock()
    # BOTH operators explode: the planner's cascade choice is profiled
    # from measured wall times, so whether a given plan keeps the cheap
    # stage is load-dependent — whichever stage fires first must raise
    cheap = _Bomb("cheap", 1, log, lock)
    gold = _Bomb("gold", 2, log, lock, is_gold=True)
    sess = Session(backend=OracleBackend(lambda op: [cheap, gold]),
                   planner=TINY, sample_frac=0.5)
    ds = make_dataset("sched-err", 40, seed=4)
    frame = _frames(sess, ds, tasks=(1,))[0]
    frame.plan()
    boom["on"] = True
    with QueryScheduler(sess, max_concurrent=2) as sched:
        h = sched.submit(frame)
        with pytest.raises(RuntimeError, match="exploded"):
            h.result(timeout=120)
    boom["on"] = False


def test_explain_analyze_scheduler_footer():
    sess, _ = _oracle_session()
    ds = make_dataset("sched-exp", 40, seed=6)
    frame = _frames(sess, ds, tasks=(1,))[0]
    frame.plan()
    with QueryScheduler(sess, paused=True,
                        tenants=(TenantSpec("acme", tier="premium"),)) \
            as sched:
        hs = [sched.submit(frame, tenant="acme") for _ in range(2)]
        sched.resume()
        reports = [h.result(timeout=120).explain_analyze() for h in hs]
    text = reports[0].render()
    assert "scheduler: tenant=acme (premium)" in text
    assert "queue_wait_s=" in text and "shared_batches=" in text


def test_scheduler_stress_many_small_queries():
    """Many overlapping small queries under the threads hub: all finish
    within the deadline (no deadlock), all bit-identical to solo."""
    sess, _ = _oracle_session()
    ds = make_dataset("sched-stress", 50, seed=11)
    frames = _frames(sess, ds, tasks=(1, 2, 3, 1, 2, 3, 1, 2, 3, 1, 2, 3))
    solo = [f.execute() for f in frames]
    for f in frames:
        f.plan()
    t0 = time.monotonic()
    with QueryScheduler(sess, max_concurrent=6, execute="threads:3",
                        paused=True) as sched:
        handles = [sched.submit(f) for f in frames]
        sched.resume()
        results = [h.result(timeout=180) for h in handles]
    assert time.monotonic() - t0 < 180
    for r, s in zip(results, solo):
        np.testing.assert_array_equal(r.accepted, s.accepted)


# ---------------------------------------------------------------------------
# engine-backed worlds: real coalescing proof + tiered device cache
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_world(tmp_path_factory):
    ds = make_dataset("sched-eng", 48, seed=5)
    session = Session(SessionConfig(
        cache_dir=str(tmp_path_factory.mktemp("cache")),
        profile_ratios=(0.0, 0.8),
        sm_ratios=(0.8, 0.0), lg_ratios=(0.8,),
        planner=TINY, sample_frac=0.35))
    session.prepare(ds.items)
    yield ds, session
    session.close()


def _eng_frame(sess, ds):
    return (sess.frame(ds.items)
            .sem_filter("f1", 1)
            .with_guarantees(recall=0.7, precision=0.7))


def _total_dispatches(sess):
    return sum(e.attn_dispatches for e in backend_engines(sess.backend))


def test_engine_coalescing_reduces_dispatches(engine_world):
    """THE acceptance proof: K concurrent copies of one query drive
    strictly fewer engine attention dispatches than K solo runs, while
    every copy's decisions stay bit-identical to solo."""
    ds, sess = engine_world
    frame = _eng_frame(sess, ds)
    frame.plan()                            # plan+profiling outside count
    base = _total_dispatches(sess)
    solo = frame.execute()
    solo_dispatches = _total_dispatches(sess) - base
    assert solo_dispatches > 0
    K = 3
    base = _total_dispatches(sess)
    with QueryScheduler(sess, max_concurrent=K, paused=True) as sched:
        handles = [sched.submit(frame) for _ in range(K)]
        sched.resume()
        results = [h.result(timeout=600) for h in handles]
        stats = sched.stats()
    merged_dispatches = _total_dispatches(sess) - base
    for r in results:
        np.testing.assert_array_equal(r.accepted, solo.accepted)
    assert merged_dispatches < K * solo_dispatches
    assert stats["n_merged_calls"] >= 1
    # kv accounting still tiles: the K queries' kv_bytes sum to K x solo
    solo_kv = sum(sg.kv_bytes for sg in solo.stage_stats)
    merged_kv = sum(sg.kv_bytes for r in results
                    for sg in r.stage_stats)
    assert merged_kv == K * solo_kv


def test_premium_warm_and_cold_evict(engine_world, tmp_path):
    """Tiered tenants drive the engine device LRU: a premium tenant's
    first query pre-stages its rungs (device-cache hits during the run),
    a cold tenant's query evicts its rungs afterwards."""
    ds = make_dataset("sched-warm", 32, seed=8)
    sess = Session(SessionConfig(
        cache_dir=str(tmp_path / "cache"),
        profile_ratios=(0.0, 0.8),
        sm_ratios=(0.8, 0.0), lg_ratios=(0.8,),
        planner=TINY, sample_frac=0.35,
        device_cache=True,
        tenants=(TenantSpec("vip", tier="premium"),
                 TenantSpec("drifter", tier="cold"))))
    sess.prepare(ds.items)
    try:
        engines = backend_engines(sess.backend)
        assert all(e.device_cache for e in engines)
        frame = _eng_frame(sess, ds)
        frame.plan()
        with sess.scheduler(max_concurrent=1) as sched:
            h0 = sched.submit(frame, tenant="vip")
            r0 = h0.result(timeout=600)
            stats = sched.stats()
            assert stats["tenants"]["vip"]["warm_batches"] > 0
            # warming staged the rungs: the run itself hit the dev LRU
            assert sum(e.dev_cache_hits for e in engines) > 0
            assert sum(len(e._dev_cache) for e in engines) > 0
            h1 = sched.submit(frame, tenant="drifter")
            r1 = h1.result(timeout=600)
            stats = sched.stats()
            assert stats["tenants"]["drifter"]["evictions"] > 0
        np.testing.assert_array_equal(r0.accepted, r1.accepted)
    finally:
        sess.close()


@pytest.fixture(scope="module")
def pool_world(tmp_path_factory):
    ds = make_dataset("sched-pool", 48, seed=7)
    session = Session(SessionConfig(
        engines=(
            EngineSpec("fast", models=("sm",),
                       sm_ratios=(0.8, 0.0), lg_ratios=(),
                       cache_dir=str(tmp_path_factory.mktemp("fast"))),
            EngineSpec("accurate", models=("lg",),
                       sm_ratios=(), lg_ratios=(0.8,),
                       include_cheap=False,
                       cache_dir=str(tmp_path_factory.mktemp("accurate"))),
        ),
        gold_engine="accurate",
        planner=TINY, sample_frac=0.35))
    session.prepare(ds.items)
    yield ds, session
    session.close()


def test_concurrent_parity_two_engine_pool(pool_world):
    """Scheduler parity holds on a 2-engine pool: concurrent queries
    decide bit-identically to sequential, and per-engine flushes still
    coalesce (group keys carry the engine tag, so merging never mixes
    engines)."""
    ds, sess = pool_world
    frame = (sess.frame(ds.items)
             .sem_filter("f1", 1)
             .sem_map("extract v2", 2)
             .with_guarantees(recall=0.7, precision=0.7))
    solo = frame.execute()
    frame.plan()
    with QueryScheduler(sess, max_concurrent=3, paused=True) as sched:
        handles = [sched.submit(frame) for _ in range(3)]
        sched.resume()
        results = [h.result(timeout=600) for h in handles]
        stats = sched.stats()
    for r in results:
        np.testing.assert_array_equal(r.accepted, solo.accepted)
        for li in solo.map_values:
            np.testing.assert_array_equal(r.map_values[li],
                                          solo.map_values[li])
    assert stats["n_calls"] <= stats["n_flushes"]
    # stage stats still carry their owning engine after merging
    engs = {sg.engine for r in results for sg in r.stage_stats}
    assert "fast" in engs or "accurate" in engs


# ---------------------------------------------------------------------------
# hub patience: a slow member must not stall unrelated parked groups
# ---------------------------------------------------------------------------

def test_hub_patience_bounds_slow_member_stall():
    """While a fired group is still executing (a remote member on a bad
    link, say), a group parked AFTER the fire must wait at most the
    patience window — not the straggler's full service time. Under
    "threads" execution the late group overlaps the slow one."""
    from repro.runtime.dispatch import FlushTask
    from repro.scheduler import FlushHub

    log, lock = [], threading.Lock()

    class _SleepFilter(_LogFilter):
        def __init__(self, name, task_id, delay):
            super().__init__(name, task_id, log, lock)
            self.delay = delay

        def run_filter(self, items, op):
            time.sleep(self.delay)
            return super().run_filter(items, op)

    slow = _SleepFilter("slow", 1, 1.2)
    fast = _SleepFilter("fast", 2, 0.0)
    backend = OracleBackend(lambda op: [slow, fast])
    ds = make_dataset("hub-slow", 20, seed=1)
    hub = FlushHub(backend, execute="threads:2", patience_s=0.05)
    elapsed = {}
    errors = []

    def driver(name, op_name, sem, start_delay):
        hub.register()
        try:
            time.sleep(start_delay)
            task = FlushTask(0, sem, op_name, list(ds.items), "")
            t0 = time.monotonic()
            out = hub.submit(name, task).result()
            elapsed[name] = time.monotonic() - t0
            assert len(out.scores) == len(ds.items)
        except BaseException as e:            # surface into the test
            errors.append(e)
        finally:
            hub.unregister()

    from repro.core.logical import SemFilter
    ta = threading.Thread(target=driver,
                          args=("a", "slow", SemFilter("s", 1), 0.0))
    # driver b parks its flush only after a's slow group has fired
    tb = threading.Thread(target=driver,
                          args=("b", "fast", SemFilter("f", 2), 0.3))
    ta.start(), tb.start()
    ta.join(timeout=30), tb.join(timeout=30)
    hub.close()
    assert not errors
    # the fast group waited ~patience, not ~the slow member's 1.2 s
    assert elapsed["b"] < 0.6
    assert elapsed["a"] >= 1.0
    snap = hub.snapshot()
    assert snap["n_calls"] == 2 and snap["n_flushes"] == 2


def test_split_ints_remainder_on_leading_segments():
    """Retry-shaped splits (a sub-batch re-issued at a different width)
    still tile exactly: sum preserved, remainder on the leading
    segments, zero-width segments get zero."""
    assert split_ints(10, [3, 3, 3]) == [4, 3, 3]
    assert split_ints(11, [3, 3, 3]) == [4, 4, 3]
    assert split_ints(1003, [37, 1, 0, 256]) == [127, 3, 0, 873]
    for total, sizes in ((1003, [37, 1, 0, 256]), (97, [64, 1, 64]),
                         (5, [1, 1, 1, 1, 1, 1, 1])):
        out = split_ints(total, sizes)
        assert sum(out) == total
        assert all(v >= 0 for v in out)
        # remainder lands on the leading segments: the split is the
        # floor apportionment plus at most 1 on a leading prefix
        n = sum(sizes)
        floors = [total * s // n for s in sizes]
        bumps = [o - f for o, f in zip(out, floors)]
        assert set(bumps) <= {0, 1}
        assert bumps == sorted(bumps, reverse=True)
