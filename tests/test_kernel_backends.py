"""Backend-selectable attention ops: dispatch rules, interpret-vs-ref
parity (the CPU oracle contract), the fused multi-token query kernel, and
int8 KV dequantization — deterministic sweeps plus hypothesis property
twins (the property tests skip when the optional dep is absent)."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from hypothesis_compat import given, settings, st  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402

KEY = jax.random.PRNGKey(7)
GLOBAL = 1 << 30


def _inputs(seed, B, S, KV, G, dk, dv, lq=None, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    qshape = (B, KV, G, dk) if lq is None else (B, lq, KV, G, dk)
    q = jax.random.normal(ks[0], qshape, jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, dk), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, dv), jnp.float32).astype(dtype)
    lengths = jnp.asarray(
        np.random.default_rng(seed).integers(
            1 if lq is None else (lq or 1), S + 1, B), jnp.int32)
    return q, k, v, lengths


def _quantize(x):
    scale = jnp.max(jnp.abs(x), -1) / 127.0
    q = jnp.round(x / jnp.maximum(scale, 1e-9)[..., None]).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


# ---------------------------------------------------------------------------
# backend resolution
# ---------------------------------------------------------------------------

def test_resolve_backend_precedence(monkeypatch):
    monkeypatch.delenv(ops.ENV_VAR, raising=False)
    assert ops.resolve_backend(None) == "auto"
    assert ops.resolve_backend("") == "auto"
    # env provides the default ...
    monkeypatch.setenv(ops.ENV_VAR, "ref")
    assert ops.resolve_backend(None) == "ref"
    # ... but an explicit argument wins
    assert ops.resolve_backend("interpret") == "interpret"
    # env is read at call time, not import time
    monkeypatch.setenv(ops.ENV_VAR, "interpret")
    assert ops.resolve_backend(None) == "interpret"


def test_resolve_backend_rejects_unknown(monkeypatch):
    with pytest.raises(ValueError, match="backend"):
        ops.resolve_backend("cuda")
    monkeypatch.setenv(ops.ENV_VAR, "nonsense")
    with pytest.raises(ValueError, match="backend"):
        ops.resolve_backend(None)


def test_env_backend_reaches_the_op(monkeypatch):
    """STRETTO_KERNELS routes the actual computation: ref and interpret
    agree numerically but go through different code paths (interpret
    raises on an illegal grid, ref does not)."""
    q, k, v, lengths = _inputs(0, 2, 128, 2, 2, 32, 32)
    monkeypatch.setenv(ops.ENV_VAR, "ref")
    out_ref = ops.decode_attention(q, k, v, lengths)
    monkeypatch.setenv(ops.ENV_VAR, "interpret")
    out_int = ops.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_int),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# interpret-vs-ref parity sweeps (deterministic)
# ---------------------------------------------------------------------------

PARITY_CASES = [
    # (B, S, KV, G, dk, dv, window)   — GQA group counts, ragged lengths
    (2, 256, 2, 4, 64, 64, GLOBAL),
    (3, 128, 1, 8, 32, 32, GLOBAL),   # MQA-style single KV head
    (1, 384, 4, 1, 64, 64, GLOBAL),   # one query per KV head
    (2, 256, 2, 2, 64, 32, GLOBAL),   # dv != dk
    (2, 256, 2, 4, 64, 64, 64),       # sliding window
    (4, 128, 2, 2, 32, 32, 17),       # window not a block multiple
]


@pytest.mark.parametrize("B,S,KV,G,dk,dv,window", PARITY_CASES)
def test_decode_parity(B, S, KV, G, dk, dv, window):
    q, k, v, lengths = _inputs(B + S, B, S, KV, G, dk, dv)
    out = ops.decode_attention(q, k, v, lengths, window=window,
                               backend="interpret")
    want = ops.decode_attention(q, k, v, lengths, window=window,
                                backend="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("B,S,KV,G,dk,dv,window", PARITY_CASES)
def test_decode_parity_int8(B, S, KV, G, dk, dv, window):
    q, k, v, lengths = _inputs(B + S + 1, B, S, KV, G, dk, dv)
    k_q, k_s = _quantize(k)
    v_q, v_s = _quantize(v)
    out = ops.decode_attention(q, k_q, v_q, lengths, window=window,
                               backend="interpret", k_scale=k_s,
                               v_scale=v_s)
    want = ops.decode_attention(q, k_q, v_q, lengths, window=window,
                                backend="ref", k_scale=k_s, v_scale=v_s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    # and the quantization itself stays close to the f32 cache
    f32 = ops.decode_attention(q, k, v, lengths, window=window,
                               backend="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(f32), atol=5e-2)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 8),
       st.integers(0, 2 ** 31), st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_decode_parity_property(B, KV, G, window_exp, seed):
    """Property twin of the sweep: any (B, KV, G, window, lengths) combo
    must agree between interpret and ref."""
    window = max(1, window_exp)
    q, k, v, lengths = _inputs(seed % 10_000, B, 128, KV, G, 32, 32)
    out = ops.decode_attention(q, k, v, lengths, window=window,
                               backend="interpret", block_s=64)
    want = ops.decode_attention(q, k, v, lengths, window=window,
                                backend="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# fused multi-token query kernel
# ---------------------------------------------------------------------------

QUERY_CASES = [
    # (B, S, KV, G, dk, dv, Lq, window)
    (2, 256, 2, 4, 64, 64, 6, GLOBAL),
    (3, 128, 1, 8, 32, 32, 4, GLOBAL),
    (2, 256, 2, 2, 64, 32, 6, GLOBAL),  # dv != dk
    (2, 256, 2, 4, 64, 64, 6, 64),      # sliding window
    (1, 128, 2, 2, 32, 32, 1, GLOBAL),  # Lq=1 degenerate
]


@pytest.mark.parametrize("B,S,KV,G,dk,dv,Lq,window", QUERY_CASES)
def test_query_parity(B, S, KV, G, dk, dv, Lq, window):
    q, k, v, lengths = _inputs(B * S + Lq, B, S, KV, G, dk, dv, lq=Lq)
    out = ops.decode_query_attention(q, k, v, lengths, window=window,
                                     backend="interpret")
    want = ops.decode_query_attention(q, k, v, lengths, window=window,
                                      backend="ref")
    assert out.shape == (B, Lq, KV, G, dv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_query_lq1_matches_decode():
    """A fused call with one query token IS single-token decode."""
    q, k, v, lengths = _inputs(3, 2, 256, 2, 4, 64, 64)
    for backend in ("ref", "interpret"):
        multi = ops.decode_query_attention(q[:, None], k, v, lengths,
                                           backend=backend)
        single = ops.decode_attention(q, k, v, lengths, backend=backend)
        np.testing.assert_allclose(np.asarray(multi[:, 0]),
                                   np.asarray(single), atol=1e-5)


def test_query_masking_exact():
    """Positions beyond each item's length must contribute exactly
    nothing: poison the padding with huge values and compare against a
    clean cache."""
    B, S, KV, G, dk, Lq = 2, 256, 2, 2, 32, 4
    q, k, v, _ = _inputs(11, B, S, KV, G, dk, dk, lq=Lq)
    lengths = jnp.asarray([100, 37], jnp.int32)
    mask = (jnp.arange(S)[None, :, None, None]
            >= lengths[:, None, None, None])
    k_p = jnp.where(mask, 1e9, k)
    v_p = jnp.where(mask, 1e9, v)
    for backend in ("ref", "interpret"):
        clean = ops.decode_query_attention(q, k, v, lengths,
                                           backend=backend)
        poisoned = ops.decode_query_attention(q, k_p, v_p, lengths,
                                              backend=backend)
        np.testing.assert_array_equal(np.asarray(clean),
                                      np.asarray(poisoned))


def test_query_causal_within_window():
    """Inside the fused block, query token i must not see tokens i+1..:
    zeroing the still-future cache rows cannot change row i."""
    B, S, KV, G, dk, Lq = 1, 128, 1, 2, 32, 4
    q, k, v, _ = _inputs(13, B, S, KV, G, dk, dk, lq=Lq)
    lengths = jnp.asarray([64], jnp.int32)   # includes the Lq query rows
    first_pos = 64 - Lq                       # q_pos of query token 0
    k_cut = k.at[:, first_pos + 1:].set(0.0)
    v_cut = v.at[:, first_pos + 1:].set(0.0)
    for backend in ("ref", "interpret"):
        full = ops.decode_query_attention(q, k, v, lengths, backend=backend)
        cut = ops.decode_query_attention(q, k_cut, v_cut, lengths,
                                         backend=backend)
        np.testing.assert_allclose(np.asarray(full[:, 0]),
                                   np.asarray(cut[:, 0]), atol=1e-6)


@pytest.mark.parametrize("B,S,KV,G,dk,dv,Lq,window", QUERY_CASES[:2])
def test_query_parity_int8(B, S, KV, G, dk, dv, Lq, window):
    q, k, v, lengths = _inputs(B + Lq, B, S, KV, G, dk, dv, lq=Lq)
    k_q, k_s = _quantize(k)
    v_q, v_s = _quantize(v)
    out = ops.decode_query_attention(q, k_q, v_q, lengths, window=window,
                                     backend="interpret", k_scale=k_s,
                                     v_scale=v_s)
    want = ops.decode_query_attention(q, k_q, v_q, lengths, window=window,
                                      backend="ref", k_scale=k_s,
                                      v_scale=v_s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@given(st.integers(1, 3), st.integers(1, 6), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_query_parity_property(B, Lq, seed):
    q, k, v, lengths = _inputs(seed % 10_000, B, 128, 2, 2, 32, 32, lq=Lq)
    out = ops.decode_query_attention(q, k, v, lengths, backend="interpret",
                                     block_s=64)
    want = ops.decode_query_attention(q, k, v, lengths, backend="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@given(st.integers(1, 3), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_int8_scale_property(B, seed):
    """Property twin for int8: arbitrary positive per-token scales must
    dequantize identically on both backends."""
    q, k, v, lengths = _inputs(seed % 10_000, B, 128, 2, 2, 32, 32)
    k_q, k_s = _quantize(k)
    v_q, v_s = _quantize(v)
    out = ops.decode_attention(q, k_q, v_q, lengths, backend="interpret",
                               block_s=64, k_scale=k_s, v_scale=v_s)
    want = ops.decode_attention(q, k_q, v_q, lengths, backend="ref",
                                k_scale=k_s, v_scale=v_s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_query_ref_oracle_softmax():
    """decode_query_attention_ref against a from-scratch softmax — the
    oracle itself must be right, not merely self-consistent."""
    B, S, KV, G, dk, Lq = 1, 32, 1, 2, 16, 3
    q, k, v, _ = _inputs(29, B, S, KV, G, dk, dk, lq=Lq)
    lengths = jnp.asarray([20], jnp.int32)
    out = ref.decode_query_attention_ref(q, k, v, lengths)
    qn, kn, vn = (np.asarray(x, np.float64) for x in (q, k, v))
    for li in range(Lq):
        q_pos = 20 - Lq + li
        for h in range(KV):
            for g in range(G):
                s = (kn[0, :, h] @ qn[0, li, h, g]) / np.sqrt(dk)
                s[q_pos + 1:] = -np.inf
                p = np.exp(s - s.max())
                p /= p.sum()
                want = p @ vn[0, :, h]
                np.testing.assert_allclose(np.asarray(out[0, li, h, g]),
                                           want, atol=1e-5)
