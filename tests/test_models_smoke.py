"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs (assignment
requirement), plus decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY, get_config
from repro.models import decode_step, forward, init_params, prefill
from repro.training.optimizer import adamw_init
from repro.training.train_step import train_step

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = sorted(REGISTRY)


def _inputs(cfg, B=2, S=16):
    if cfg.frontend == "none":
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        return {"tokens": toks}
    emb = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return {"embeds": emb, "labels": labels}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    batch = _inputs(cfg)
    logits, _ = forward(params, cfg, tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"))
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_runs(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    opt = adamw_init(params)
    batch = _inputs(cfg)
    new_params, new_opt, loss = train_step(params, opt, batch, cfg,
                                           remat=False)
    assert np.isfinite(float(loss))
    assert int(new_opt.step) == 1
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    params = init_params(cfg, KEY)
    B, S = 2, 12
    batch = _inputs(cfg, B, S + 1)
    if cfg.frontend == "none":
        toks = batch["tokens"]
        full, _ = forward(params, cfg, tokens=toks)
        _, cache = prefill(params, cfg, tokens=toks[:, :S], max_len=S + 4)
        dec, cache = decode_step(params, cfg, cache,
                                 tokens=toks[:, S:S + 1])
    else:
        emb = batch["embeds"]
        full, _ = forward(params, cfg, embeds=emb)
        _, cache = prefill(params, cfg, embeds=emb[:, :S], max_len=S + 4)
        dec, cache = decode_step(params, cfg, cache,
                                 embeds=emb[:, S:S + 1])
    ref = full[:, S]
    err = float(jnp.max(jnp.abs(ref - dec)) /
                (jnp.max(jnp.abs(ref)) + 1e-9))
    assert err < 5e-3, f"{arch}: decode/forward mismatch {err}"
    assert int(cache["lengths"][0]) == S + 1


def test_remat_matches_no_remat():
    cfg = get_config("granite-8b").reduced(dtype="float32")
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    l1, _ = forward(params, cfg, tokens=toks, remat=False)
    l2, _ = forward(params, cfg, tokens=toks, remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_param_count_sanity():
    """Analytic parameter count should match actual tree size (within the
    small terms the formula ignores)."""
    for arch in ("granite-8b", "rwkv6-1.6b", "dbrx-132b"):
        cfg = get_config(arch).reduced()
        params = init_params(cfg, KEY)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.n_params
        assert abs(actual - analytic) / actual < 0.25, arch
