"""Serving fast path: fused one-dispatch flushes, device-resident profile
cache, int8 KV profiles end to end, memory-bounded bucketing, and the
API-level guarantee that flipping STRETTO_KERNELS between the ref oracle
and Pallas interpret mode changes neither query decisions nor the
EXPLAIN ANALYZE telemetry."""
import numpy as np
import pytest

from repro.api import Session, SessionConfig
from repro.cache.store import CacheStore, Profile
from repro.core import PlannerConfig
from repro.data.synthetic import (TOK_NO, TOK_YES, filter_query_token,
                                  make_dataset, make_planted_params,
                                  planted_config)
from repro.serving.engine import KERNEL_BLOCK_S, ServingEngine, _bucket
from repro.serving.operators import KVCacheLLMOperator


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    ds = make_dataset("fp", 60, seed=3)
    store = CacheStore(str(tmp_path_factory.mktemp("cache")))
    eng = ServingEngine(store, device_cache=False)
    cfg = planted_config("sm")
    eng.register_model("sm", cfg, make_planted_params(cfg, seed=1))
    eng.build_profiles("sm", ds.items, ratios=[0.0, 0.5],
                       quant_ratios=[0.5], prefill_batch=30)
    return eng, ds


def _ids(ds, n):
    return [it.item_id for it in ds.items[:n]]


# ---------------------------------------------------------------------------
# bucketing respects the memory budget
# ---------------------------------------------------------------------------

def test_bucket_cap_never_exceeds_memory_batch(engine, monkeypatch):
    """Regression: power-of-two bucketing used to round 48 ids up to a
    64-wide batch even when the memory budget only admitted 48."""
    eng, ds = engine
    per_item = eng.store.item_nbytes(Profile("sm", 0.0))
    widths = []
    orig = eng.store.load_batch

    def spy(cfg, profile, item_ids, **kw):
        widths.append(len(item_ids))
        return orig(cfg, profile, item_ids, **kw)

    monkeypatch.setattr(eng.store, "load_batch", spy)
    budget0 = eng.memory_budget
    try:
        eng.memory_budget = 48 * per_item          # admits 48, not 64
        assert eng.max_batch_for("sm", 0.0) == 48
        ids = _ids(ds, 48)
        out = eng.run_filter("sm", 0.0, ids, [filter_query_token(1)],
                             TOK_YES, TOK_NO)
        assert len(out) == 48
        assert widths == [48]                      # not bucketed to 64
        # a ragged final chunk still buckets up (shape-diversity bound)
        eng.memory_budget = 20 * per_item
        widths.clear()
        eng.run_filter("sm", 0.0, _ids(ds, 45), [filter_query_token(1)],
                       TOK_YES, TOK_NO)
        assert widths == [20, 20, 8]   # _bucket(5) = 8, under the cap
    finally:
        eng.memory_budget = budget0


def test_bucket_helper():
    assert [_bucket(n) for n in (1, 2, 3, 5, 48, 64)] == [1, 2, 4, 8, 64, 64]


# ---------------------------------------------------------------------------
# batch sizing reads store metadata, not shards
# ---------------------------------------------------------------------------

def test_max_batch_for_reads_metadata_not_shards(engine, monkeypatch):
    """max_batch_for runs on every flush; it must not decompress an .npz
    shard. A store reopened on the same root (cold in-memory cache) must
    size batches from _meta.jsonl alone."""
    eng, _ = engine
    store2 = CacheStore(eng.store.root)

    def boom(*a, **k):
        raise AssertionError("max_batch_for read a shard")

    monkeypatch.setattr(store2, "load", boom)
    eng2 = ServingEngine(store2, memory_budget_bytes=eng.memory_budget)
    for ratio, quant in ((0.0, False), (0.5, False), (0.5, True)):
        b = eng2.max_batch_for("sm", ratio, quant=quant)
        assert 1 <= b <= eng2.max_batch
    # int8 shards are smaller -> at least as many fit in the budget
    per = store2.item_nbytes(Profile("sm", 0.5))
    eng2.memory_budget = 10 * per
    assert (eng2.max_batch_for("sm", 0.5, quant=True)
            >= eng2.max_batch_for("sm", 0.5))


# ---------------------------------------------------------------------------
# fused flush: one attention dispatch per flush
# ---------------------------------------------------------------------------

def test_fused_one_dispatch_per_flush(engine):
    eng, ds = engine
    ids = _ids(ds, 8)
    query = [filter_query_token(1)]
    assert eng.fused   # default on
    base = eng.attn_dispatches
    fused = eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
    assert eng.attn_dispatches - base == 1         # ONE fused dispatch
    try:
        eng.fused = False
        eng._decode_jit.clear()
        base = eng.attn_dispatches
        scan = eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        assert eng.attn_dispatches - base == len(query)  # one per token
    finally:
        eng.fused = True
        eng._decode_jit.clear()
    # and the fused path computes the same answer as the scan
    np.testing.assert_allclose(fused, scan, atol=1e-4)


def test_fused_multi_token_query(engine):
    """Multi-token operator queries (the common case) still flush once."""
    eng, ds = engine
    ids = _ids(ds, 6)
    query = [filter_query_token(1), filter_query_token(2),
             filter_query_token(3)]
    base = eng.attn_dispatches
    fused = eng.run_filter("sm", 0.5, ids, query, TOK_YES, TOK_NO)
    assert eng.attn_dispatches - base == 1
    try:
        eng.fused = False
        eng._decode_jit.clear()
        base = eng.attn_dispatches
        scan = eng.run_filter("sm", 0.5, ids, query, TOK_YES, TOK_NO)
        assert eng.attn_dispatches - base == len(query)
    finally:
        eng.fused = True
        eng._decode_jit.clear()
    np.testing.assert_allclose(fused, scan, atol=1e-4)


# ---------------------------------------------------------------------------
# device-resident profile cache
# ---------------------------------------------------------------------------

def test_device_cache_hit_skips_load_and_kv_bytes(engine):
    eng, ds = engine
    ids = _ids(ds, 8)
    query = [filter_query_token(2)]
    try:
        eng.device_cache = True
        eng.device_cache_clear()
        h0, m0 = eng.dev_cache_hits, eng.dev_cache_misses
        first = eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        assert eng.dev_cache_misses - m0 == 1
        bytes_after_first = eng.store.bytes_loaded
        again = eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        # hit: identical results, no reload, kv_bytes unchanged
        np.testing.assert_array_equal(first, again)
        assert eng.dev_cache_hits - h0 == 1
        assert eng.store.bytes_loaded == bytes_after_first
        # a different batch is a miss and DOES count bytes
        other = _ids(ds, 10)[8:]
        eng.run_filter("sm", 0.0, other, query, TOK_YES, TOK_NO)
        assert eng.store.bytes_loaded > bytes_after_first
        assert eng.dev_cache_misses - m0 == 2
    finally:
        eng.device_cache = False
        eng.device_cache_clear()


def test_device_cache_disabled_always_loads(engine):
    eng, ds = engine
    assert not eng.device_cache        # suite default (conftest)
    ids = _ids(ds, 4)
    query = [filter_query_token(3)]
    eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
    b0 = eng.store.bytes_loaded
    eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
    assert eng.store.bytes_loaded > b0   # every flush loads, and counts


def test_device_cache_evicts_lru_under_budget(engine):
    eng, ds = engine
    per_item = eng.store.item_nbytes(Profile("sm", 0.0))
    budget0 = eng.memory_budget
    try:
        eng.device_cache = True
        eng.device_cache_clear()
        # room for ~2 four-item padded batches, not 6
        eng.memory_budget = 16 * per_item
        query = [filter_query_token(1)]
        for s in range(6):
            ids = _ids(ds, 24)[4 * s:4 * s + 4]
            eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        assert len(eng._dev_cache) < 6
        assert eng._dev_bytes <= eng.memory_budget \
            or len(eng._dev_cache) == 1
    finally:
        eng.device_cache = False
        eng.device_cache_clear()
        eng.memory_budget = budget0


# ---------------------------------------------------------------------------
# int8 KV profiles end to end
# ---------------------------------------------------------------------------

def test_int8_profile_stored_and_distinct(engine):
    eng, ds = engine
    p8 = Profile("sm", 0.5, quant=True)
    assert p8.tag.endswith("__q8")
    shard = eng.store.load(p8, ds.items[0].item_id)
    assert shard["k"].dtype == np.int8 and shard["v"].dtype == np.int8
    assert shard["k_scale"].dtype == np.float32
    assert shard["k_scale"].shape == shard["k"].shape[:-1]
    # int8 shards are materially smaller than their f32 rung
    assert (eng.store.item_nbytes(p8)
            < 0.6 * eng.store.item_nbytes(Profile("sm", 0.5)))


def test_int8_filter_accuracy(engine):
    """int8 decisions track the f32 rung: the quantization is a real
    precision trade, not a different answer."""
    eng, ds = engine
    ids = [it.item_id for it in ds.items]
    q = [filter_query_token(1)]
    lo_f32 = eng.run_filter("sm", 0.5, ids, q, TOK_YES, TOK_NO)
    lo_int8 = eng.run_filter("sm", 0.5, ids, q, TOK_YES, TOK_NO, quant=True)
    agree = ((lo_f32 > 0) == (lo_int8 > 0)).mean()
    assert agree > 0.9
    np.testing.assert_allclose(lo_int8, lo_f32, atol=0.5)


def test_int8_operator_surface(engine):
    eng, _ = engine
    op32 = KVCacheLLMOperator(eng, "sm", 0.5)
    op8 = KVCacheLLMOperator(eng, "sm", 0.5, quant=True)
    assert op8.name != op32.name and "i8" in op8.name
    assert op8.cost_model() < op32.cost_model()
    assert op8.max_batch() >= 1


# ---------------------------------------------------------------------------
# API level: backend flip changes nothing observable
# ---------------------------------------------------------------------------

FAST = PlannerConfig(steps=120, restarts=2, snapshots=2)


@pytest.fixture(scope="module")
def api_world(tmp_path_factory):
    ds = make_dataset("fpapi", 40, seed=9)
    session = Session(SessionConfig(
        cache_dir=str(tmp_path_factory.mktemp("cache")),
        models=("sm",), profile_ratios=(0.0, 0.8),
        sm_ratios=(0.8, 0.0), lg_ratios=(0.0,),
        planner=FAST, sample_frac=0.4, partition_size=20))
    session.prepare(ds.items)
    yield ds, session
    session.close()


def _stats_key(result):
    return [(s.op_name, s.n_tuples, s.n_llm_calls, s.kv_bytes, s.n_batches)
            for s in result.stage_stats]


def test_decisions_identical_across_kernel_backends(api_world, monkeypatch):
    """STRETTO_KERNELS=ref vs interpret: same accepted set, same map
    values, same EXPLAIN ANALYZE counters — on both dispatchers. The
    backend is resolved at flush time, so flipping the env between runs
    of one session exercises real re-dispatch."""
    ds, sess = api_world
    frame = sess.frame(ds.items).sem_filter("f1", 1).sem_map("m2", 2)
    runs = {}
    for backend in ("ref", "interpret"):
        monkeypatch.setenv("STRETTO_KERNELS", backend)
        for eng in sess.engines.values():
            eng._decode_jit.clear()
        for dispatcher in ("inline", "threads"):
            runs[(backend, dispatcher)] = frame.execute(
                dispatcher=dispatcher)
    monkeypatch.delenv("STRETTO_KERNELS", raising=False)
    base = runs[("ref", "inline")]
    for key, res in runs.items():
        np.testing.assert_array_equal(res.accepted, base.accepted,
                                      err_msg=str(key))
        for col, vals in res.map_values.items():
            np.testing.assert_array_equal(vals, base.map_values[col],
                                          err_msg=str(key))
        assert _stats_key(res) == _stats_key(base), key
    # EXPLAIN ANALYZE is identical apart from measured wall-clock columns
    import re

    def strip_times(text):
        text = re.sub(r"\d+\.\d+(ms|s|us)\b", "<t>", text)
        return re.sub(r"(runtime_s|wall_s)=\d+\.\d+", r"\1=<t>", text)

    rep_ref = strip_times(runs[("ref", "inline")].explain_analyze().render())
    rep_int = strip_times(
        runs[("interpret", "inline")].explain_analyze().render())
    assert rep_ref == rep_int


def test_session_config_validates_kernels_backend():
    """The kernels knob is part of the declarative config surface and is
    validated at construction, not first flush."""
    from repro.api import EngineSpec
    spec = EngineSpec("e", kernels="ref", fused=False, device_cache=True)
    assert spec.kernels == "ref"
    with pytest.raises(ValueError, match="kernels"):
        EngineSpec("e", kernels="cuda")
    cfg = SessionConfig(kernels="interpret", cache_dir="/tmp/nowhere")
    assert cfg.resolved_engines()[0].kernels == "interpret"


# ---------------------------------------------------------------------------
# async H2D overlap + donation
# ---------------------------------------------------------------------------

def test_async_h2d_identical_results_and_counters(engine):
    """Transfer overlap is a schedule change, not a math change: with the
    memory budget forcing a multi-batch flush, async_h2d=True must return
    bit-identical logits while the engine's h2d_overlap_s (prefetch time
    hidden behind decode) and donated_bytes (consumed KV buffers handed
    back to XLA) counters both advance."""
    eng, ds = engine
    ids = _ids(ds, 24)
    query = [filter_query_token(1)]
    per_item = eng.store.item_nbytes(Profile("sm", 0.0))
    budget0, flag0 = eng.memory_budget, eng.async_h2d
    assert eng.async_h2d                           # overlap is on by default
    try:
        eng.memory_budget = 8 * per_item           # forces 3 flush batches
        assert eng.max_batch_for("sm", 0.0) == 8
        eng.async_h2d = False
        base = eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        eng.async_h2d = True
        h0, d0 = eng.h2d_overlap_s, eng.donated_bytes
        overlapped = eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        np.testing.assert_array_equal(overlapped, base)
        assert eng.h2d_overlap_s > h0              # prefetches were timed
        assert eng.donated_bytes > d0              # consumed KV donated
    finally:
        eng.async_h2d = flag0
        eng.memory_budget = budget0


def test_async_h2d_single_batch_no_prefetch(engine):
    """A corpus that fits one flush batch has no 'next cohort' to stage:
    h2d_overlap_s must not move (nothing was overlapped), while donation
    still returns the one consumed cache buffer."""
    eng, ds = engine
    ids = _ids(ds, 6)
    query = [filter_query_token(2)]
    flag0 = eng.async_h2d
    try:
        eng.async_h2d = True
        h0, d0 = eng.h2d_overlap_s, eng.donated_bytes
        eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        assert eng.h2d_overlap_s == h0
        assert eng.donated_bytes > d0
    finally:
        eng.async_h2d = flag0


def test_donation_disabled_with_device_cache(engine):
    """The device LRU keeps references to cached KV buffers, so donating
    them would hand XLA memory the cache later reuses — donation must be
    gated off whenever device_cache is on, and cache hits must still
    return identical results under async_h2d."""
    eng, ds = engine
    ids = _ids(ds, 8)
    query = [filter_query_token(3)]
    flag0 = eng.async_h2d
    try:
        eng.async_h2d = True
        eng.device_cache = True
        eng.device_cache_clear()
        d0 = eng.donated_bytes
        first = eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        again = eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        np.testing.assert_array_equal(first, again)
        assert eng.donated_bytes == d0             # never donated
    finally:
        eng.async_h2d = flag0
        eng.device_cache = False
        eng.device_cache_clear()


def test_transfer_stats_thread_local(engine):
    """The executor attributes h2d/donation deltas to the flush that
    caused them via thread-scoped counters (a flush runs entirely on one
    dispatcher thread) — an async run must advance the calling thread's
    transfer_stats_local, and a fresh thread must start at zero."""
    import threading

    eng, ds = engine
    ids = _ids(ds, 8)
    query = [filter_query_token(1)]
    flag0 = eng.async_h2d
    try:
        eng.async_h2d = True
        t0 = eng.transfer_stats_local()
        eng.run_filter("sm", 0.0, ids, query, TOK_YES, TOK_NO)
        t1 = eng.transfer_stats_local()
        assert t1[1] > t0[1]                       # this thread donated
        seen = {}
        th = threading.Thread(
            target=lambda: seen.update(other=eng.transfer_stats_local()))
        th.start()
        th.join()
        assert seen["other"] == (0.0, 0)           # not leaked cross-thread
    finally:
        eng.async_h2d = flag0


def test_engine_loads_padded_to_kernel_block(engine):
    """Every engine load pads S to the Pallas block multiple so any
    backend's grid is legal."""
    eng, ds = engine
    cache, _ = eng.store.load_batch(
        eng.models["sm"].cfg, Profile("sm", 0.0), _ids(ds, 3),
        pad_to_multiple=KERNEL_BLOCK_S, headroom=4, n_real=3)
    assert cache["k"].shape[2] % KERNEL_BLOCK_S == 0
