import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import bounds as B


def test_betaincinv_inverts_betainc():
    from jax.scipy.special import betainc
    a = jnp.array([2.0, 5.0, 91.0, 1.0])
    b = jnp.array([3.0, 1.0, 11.0, 1.0])
    q = jnp.array([0.05, 0.5, 0.95, 0.3])
    x = B.betaincinv(a, b, q)
    np.testing.assert_allclose(np.asarray(betainc(a, b, x)), np.asarray(q),
                               atol=1e-6)


def test_known_quantile():
    # Beta(91, 11) 5th percentile ~ 0.8378 (checked against scipy offline)
    lb = float(B.recall_lower_bound(90.0, 10.0, 0.95))
    assert abs(lb - 0.8378) < 2e-3


def test_bound_below_point_estimate():
    lb = float(B.recall_lower_bound(50.0, 50.0, 0.95))
    assert lb < 0.5
    lb99 = float(B.recall_lower_bound(50.0, 50.0, 0.99))
    assert lb99 < lb            # stricter credibility -> lower bound


def test_gradients():
    g_tp = float(jax.grad(
        lambda tp: B.recall_lower_bound(tp, 10.0, 0.95))(90.0))
    g_fn = float(jax.grad(
        lambda fn: B.recall_lower_bound(90.0, fn, 0.95))(10.0))
    assert g_tp > 0 and g_fn < 0


@settings(max_examples=30, deadline=None)
@given(tp=st.floats(0.0, 500.0), fn=st.floats(0.0, 500.0))
def test_bound_in_unit_interval(tp, fn):
    lb = float(B.recall_lower_bound(tp, fn, 0.95))
    assert 0.0 <= lb <= 1.0


@settings(max_examples=20, deadline=None)
@given(tp=st.floats(1.0, 200.0), fn=st.floats(0.0, 200.0),
       extra=st.floats(0.5, 50.0))
def test_bound_monotone_in_tp(tp, fn, extra):
    l1 = float(B.recall_lower_bound(tp, fn, 0.95))
    l2 = float(B.recall_lower_bound(tp + extra, fn, 0.95))
    assert l2 >= l1 - 1e-6


def test_more_data_tightens_bound():
    # same empirical rate, 10x the evidence -> tighter bound
    l_small = float(B.recall_lower_bound(9.0, 1.0, 0.95))
    l_big = float(B.recall_lower_bound(90.0, 10.0, 0.95))
    assert l_big > l_small
