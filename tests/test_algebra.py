"""Semantic algebra tests: sem_join / sem_topk / sem_agg through the
logical plan tree.

Pins the refactor's load-bearing invariants:

  - checked pushdown: RelFilters move ahead of LLM stages only when
    legal (never across a SemMap producing their column, never across a
    SemTopK/SemAgg barrier); pushdown shrinks the priced corpus without
    changing decisions; legacy filter/map queries are untouched.
  - dispatcher parity: top-k and join-tree decisions plus per-stage
    n_tuples / n_llm_calls / kv_bytes are bit-identical across inline /
    threads / sharded / mesh dispatchers, and solo vs scheduler
    (FlushHub) admission.
  - quality: a planned join / top-k meets its declared recall target
    against the gold reference on the planted synthetic corpora, with
    the error budget visibly split across the tree's pipelines.
"""
import dataclasses

import numpy as np
import pytest

from repro.cache.store import CacheStore
from repro.api import Session
from repro.core.physical import PhysicalOperator
from repro.runtime import OracleBackend
from repro.scheduler import QueryScheduler
from repro.core import PlannerConfig, Query, RelFilter, SemFilter, SemMap
from repro.core.logical import (AggNode, JoinNode, PipelineLeaf, SemAgg,
                                SemJoin, SemTopK, TopKNode, as_tree,
                                lower_tree, normalize, pinned_relational,
                                pull_up_semantic)
from repro.core.planner import _effective_targets, plan_query, plan_tree
from repro.data.synthetic import (make_dataset, make_join_corpora,
                                  make_planted_params, planted_config)
from repro.runtime import as_backend, run_plan
from repro.runtime.plan_utils import gold_plan_for
from repro.runtime.tree import (evaluate_pairs, make_pairs, run_gold_tree,
                                run_tree, survivor_pairs)
from repro.serving.engine import ServingEngine
from repro.serving.operators import make_registry

FAST = PlannerConfig(steps=150, restarts=2, snapshots=3)


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    ds = make_dataset("alg", 120, seed=5)
    left, right = make_join_corpora(n_left=60, n_right=60, seed=3)
    store = CacheStore(str(tmp_path_factory.mktemp("cache")))
    eng = ServingEngine(store)
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        eng.register_model(size, cfg, make_planted_params(cfg, seed=1))
        for items in (ds.items, left.items, right.items):
            eng.build_profiles(size, items, ratios=[0.0, 0.3, 0.5, 0.8],
                               prefill_batch=40)
    registry = make_registry(eng)
    return ds, left, right, registry


def _stat_key(stats):
    """Schedule-invariant telemetry fingerprint: per (logical op, stage,
    operator) the exact tuples scored, LLM calls, and KV bytes."""
    out = {}
    for sg in stats:
        key = (sg.logical_idx, sg.stage, sg.op_name)
        t, l, k = out.get(key, (0, 0, 0))
        out[key] = (t + sg.n_tuples, l + sg.n_llm_calls, k + sg.kv_bytes)
    return out


# ---------------------------------------------------------------------------
# RelFilter semantics (unit)
# ---------------------------------------------------------------------------

def test_relfilter_missing_column_and_new_ops():
    assert RelFilter("year", "<", 2000).apply({}) is False       # missing
    assert RelFilter("year", ">", 2000).apply({"year": None}) is False
    assert RelFilter("year", "<=", 2000).apply({"year": 2000})
    assert RelFilter("year", ">=", 2000).apply({"year": 2000})
    assert not RelFilter("year", ">=", 2001).apply({"year": 2000})
    assert RelFilter("tags", "contains", "a").apply({"tags": ["a", "b"]})
    assert not RelFilter("tags", "contains", "z").apply({"tags": ["a"]})
    assert RelFilter("cat", "in", ("x", "y")).apply({"cat": "x"})
    # incomparable types reject cleanly instead of raising
    assert RelFilter("year", "<", 2000).apply({"year": "nineteen"}) is False


def test_relfilter_rejects_unknown_op_at_construction():
    with pytest.raises(ValueError, match="not supported"):
        RelFilter("year", "=", 2000)
    with pytest.raises(ValueError, match="not supported"):
        RelFilter("year", "like", "x")


def test_topk_agg_construction_validation():
    with pytest.raises(ValueError, match="k must be"):
        SemTopK("t", 1, k=0)
    with pytest.raises(ValueError, match="mode"):
        SemAgg("a", 1, how="sum")
    assert SemTopK("t", 1, k=3).k == 3
    assert SemAgg("a", 1, group_by="cat").how == "mode"


# ---------------------------------------------------------------------------
# checked pushdown (unit)
# ---------------------------------------------------------------------------

def test_pushdown_never_crosses_producing_map():
    """Regression for the unchecked pull-up: a RelFilter over a SemMap's
    output column must stay pinned behind the map — the value it
    filters does not exist before the map runs."""
    m = SemMap("extract", 3, out_column="v")
    pinned = RelFilter("v", "==", 10)
    free = RelFilter("year", ">", 2000)
    q = Query([m, pinned, free])
    n = normalize(q)
    assert n.nodes == [free, m, pinned]          # free moved, pinned stayed
    assert pull_up_semantic(q).nodes == n.nodes  # alias is the checked one
    assert pinned_relational(n) == [(pinned, 0)]


def test_pushdown_never_crosses_topk_barrier():
    """Filtering before a rank cut is a different query: RelFilters
    declared after a SemTopK/SemAgg stay pinned (post-cut row filters)."""
    topk = SemTopK("rank", 2, k=5)
    post = RelFilter("year", ">", 2000)
    q = Query([topk, post])
    n = normalize(q)
    assert n.nodes == [topk, post]
    assert pinned_relational(n) == [(post, None)]
    agg = SemAgg("a", 1, group_by="category")
    n2 = normalize(Query([agg, post]))
    assert n2.nodes == [agg, post]


def test_lower_tree_and_as_tree():
    leaf = as_tree(Query([SemFilter("f", 1)]))
    assert isinstance(leaf, PipelineLeaf)
    t = lower_tree(TopKNode(leaf, SemTopK("t", 2, k=4)))
    assert isinstance(t, PipelineLeaf) and isinstance(t.nodes[-1], SemTopK)
    a = lower_tree(AggNode(leaf, SemAgg("a", 3)))
    assert isinstance(a.nodes[-1], SemAgg)
    join = JoinNode(leaf, leaf, SemJoin("j", 3))
    with pytest.raises(ValueError, match="not supported"):
        lower_tree(TopKNode(join, SemTopK("t", 2, k=4)))


def test_survivor_pairs_blocking_and_order():
    class It:
        def __init__(self, i, cat):
            self.item_id = i
            self.row = {"category": cat}
            self.tokens = []
    L = [It(0, "a"), It(1, "b"), It(2, None)]
    R = [It(10, "b"), It(11, "a"), It(12, "a")]
    pairs = survivor_pairs(L, R, "category")
    assert [p.item_id for p in pairs] == [(0, 11), (0, 12), (1, 10)]
    assert all(p.row["category"] is not None for p in pairs)
    full = survivor_pairs(L, R, None)
    assert len(full) == 9
    with pytest.raises(ValueError, match="equal-length"):
        make_pairs(L, R[:2])


# ---------------------------------------------------------------------------
# pushdown shrinks the priced corpus without changing decisions
# ---------------------------------------------------------------------------

def test_pushdown_shrinks_priced_corpus_same_decisions(world):
    ds, _, _, registry = world
    rel = RelFilter("category", "==", "news")
    sem = SemFilter("f1", 1)
    q_after = Query([sem, rel], target_recall=0.6, target_precision=0.6)
    q_before = Query([rel, sem], target_recall=0.6, target_precision=0.6)
    # declared order does not matter: both normalize to the pushed form
    assert normalize(q_after).nodes == normalize(q_before).nodes

    plan = plan_query(q_after, ds.items, registry, FAST, sample_frac=0.3)
    assert [r.column for r in plan.relational] == ["category"]
    assert not plan.post_relational

    # the pushdown proof on ONE plan (planning twice re-measures operator
    # wall costs, so separate plans differ in thresholds by design):
    # identical stages with the predicate applied pre- vs post-cascade
    # must decide identically, and the pushed variant prices fewer tuples
    p_post = dataclasses.replace(plan, relational=[],
                                 post_relational=[(rel, None)])
    r_push = run_plan(plan, q_after, ds.items, registry)
    r_post = run_plan(p_post, q_after, ds.items, registry)
    np.testing.assert_array_equal(r_push.accepted, r_post.accepted)
    assert r_push.n_llm_tuples < r_post.n_llm_tuples
    # and the pushed predicate never leaks a non-matching row
    news = np.array([it.row["category"] == "news" for it in ds.items])
    assert not (r_push.accepted & ~news).any()


def test_legacy_filter_map_query_unchanged(world):
    """Pre-tree queries (filters/maps + leading relational) are exactly
    the old flat pipeline: normalization is the identity, nothing gets
    pinned, and execution is dispatcher-invariant as before."""
    ds, _, _, registry = world
    q = Query([RelFilter("year", ">", 2000), SemFilter("f1", 1),
               SemMap("m3", 3)], target_recall=0.7, target_precision=0.7)
    assert normalize(q).nodes == q.nodes
    assert pull_up_semantic(q).nodes == q.nodes
    plan = plan_query(q, ds.items, registry, FAST, sample_frac=0.3)
    assert plan.post_relational == []
    r1 = run_plan(plan, q, ds.items, registry)
    r2 = run_plan(plan, q, ds.items, registry, dispatcher="threads:4")
    np.testing.assert_array_equal(r1.accepted, r2.accepted)
    np.testing.assert_array_equal(r1.map_values[1], r2.map_values[1])
    assert _stat_key(r1.stage_stats) == _stat_key(r2.stage_stats)


# ---------------------------------------------------------------------------
# sem_topk: rank-cut execution, dispatcher parity, quality
# ---------------------------------------------------------------------------

def test_topk_parity_and_quality(world):
    ds, _, _, registry = world
    k = 30
    q = Query([SemTopK("rank f2", 2, k=k)],
              target_recall=0.6, target_precision=0.6)
    plan = plan_query(q, ds.items, registry, FAST, sample_frac=0.3)
    # reject-only cascade: no non-gold stage may accept early
    for s in plan.stages:
        if not s.is_gold:
            assert s.thr_hi == float("inf")

    runs = {
        "inline": run_plan(plan, q, ds.items, registry),
        "threads": run_plan(plan, q, ds.items, registry,
                            dispatcher="threads:4"),
        "sharded": run_plan(plan, q, ds.items, registry,
                            dispatcher="sharded:3", partition_size=40),
        "mesh": run_plan(plan, q, ds.items, registry, dispatcher="mesh:2",
                         partition_size=40),
    }
    base = runs["inline"]
    assert int(base.accepted.sum()) == k
    for name, r in runs.items():
        np.testing.assert_array_equal(r.accepted, base.accepted,
                                      err_msg=name)
        assert _stat_key(r.stage_stats) == _stat_key(base.stage_stats), name

    gold = run_plan(gold_plan_for(q, as_backend(registry)), q, ds.items,
                    registry)
    assert int(gold.accepted.sum()) == k
    overlap = int((base.accepted & gold.accepted).sum())
    if plan.feasible:
        assert overlap / k >= 0.55       # statistical target, headroom
    # early termination really happened: the gold scorer saw no more
    # tuples than the corpus (cheap stages reject hopeless items first)
    gold_names = {s.op_name for s in plan.stages if s.is_gold}
    gold_tuples = sum(sg.n_tuples for sg in base.stage_stats
                      if sg.op_name in gold_names)
    assert gold_tuples <= len(ds.items)


def test_topk_post_barrier_row_filter(world):
    """A RelFilter after the SemTopK filters the RESULT, post-cut: at
    most k survivors, all satisfying the predicate, and the ranked set
    itself is unaffected by the filter (same query without it admits a
    superset)."""
    ds, _, _, registry = world
    k = 25
    topk = SemTopK("rank f2", 2, k=k)
    post = RelFilter("year", ">", 2007)
    q = Query([topk, post], target_recall=0.6, target_precision=0.6)
    plan = plan_query(q, ds.items, registry, FAST, sample_frac=0.3)
    assert [r for r, li in plan.post_relational] == [post]
    res = run_plan(plan, q, ds.items, registry)
    years = np.array([it.row["year"] > 2007 for it in ds.items])
    assert not (res.accepted & ~years).any()
    assert int(res.accepted.sum()) <= k

    # post-cut semantics on the SAME stages: stripping the pinned filter
    # yields the unfiltered rank cut, and filtered == cut ∩ predicate —
    # the filter selects FROM the top-k, it never changes the ranking
    p_plain = dataclasses.replace(plan, post_relational=[])
    plain = run_plan(p_plain, Query([topk], 0.6, 0.6), ds.items, registry)
    assert int(plain.accepted.sum()) == k
    np.testing.assert_array_equal(res.accepted, plain.accepted & years)


# ---------------------------------------------------------------------------
# sem_join: tree planning, budget split, parity, quality
# ---------------------------------------------------------------------------

def test_join_tree_budget_split_parity_quality(world):
    _, left, right, registry = world
    tree = JoinNode(PipelineLeaf((SemFilter("lf", 1),)),
                    PipelineLeaf((SemFilter("rf", 4),)),
                    SemJoin("same v3", 3, on="category"))
    plan = plan_tree(tree, left.items, right.items, registry, FAST,
                     target_recall=0.7, target_precision=0.7,
                     sample_frac=0.5)
    # the query-level budget is split across every pipeline of the tree
    assert set(plan.split) == {"left", "right", "pair"}
    assert all(0.0 <= v <= 1.0 for rp in plan.split.values() for v in rp)
    assert plan.est_pairs >= 1
    # telemetry tiles: tree-unique (logical_idx, stage, op) keys
    keys = [(s.logical_idx, s.stage, s.op_name) for s in plan.stages]
    assert len(keys) == len(set(keys))

    r_in = run_tree(plan, left.items, right.items, registry)
    r_th = run_tree(plan, left.items, right.items, registry,
                    dispatcher="threads:4")
    r_mesh = run_tree(plan, left.items, right.items, registry,
                      dispatcher="mesh:2", partition_size=32)
    assert r_th.pair_ids == r_in.pair_ids
    assert r_mesh.pair_ids == r_in.pair_ids
    assert _stat_key(r_th.stage_stats) == _stat_key(r_in.stage_stats)
    assert _stat_key(r_mesh.stage_stats) == _stat_key(r_in.stage_stats)

    gold = run_gold_tree(plan, left.items, right.items, registry)
    m = evaluate_pairs(r_in, gold)
    assert m["n_gold"] > 0
    if plan.feasible:
        assert m["recall"] >= 0.55       # declared 0.7, headroom
    # blocking really shrank the pair corpus below the full cross product
    n_l = int(r_in.roles["left"].accepted.sum())
    n_r = int(r_in.roles["right"].accepted.sum())
    assert len(r_in.pair_items) < max(n_l * n_r, 1) or n_l * n_r == 0


def test_join_blocking_mismatch_raises(world):
    _, left, right, registry = world
    tree = JoinNode(PipelineLeaf(()), PipelineLeaf(()),
                    SemJoin("j", 3, on="no_such_column"))
    with pytest.raises(ValueError, match="eliminated every sample pair"):
        plan_tree(tree, left.items, right.items, registry, FAST,
                  sample_frac=0.35)


# ---------------------------------------------------------------------------
# solo vs scheduler (FlushHub) parity for the new operators
# ---------------------------------------------------------------------------

class _DetFilter(PhysicalOperator):
    """Deterministic batch-composition-independent scorer (no engine)."""
    uses_llm = True

    def __init__(self, name, is_gold=False):
        self.name = name
        self.is_gold = is_gold

    def run_filter(self, items, op):
        idx = np.asarray([it.item_id for it in items], np.float64)
        return np.asarray(
            3.0 * np.sin(idx * 12.9898 + op.task_id * 78.233), np.float32)


def test_topk_solo_vs_scheduler_parity():
    """SemTopK admitted through the QueryScheduler's FlushHub (frozen op
    in the coalescing key) decides bit-identically to its solo run, with
    exactly-tiling per-stage telemetry."""
    tiny = PlannerConfig(steps=40, restarts=1, snapshots=2)
    ops = [_DetFilter("cheap"), _DetFilter("gold", is_gold=True)]
    sess = Session(backend=OracleBackend(lambda op: ops), planner=tiny,
                   sample_frac=0.5)
    ds = make_dataset("alg-sched", 80, seed=11)
    frames = [(sess.frame(ds.items)
               .sem_topk(f"rank t{t}", task_id=t, k=20)
               .with_guarantees(recall=0.7, precision=0.7))
              for t in (1, 2)]
    solo = [f.execute() for f in frames]
    for f in frames:
        f.plan()
    with QueryScheduler(sess, max_concurrent=4, paused=True) as sched:
        handles = [sched.submit(f) for f in frames]
        sched.resume()
        results = [h.result(timeout=120) for h in handles]
    for r, s in zip(results, solo):
        assert int(s.accepted.sum()) == 20
        np.testing.assert_array_equal(r.accepted, s.accepted)
        assert _stat_key(r.stage_stats) == _stat_key(s.stage_stats)


# ---------------------------------------------------------------------------
# sem_agg: group-wise guarantee tightening + aggregate correctness
# ---------------------------------------------------------------------------

def test_agg_tightens_targets_and_matches_gold(world):
    ds, _, _, registry = world
    q = Query([SemAgg("mode v1", 1, group_by="category")],
              target_recall=0.8, target_precision=0.8)
    # group-level guarantee -> tightened per-item targets
    rec, prec = _effective_targets(q, ds.items)
    assert rec > 0.8 and prec > 0.8
    ungrouped = Query([SemAgg("mode v1", 1)], 0.8, 0.8)
    assert _effective_targets(ungrouped, ds.items) == (0.8, 0.8)

    plan = plan_query(q, ds.items, registry, FAST, sample_frac=0.3)
    res = run_plan(plan, q, ds.items, registry)
    gold = run_plan(gold_plan_for(q, as_backend(registry)), q, ds.items,
                    registry)

    def agg_mode(r):
        groups = {}
        for it, ok, v in zip(ds.items, r.accepted, r.map_values[0]):
            if ok:
                groups.setdefault(it.row["category"], []).append(int(v))
        return {g: max({x: vs.count(x) for x in vs}.items(),
                       key=lambda kv: (kv[1], -kv[0]))[0]
                for g, vs in groups.items()}

    got, want = agg_mode(res), agg_mode(gold)
    assert set(got) == set(want)
    agree = sum(got[g] == want[g] for g in want)
    assert agree >= len(want) - 1        # group aggregates track gold
