"""Optional-hypothesis shim: property tests skip when the dep is absent,
deterministic tests in the same module still run.

Usage in a test module:  from hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    import pytest

    HAS_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="property test needs the optional hypothesis dep")(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stand-in for hypothesis.strategies: any strategy constructor
        returns None (the arguments are never executed — @given skips)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
