"""Dry-run smoke: one production-mesh cell compiled in a subprocess (the
512-device XLA flag must be set before jax init, so this cannot run
in-process with the rest of the suite)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.parametrize("arch,shape", [("granite-8b", "decode_32k")])
def test_dryrun_cell_compiles(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape],
        capture_output=True, text=True, timeout=480, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout)
    assert rec["ok"]
    assert rec["n_devices"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory",
                                           "collective")
    assert rec["hlo_flops_per_dev"] > 0
