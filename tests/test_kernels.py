"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.expected_attention import expected_attention_scores
from repro.kernels.prefill_attention import prefill_attention

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


DECODE_CASES = [
    # (B, KV, G, dk, dv, S, block_s, window, dtype)
    (2, 2, 4, 64, 64, 256, 128, 1 << 30, jnp.float32),
    (3, 1, 8, 128, 128, 384, 128, 1 << 30, jnp.float32),
    (1, 4, 1, 64, 64, 128, 64, 1 << 30, jnp.bfloat16),
    (2, 2, 2, 64, 32, 256, 128, 1 << 30, jnp.float32),   # dv != dk (MLA)
    (2, 2, 4, 64, 64, 256, 128, 64, jnp.float32),        # windowed
    (1, 1, 4, 256, 128, 512, 128, 1 << 30, jnp.float32),  # latent-wide
]


@pytest.mark.parametrize("B,KV,G,dk,dv,S,bs,window,dtype", DECODE_CASES)
def test_decode_attention_sweep(B, KV, G, dk, dv, S, bs, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, KV, G, dk), dtype)
    k = _rand(ks[1], (B, S, KV, dk), dtype)
    v = _rand(ks[2], (B, S, KV, dv), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, k, v, lengths, window=window, block_s=bs,
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


PREFILL_CASES = [
    # (B, S, KV, G, dk, dv, bq, bk, window, causal, dtype)
    (2, 256, 2, 2, 32, 32, 64, 64, 1 << 30, True, jnp.float32),
    (1, 512, 1, 4, 64, 64, 128, 128, 1 << 30, True, jnp.float32),
    (2, 256, 2, 2, 32, 32, 64, 64, 64, True, jnp.float32),
    (1, 256, 2, 1, 64, 64, 128, 64, 1 << 30, False, jnp.float32),
    (1, 256, 1, 2, 32, 32, 64, 64, 1 << 30, True, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,KV,G,dk,dv,bq,bk,window,causal,dtype",
                         PREFILL_CASES)
def test_prefill_attention_sweep(B, S, KV, G, dk, dv, bq, bk, window,
                                 causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, S, KV, G, dk), dtype)
    k = _rand(ks[1], (B, S, KV, dk), dtype)
    v = _rand(ks[2], (B, S, KV, dv), dtype)
    out = prefill_attention(q, k, v, window=window, causal=causal,
                            block_q=bq, block_k=bk, interpret=True)
    want = ref.prefill_attention_ref(q, k, v, window=window, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


EA_CASES = [
    (2, 256, 3, 64, 4, 128, jnp.float32),
    (1, 512, 1, 128, 8, 256, jnp.float32),
    (2, 128, 2, 32, 1, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,KV,dk,G,bs,dtype", EA_CASES)
def test_expected_attention_sweep(B, S, KV, dk, G, bs, dtype):
    ks = jax.random.split(KEY, 3)
    kc = _rand(ks[0], (B, S, KV, dk), dtype)
    mu = _rand(ks[1], (KV, G, dk), jnp.float32)
    sig2 = jnp.abs(_rand(ks[2], (KV, G, dk), jnp.float32))
    out = expected_attention_scores(kc, mu, sig2, block_s=bs, interpret=True)
    want = ref.expected_attention_scores_ref(kc, mu, sig2)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol,
                               rtol=tol)


def test_decode_masking_exact():
    """Entries beyond `lengths` must not influence the output at all."""
    B, KV, G, dk, S = 1, 1, 2, 32, 128
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, KV, G, dk), jnp.float32)
    k = _rand(ks[1], (B, S, KV, dk), jnp.float32)
    v = _rand(ks[2], (B, S, KV, dk), jnp.float32)
    lengths = jnp.asarray([40], jnp.int32)
    out1 = decode_attention(q, k, v, lengths, block_s=64, interpret=True)
    k2 = k.at[:, 40:].set(1e4)     # poison the padding
    v2 = v.at[:, 40:].set(-1e4)
    out2 = decode_attention(q, k2, v2, lengths, block_s=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_decode_attention_int8():
    """int8 KV + in-register dequant must match the dequantized oracle."""
    B, KV, G, dk, S = 2, 2, 4, 64, 256
    ks = jax.random.split(KEY, 3)
    k_f = jax.random.normal(ks[0], (B, S, KV, dk), jnp.float32)
    v_f = jax.random.normal(ks[1], (B, S, KV, dk), jnp.float32)
    q = jax.random.normal(ks[2], (B, KV, G, dk), jnp.float32)
    k_s = jnp.max(jnp.abs(k_f), -1) / 127.0
    v_s = jnp.max(jnp.abs(v_f), -1) / 127.0
    k_q = jnp.round(k_f / k_s[..., None]).astype(jnp.int8)
    v_q = jnp.round(v_f / v_s[..., None]).astype(jnp.int8)
    lengths = jnp.asarray([256, 100], jnp.int32)
    out = decode_attention(q, k_q, v_q, lengths, block_s=128,
                           interpret=True, k_scale=k_s, v_scale=v_s)
    want = ref.decode_attention_ref(q, k_q.astype(jnp.float32) *
                                    k_s[..., None],
                                    v_q.astype(jnp.float32) * v_s[..., None],
                                    lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)
