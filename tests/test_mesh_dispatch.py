"""MeshDispatcher tests: spec resolution, shard-bounds tiling (property
tests for Sharded AND Mesh dispatchers), device placement plumbing, and
the core guarantee — mesh:N decisions / map values / per-stage telemetry
bit-identical to inline through the real serving engine.

The parity tests here run on however many devices the host exposes (the
CI mesh-parity job forces 8 via XLA_FLAGS=--xla_force_host_platform_
device_count=8 in the job env — the flag must precede the first jax
import, so it cannot be set inside a test); on a 1-device host the mesh
degenerates to the sharded scatter and every assertion still holds.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

import jax

from repro.launch.mesh import make_dispatch_mesh
from repro.runtime.dispatch import (MeshDispatcher, ShardedDispatcher,
                                    backend_engines, resolve_dispatcher)


# ---------------------------------------------------------------------------
# spec resolution + mesh construction
# ---------------------------------------------------------------------------

def test_resolve_mesh_specs():
    d, owned = resolve_dispatcher("mesh:8")
    assert isinstance(d, MeshDispatcher) and owned
    assert d.name == "mesh"
    assert d.n_shards == 8 and d.n_workers == 8
    d, _ = resolve_dispatcher("mesh")          # bare: every local device
    assert d.n_shards == jax.local_device_count()
    with pytest.raises(ValueError, match="must be positive"):
        resolve_dispatcher("mesh:0")


def test_dispatch_mesh_axes_and_size():
    """The dispatch mesh carries the production axis names (so the
    logical-axis sharding rules resolve identically) and never exceeds
    the host's device count."""
    n_dev = jax.local_device_count()
    for n in (1, 2, 8):
        mesh = make_dispatch_mesh(n)
        assert set(mesh.axis_names) == {"data", "model"}
        assert mesh.devices.size <= n_dev
    d = MeshDispatcher(8)
    assert d.mesh.devices.size <= n_dev
    # shards cycle over the data-axis slices: every shard resolves to a
    # real device, and with >=2 devices distinct slices get distinct
    # shards
    devs = [d.shard_device(i) for i in range(8)]
    assert all(dev in jax.devices() for dev in devs)
    if n_dev >= 2:
        assert len(set(devs)) >= 2


# ---------------------------------------------------------------------------
# shard_bounds tiles any corpus exactly (Sharded and Mesh dispatchers)
# ---------------------------------------------------------------------------

def _check_bounds_tile(disp, n):
    bounds = disp.shard_bounds(n)
    covered = [i for lo, hi in bounds for i in range(lo, hi)]
    assert covered == list(range(n)), \
        f"{disp.name}:{disp.n_shards} bounds {bounds} do not tile {n}"
    assert all(lo < hi for lo, hi in bounds)          # no empty shards
    assert len(bounds) <= max(disp.n_shards, 1)


@pytest.mark.parametrize("n", [0, 1, 2, 3, 7, 8, 9, 64, 100])
@pytest.mark.parametrize("shards", [1, 2, 3, 8, 16])
def test_shard_bounds_tile_exactly(n, shards):
    """Including n=0 and n_items < n_shards, for both dispatcher kinds."""
    _check_bounds_tile(ShardedDispatcher(shards), n)
    _check_bounds_tile(MeshDispatcher(shards), n)


@given(n=st.integers(0, 200), shards=st.integers(1, 16))
@settings(max_examples=60, deadline=None)
def test_shard_bounds_tile_property(n, shards):
    _check_bounds_tile(ShardedDispatcher(shards), n)
    _check_bounds_tile(MeshDispatcher(shards), n)


# ---------------------------------------------------------------------------
# shard_context placement plumbing
# ---------------------------------------------------------------------------

class _FakeEngine:
    def __init__(self):
        self.placed = []

    def place_on(self, device, sharding=None):
        import contextlib

        @contextlib.contextmanager
        def ctx():
            self.placed.append((device, sharding))
            yield
        return ctx()


class _FakeBackend:
    def __init__(self, engine):
        self.engine = engine


def test_shard_context_places_engines_per_device():
    d = MeshDispatcher(4)
    eng = _FakeEngine()
    for i in range(4):
        with d.shard_context(i, _FakeBackend(eng)):
            pass
    assert len(eng.placed) == 4
    for i, (dev, sharding) in enumerate(eng.placed):
        assert dev == d.shard_device(i)
        # params placement resolves through the logical-axis rules to a
        # replicated NamedSharding pinned on that shard's device
        assert isinstance(sharding, jax.sharding.NamedSharding)
        assert sharding.spec == jax.sharding.PartitionSpec()
        assert set(sharding.mesh.axis_names) == {"data", "model"}
        assert sharding.mesh.devices.flatten().tolist() == [dev]


def test_backend_engines_discovery():
    eng_a, eng_b = _FakeEngine(), _FakeEngine()

    class _Pool:
        members = {"a": _FakeBackend(eng_a), "b": _FakeBackend(eng_b)}

    assert backend_engines(_FakeBackend(eng_a)) == [eng_a]
    assert backend_engines(_Pool()) == [eng_a, eng_b]
    assert backend_engines(object()) == []


# ---------------------------------------------------------------------------
# end-to-end parity through the real serving engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sports_frame(tmp_path_factory):
    from repro.api import Session, SessionConfig
    from repro.core import PlannerConfig
    from repro.data.synthetic import make_dataset
    ds = make_dataset("mesh-parity", 60, seed=5)
    sess = Session(SessionConfig(
        cache_dir=str(tmp_path_factory.mktemp("cache")),
        profile_ratios=(0.0, 0.8), models=("sm",),
        sm_ratios=(0.8, 0.0), lg_ratios=(0.8,),
        planner=PlannerConfig(steps=120, restarts=2, snapshots=2),
        sample_frac=0.35, partition_size=20))
    sess.prepare(ds.items)
    frame = (sess.frame(ds.items)
             .sem_filter("about sports?", task_id=1)
             .sem_map("which group?", task_id=3)
             .with_guarantees(recall=0.7, precision=0.7))
    yield frame
    sess.close()


def test_mesh_bit_identical_to_inline(sports_frame):
    """The acceptance criterion: decisions, map values and the per-stage
    EXPLAIN ANALYZE counters (n_tuples / n_llm_calls / kv_bytes) of a
    mesh:8 run match inline bit-for-bit. n_batches is NOT compared —
    shards flush independently, so the batch count legitimately differs;
    the scored-tuple and byte counters may not."""
    r_inline = sports_frame.execute(dispatcher="inline")
    r_mesh = sports_frame.execute(dispatcher="mesh:8")
    a, b = r_inline.raw, r_mesh.raw

    np.testing.assert_array_equal(a.accepted, b.accepted)
    assert set(a.map_values) == set(b.map_values)
    for li in a.map_values:
        np.testing.assert_array_equal(a.map_values[li], b.map_values[li])

    key = lambda sg: (sg.logical_idx, sg.stage, sg.op_name)
    sa = {key(sg): sg for sg in a.stage_stats}
    sb = {key(sg): sg for sg in b.stage_stats}
    assert set(sa) == set(sb)
    for k in sa:
        assert sa[k].n_tuples == sb[k].n_tuples, k
        assert sa[k].n_llm_calls == sb[k].n_llm_calls, k
        assert sa[k].kv_bytes == sb[k].kv_bytes, k

    # the ANALYZE rendering names the dispatcher that actually ran it
    txt = str(r_mesh.explain_analyze())
    assert "dispatcher=mesh" in txt


def test_mesh_wall_clock_reported(sports_frame):
    """A mesh scatter reports elapsed wall_s separately from summed
    runtime_s; with >1 worker overlapping shards, wall must not exceed
    the sum by much (overlap is the whole point of the scatter)."""
    r = sports_frame.execute(dispatcher="mesh:4").raw
    assert r.dispatcher == "mesh" and r.n_workers == 4
    assert r.wall_s > 0 and r.runtime_s > 0
    assert r.wall_s <= r.runtime_s * 1.5    # generous: tiny corpora jitter
