"""KV-cache compression ladder + serving engine end-to-end behavior."""
import numpy as np
import pytest

from repro.cache.compression import prune_dominated
from repro.cache.store import CacheStore, Profile
from repro.data.synthetic import (TOK_NO, TOK_YES, filter_query_token,
                                  make_dataset, make_planted_params,
                                  map_query_token, planted_config,
                                  value_token)
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    ds = make_dataset("t", 80, seed=11)
    store = CacheStore(str(tmp_path_factory.mktemp("cache")))
    eng = ServingEngine(store)
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        eng.register_model(size, cfg, make_planted_params(cfg, seed=1))
        eng.build_profiles(size, ds.items, ratios=[0.0, 0.5, 0.8],
                           prefill_batch=40)
    return eng, ds


def test_compressed_lengths(engine):
    eng, ds = engine
    s0 = eng.store.load(Profile("lg", 0.0), 0)
    s5 = eng.store.load(Profile("lg", 0.5), 0)
    s8 = eng.store.load(Profile("lg", 0.8), 0)
    n = len(ds.items[0].tokens)
    assert int(s0["__length__"]) == n
    assert int(s5["__length__"]) == max(4, round(0.5 * n))
    assert int(s8["__length__"]) == max(4, round(0.2 * n))
    # cache arrays shrink accordingly
    assert s5["k"].shape[1] < s0["k"].shape[1]
    assert s8["k"].shape[1] < s5["k"].shape[1]


def test_storage_shrinks_with_ratio(engine):
    eng, _ = engine
    b0 = eng.store.storage_bytes(Profile("lg", 0.0))
    b8 = eng.store.storage_bytes(Profile("lg", 0.8))
    assert b8 < 0.4 * b0


def test_quality_ladder_model_size(engine):
    """Gold (lg, r=0) must beat the small model on the planted filters."""
    eng, ds = engine
    ids = [it.item_id for it in ds.items]
    accs = {}
    for size in ("sm", "lg"):
        lo = eng.run_filter(size, 0.0, ids, [filter_query_token(1)],
                            TOK_YES, TOK_NO)
        labels = np.array([it.labels[1] for it in ds.items])
        accs[size] = ((lo > 0) == labels).mean()
    assert accs["lg"] > accs["sm"]
    assert accs["lg"] > 0.8


def test_quality_ladder_compression(engine):
    """Aggressive compression must hurt lg filter accuracy (the token-drop
    mechanism is real, not simulated)."""
    eng, ds = engine
    ids = [it.item_id for it in ds.items]
    labels = np.array([it.labels[1] for it in ds.items])
    acc = {}
    for r in (0.0, 0.8):
        lo = eng.run_filter("lg", r, ids, [filter_query_token(1)],
                            TOK_YES, TOK_NO)
        acc[r] = ((lo > 0) == labels).mean()
    assert acc[0.8] < acc[0.0]


def test_map_values(engine):
    eng, ds = engine
    ids = [it.item_id for it in ds.items]
    vals, conf = eng.run_map("lg", 0.0, ids, [map_query_token(2)],
                             [value_token(v) for v in range(8)])
    want = np.array([value_token(it.map_vals[2]) for it in ds.items])
    assert (vals == want).mean() > 0.9
    assert (conf > 0).all()


def test_padded_batching_consistent(engine):
    """Results must not depend on batch composition (padding is masked)."""
    eng, ds = engine
    ids = [it.item_id for it in ds.items[:16]]
    full = eng.run_filter("lg", 0.5, ids, [filter_query_token(3)],
                          TOK_YES, TOK_NO)
    solo = np.concatenate(
        [eng.run_filter("lg", 0.5, [i], [filter_query_token(3)],
                        TOK_YES, TOK_NO) for i in ids])
    np.testing.assert_allclose(full, solo, atol=2e-3)


def test_max_batch_grows_with_compression(engine):
    """The memory-budget -> max-batch computation (paper §5): higher
    compression means smaller per-item caches, hence larger batches —
    bounded above by the engine's max_batch and below by 1."""
    eng, ds = engine
    per_item = {r: sum(a.nbytes for k, a in
                       eng.store.load(Profile("lg", r), 0).items()
                       if k != "__length__")
                for r in (0.0, 0.5, 0.8)}
    assert per_item[0.8] < per_item[0.5] < per_item[0.0]
    budget0, cap0 = eng.memory_budget, eng.max_batch
    try:
        # budget sized so compression visibly widens the batch
        eng.memory_budget = 8 * per_item[0.0]
        bs = {r: eng.max_batch_for("lg", r) for r in (0.0, 0.5, 0.8)}
        assert bs[0.0] < bs[0.5] < bs[0.8]
        assert bs[0.0] == 8
        # never exceeds the configured hard cap ...
        eng.max_batch = 4
        assert all(eng.max_batch_for("lg", r) == 4 for r in (0.0, 0.5, 0.8))
        # ... never collapses below one even under an absurd budget
        eng.max_batch = cap0
        eng.memory_budget = 1
        assert all(eng.max_batch_for("lg", r) == 1 for r in (0.0, 0.5, 0.8))
        # unbounded budget saturates at the hard cap
        eng.memory_budget = 1e18
        assert eng.max_batch_for("lg", 0.8) == cap0
    finally:
        eng.memory_budget, eng.max_batch = budget0, cap0


def test_batch_size_respects_item_count(engine):
    """_batch_size (the online chunking) is the profile's max batch
    clipped to the actual batch of ids."""
    eng, ds = engine
    ids = [it.item_id for it in ds.items[:10]]
    assert eng._batch_size(Profile("lg", 0.0), ids) == 10
    budget0 = eng.memory_budget
    try:
        per_item = sum(a.nbytes for k, a in
                       eng.store.load(Profile("lg", 0.0), ids[0]).items()
                       if k != "__length__")
        eng.memory_budget = 3 * per_item
        assert eng._batch_size(Profile("lg", 0.0), ids) == 3
    finally:
        eng.memory_budget = budget0
    # operators surface the cap to the profiler/cost model
    from repro.serving.operators import KVCacheLLMOperator
    op = KVCacheLLMOperator(eng, "lg", 0.8)
    assert op.max_batch() == eng.max_batch_for("lg", 0.8)


def test_prune_dominated():
    profiles = [
        {"ratio": 0.0, "quality": 0.95, "cost": 10.0},
        {"ratio": 0.3, "quality": 0.94, "cost": 8.0},
        {"ratio": 0.5, "quality": 0.80, "cost": 9.0},   # dominated by r=.3
        {"ratio": 0.8, "quality": 0.60, "cost": 3.0},
    ]
    kept = prune_dominated(profiles)
    ratios = {p["ratio"] for p in kept}
    assert 0.5 not in ratios
    assert {0.0, 0.3, 0.8} <= ratios
