import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import relaxation as R


def _random_pipeline(rng, n_ops=3, N=40, is_map=False):
    scores = rng.normal(size=(n_ops, N)).astype(np.float32)
    costs = np.sort(rng.uniform(0.01, 1.0, n_ops)).astype(np.float32)
    correct = (rng.random((n_ops, N)) < 0.7).astype(np.float32)
    if is_map:
        correct[-1] = 1.0
    return R.PipelineData(jnp.asarray(scores), jnp.asarray(costs), is_map,
                          jnp.asarray(correct) if is_map else None)


def _random_params(rng, n_ops=3):
    return R.PipelineParams(
        jnp.asarray(rng.normal(size=n_ops).astype(np.float32)),
        jnp.asarray(rng.normal(size=n_ops).astype(np.float32)),
        jnp.asarray(rng.normal(size=n_ops).astype(np.float32)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), tau=st.floats(0.05, 2.0))
def test_accept_in_unit_interval(seed, tau):
    rng = np.random.default_rng(seed)
    data = _random_pipeline(rng)
    params = _random_params(rng)
    acc, cost, dec = R.simulate_pipeline(params, data, tau)
    assert float(jnp.min(acc)) >= -1e-5
    assert float(jnp.max(acc)) <= 1.0 + 1e-5
    assert float(jnp.min(cost)) >= -1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_soft_converges_to_hard(seed):
    """tau -> 0 soft simulation must match the hard (argmax) extraction
    away from decision boundaries (ties are genuinely ambiguous)."""
    rng = np.random.default_rng(seed)
    data = _random_pipeline(rng)
    params = _random_params(rng)
    acc_soft, cost_soft, _ = R.simulate_pipeline(params, data, 1e-4,
                                                 pick_tau=1e-4)
    acc_hard, cost_hard, _ = R.simulate_pipeline(params, data, 0.0,
                                                 hard=True)
    # mask tuples where any op's score sits within eps of a boundary
    z_acc = np.asarray(data.scores) - np.asarray(params.thr_hi)[:, None]
    z_rej = np.asarray(params.thr_lo)[:, None] - np.asarray(data.scores)
    margins = np.minimum.reduce([
        np.abs(z_acc), np.abs(z_rej), np.abs(z_acc - z_rej),
        np.abs(np.asarray(data.scores))])
    clear = (margins > 5e-3).all(axis=0)
    np.testing.assert_allclose(np.asarray(acc_soft)[clear],
                               np.asarray(acc_hard)[clear], atol=1e-3)


def test_gold_always_decides():
    rng = np.random.default_rng(0)
    data = _random_pipeline(rng)
    # nothing selected except gold
    params = R.PipelineParams(jnp.asarray([-10.0, -10.0, 10.0]),
                              jnp.zeros(3), jnp.zeros(3))
    acc, cost, _ = R.simulate_pipeline(params, data, 0.0, hard=True)
    gold_acc = np.asarray(data.scores[-1] > 0, np.float32)
    np.testing.assert_allclose(np.asarray(acc), gold_acc)
    # cost = everyone pays the gold op
    np.testing.assert_allclose(np.asarray(cost),
                               np.full(acc.shape, float(data.costs[-1])),
                               rtol=1e-5)


def test_selecting_cheap_op_reduces_cost():
    rng = np.random.default_rng(1)
    data = _random_pipeline(rng)
    off = R.PipelineParams(jnp.asarray([-10.0, -10.0, 10.0]),
                           jnp.asarray([0.0, 0.0, 0.0]),
                           jnp.asarray([0.0, 0.0, 0.0]))
    on = R.PipelineParams(jnp.asarray([10.0, -10.0, 10.0]),
                          jnp.asarray([0.5, 0.0, 0.0]),
                          jnp.asarray([-0.5, 0.0, 0.0]))
    _, c_off, _ = R.simulate_pipeline(off, data, 0.0, hard=True)
    _, c_on, _ = R.simulate_pipeline(on, data, 0.0, hard=True)
    assert float(jnp.sum(c_on)) < float(jnp.sum(c_off))


def test_query_counts_consistency():
    rng = np.random.default_rng(2)
    d1 = _random_pipeline(rng)
    d2 = _random_pipeline(rng, is_map=True)
    p1, p2 = _random_params(rng), _random_params(rng)
    g = (rng.random(40) < 0.5).astype(np.float32)
    c = R.query_counts([d1, d2], [p1, p2], jnp.asarray(g), 0.0, hard=True)
    # TP <= gold positives; FN = gold positives - TP
    assert float(c.tp) <= g.sum() + 1e-5
    np.testing.assert_allclose(float(c.tp + c.fn), g.sum(), atol=1e-3)
