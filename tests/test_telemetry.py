"""Truthful-telemetry tests: per-partition StageStats tiling, wall_s vs
runtime_s under parallel dispatch, EXPLAIN ANALYZE measured columns, the
MeasuredBatchStore measure->plan loop and replan-on-drift.

The worlds here are pure-python recording/sleeping operators (no engine),
so counts are observable and parallel speedup is deterministic enough to
assert on; engine-backed KV-bytes parity lives in tests/test_api.py where
the profile-built session fixture already exists.
"""
import json
import time

import numpy as np
import pytest

from repro.api import Session
from repro.core import MeasuredBatchStore, PlannerConfig, Query, \
    SemFilter, SemMap, batch_drift
from repro.core.physical import (PhysicalOperator, PhysicalPlan,
                                 PhysicalPlanStage)
from repro.runtime import OracleBackend, as_backend, iter_plan, run_plan
from repro.runtime.executor import StageStats, merge_stage_stats

FASTCFG = PlannerConfig(steps=120, restarts=2, snapshots=2)
FAST = dict(planner=FASTCFG, sample_frac=0.5)


class _Item:
    __slots__ = ("idx", "row")

    def __init__(self, idx: int):
        self.idx = idx
        self.row = {}


def _score(idx, task_id, scale=3.0):
    return np.float32(
        scale * np.sin(np.asarray(idx, np.float64) * 12.9898
                       + task_id * 78.233))


class _Filter(PhysicalOperator):
    uses_llm = True

    def __init__(self, name, task_id, is_gold=False, sleep_s=0.0):
        self.name = name
        self.task_id = task_id
        self.is_gold = is_gold
        self.sleep_s = sleep_s

    def run_filter(self, items, op):
        if self.sleep_s:
            time.sleep(self.sleep_s)
        return _score([it.idx for it in items], self.task_id)


class _Map(PhysicalOperator):
    uses_llm = True

    def __init__(self, name, task_id, is_gold=False):
        self.name = name
        self.task_id = task_id
        self.is_gold = is_gold

    def run_filter(self, items, op):
        raise NotImplementedError

    def run_map(self, items, op):
        idx = [it.idx for it in items]
        return (np.asarray(idx, np.int64) % 5, _score(idx, self.task_id))


def _world(sleep_s=0.0):
    """A 2-stage filter cascade + 2-stage map cascade with a hand-built
    plan (no planner), so telemetry shape is fully deterministic."""
    f_cheap = _Filter("f-cheap", 1, sleep_s=sleep_s)
    f_gold = _Filter("f-gold", 2, is_gold=True, sleep_s=sleep_s)
    m_cheap = _Map("m-cheap", 3)
    m_gold = _Map("m-gold", 4, is_gold=True)
    sf, sm = SemFilter("f", 1), SemMap("m", 3)

    def registry(op):
        return [f_cheap, f_gold] if isinstance(op, SemFilter) \
            else [m_cheap, m_gold]

    q = Query([sf, sm], target_recall=0.8, target_precision=0.8)
    stages = [
        PhysicalPlanStage(0, 0, "f-cheap", 1.0, -1.0, False, False, 0.1,
                          exp_batch=16.0),
        PhysicalPlanStage(1, 0, "m-cheap", 1.5, -np.inf, True, False, 0.1,
                          exp_batch=16.0),
        PhysicalPlanStage(0, 1, "f-gold", 0.0, 0.0, False, True, 1.0,
                          exp_batch=8.0),
        PhysicalPlanStage(1, 1, "m-gold", 0.0, 0.0, True, True, 1.0,
                          exp_batch=8.0),
    ]
    plan = PhysicalPlan(stages, [], 0.0, 1.0, 1.0, True)
    return q, plan, registry


def _stats_by_key(stats):
    return {(s.logical_idx, s.stage, s.op_name): s for s in stats}


# ---------------------------------------------------------------------------
# per-partition StageStats tile the run's final stats exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dispatcher", ["inline", "threads:3", "sharded:3"])
@pytest.mark.parametrize("part", [7, 20, None])
def test_partition_stats_sum_to_final(dispatcher, part):
    items = [_Item(i) for i in range(53)]
    q, plan, registry = _world()
    gen = iter_plan(plan, q, items, as_backend(registry),
                    partition_size=part, coalesce=13, dispatcher=dispatcher)
    parts = []
    while True:
        try:
            parts.append(next(gen))
        except StopIteration as stop:
            final = stop.value
            break
    assert parts, "no partitions emitted"
    # integer counters tile bit-exactly; wall times up to summation order
    merged = _stats_by_key(merge_stage_stats(
        [p.stage_stats for p in parts], plan))
    fin = _stats_by_key(final.stage_stats)
    assert set(merged) == set(fin)
    for key, sg in fin.items():
        m = merged[key]
        assert m.n_tuples == sg.n_tuples, key
        assert m.n_llm_calls == sg.n_llm_calls, key
        assert m.n_batches == sg.n_batches, key
        assert m.kv_bytes == sg.kv_bytes, key
        assert m.wall_s == pytest.approx(sg.wall_s, rel=1e-9), key
    # and the counts themselves are real: every corpus tuple was scored
    # by the first stage exactly once
    assert fin[(0, 0, "f-cheap")].n_tuples == len(items)


def test_final_stage_counters_bit_identical_across_dispatchers():
    """The *final* integer counters are dispatcher-invariant: every stage
    scores exactly the same tuple set under any dispatcher (the flush
    membership invariant), so n_tuples / n_llm_calls / kv_bytes must be
    bit-identical across inline, threads and sharded. Only the grouping
    of that work into flush batches (n_batches) and its per-partition
    attribution may move with the schedule — per-tuple totals never do."""
    items = [_Item(i) for i in range(41)]

    def run(disp):
        q, plan, registry = _world()
        return run_plan(plan, q, items, as_backend(registry),
                        partition_size=9, coalesce=11, dispatcher=disp)

    ref = _stats_by_key(run("inline").stage_stats)
    for disp in ("threads:3", "sharded:3"):
        got = _stats_by_key(run(disp).stage_stats)
        assert set(got) == set(ref), disp
        for key in ref:
            assert got[key].n_tuples == ref[key].n_tuples, (disp, key)
            assert got[key].n_llm_calls == ref[key].n_llm_calls, (disp, key)
            assert got[key].kv_bytes == ref[key].kv_bytes, (disp, key)


# ---------------------------------------------------------------------------
# wall_s vs runtime_s
# ---------------------------------------------------------------------------

def test_wall_s_measures_elapsed_not_summed_time():
    items = [_Item(i) for i in range(48)]
    q, plan, registry = _world(sleep_s=0.005)
    # serial: elapsed covers every operator call plus scheduling overhead
    rr = run_plan(plan, q, items, as_backend(registry),
                  partition_size=8, dispatcher="inline")
    assert rr.wall_s >= rr.runtime_s > 0
    # parallel scatter: summed operator time stays ~the serial total, but
    # elapsed wall clock must drop strictly below it — the speedup the
    # old summed-only accounting could not show
    rs = run_plan(plan, q, items, as_backend(registry),
                  partition_size=8, dispatcher="sharded:4")
    assert rs.n_workers == 4
    assert 0 < rs.wall_s < rs.runtime_s


def test_sharded_partition_carries_shard_stats_and_wall():
    items = [_Item(i) for i in range(30)]
    q, plan, registry = _world(sleep_s=0.002)
    gen = iter_plan(plan, q, items, as_backend(registry),
                    dispatcher="sharded:3")
    parts = []
    while True:
        try:
            parts.append(next(gen))
        except StopIteration as stop:
            final = stop.value
            break
    assert len(parts) == 3
    for p in parts:
        assert p.stage_stats and p.wall_s > 0
        assert sum(s.n_tuples for s in p.stage_stats
                   if s.op_name == "f-cheap") == len(p)
    assert sum(s.n_tuples for p in parts for s in p.stage_stats) == \
        sum(s.n_tuples for s in final.stage_stats)


# ---------------------------------------------------------------------------
# EXPLAIN ANALYZE via the Session API
# ---------------------------------------------------------------------------

def _session_world():
    f_cheap = _Filter("f-cheap", 1)
    f_gold = _Filter("f-gold", 2, is_gold=True)
    sess = Session(backend=OracleBackend(
        lambda op: [f_cheap, f_gold]), **FAST)
    items = [_Item(i) for i in range(60)]
    return sess, items


def test_explain_analyze_matches_stage_stats():
    sess, items = _session_world()
    frame = (sess.frame(items).sem_filter("f", task_id=1)
             .with_guarantees(recall=0.7, precision=0.7))
    plain = frame.explain()
    assert not plain.analyzed
    assert "EXPLAIN ANALYZE" not in plain.render()

    res = frame.execute(partition_size=16)
    rep = res.explain_analyze()
    assert rep.analyzed
    assert rep.measured_runtime_s == pytest.approx(res.runtime_s)
    assert rep.measured_wall_s == pytest.approx(res.wall_s)
    assert rep.measured_partitions == res.n_partitions

    measured = _stats_by_key(res.stage_stats)
    seen = 0
    for st in rep.stages:
        sg = measured.get((st.logical_idx, st.stage, st.op_name))
        if sg is None:
            assert st.meas_tuples is None    # never flushed: renders "--"
            continue
        seen += 1
        assert st.meas_tuples == sg.n_tuples
        assert st.meas_batches == sg.n_batches
        assert st.meas_kv_bytes == sg.kv_bytes
        assert st.meas_batch == pytest.approx(sg.mean_batch)
        assert st.meas_cost_per_tuple_s == pytest.approx(
            sg.wall_s / max(sg.n_tuples, 1))
    assert seen == len(res.stage_stats)

    text = rep.render()
    assert "EXPLAIN ANALYZE" in text
    assert "meas/t" in text and "mbatch" in text
    assert "wall_s" in text and "runtime_s" in text
    # rows() carries the measured fields for programmatic use
    rows = [r for r in rep.rows() if "meas_tuples" in r]
    assert len(rows) == seen
    # the execution line reports the config that actually ran (the
    # per-call partition_size=16 override), not the session default
    assert rep.partition_size == 16
    assert "partition_size=16" in text


def test_explain_analyze_uses_the_executed_plan():
    """After new measured telemetry lands in the session store, a prior
    result's explain_analyze() must still render the plan that produced
    it — not today's (measured-fed, different) plan."""
    sess, items = _session_world()
    frame = (sess.frame(items).sem_filter("f", task_id=1)
             .with_guarantees(recall=0.7, precision=0.7))
    res = frame.execute(partition_size=16)
    planned0 = {(s.logical_idx, s.stage, s.op_name): s.exp_batch
                for s in res.raw.plan.stages}
    # recording bumps the store version; session.plan() would now re-plan
    sess.record_measured(res.raw)
    rep = res.explain_analyze()
    got = {(s.logical_idx, s.stage, s.op_name): s.exp_batch
           for s in rep.stages}
    assert got == planned0


def test_stream_wall_excludes_consumer_hold():
    """wall_s measures the engine, not the consumer's loop body: holding
    each partition must not inflate the run's elapsed time."""
    sess, items = _session_world()
    frame = (sess.frame(items).sem_filter("f", task_id=1)
             .with_guarantees(recall=0.7, precision=0.7))
    frame.plan()
    stream = frame.stream(partition_size=15, coalesce=1,
                          dispatcher="inline")
    held = 0.0
    for _ in stream:
        time.sleep(0.05)
        held += 0.05
    final = stream.result
    assert final.wall_s < held / 2       # ~0.2s of hold, ms of execution
    # per-partition windows exclude the hold too
    # (re-stream to inspect, holding between partitions)
    stream2 = frame.stream(partition_size=15, coalesce=1,
                           dispatcher="inline")
    parts = []
    for p in stream2:
        parts.append(p)
        time.sleep(0.05)
    assert sum(p.wall_s for p in parts) < 0.1


def test_stream_live_stats_track_progress():
    sess, items = _session_world()
    frame = (sess.frame(items).sem_filter("f", task_id=1)
             .with_guarantees(recall=0.7, precision=0.7))
    stream = frame.stream(partition_size=15, coalesce=1,
                          dispatcher="inline")
    assert stream.progress == 0.0 and stream.tuples_settled == 0
    first = next(stream)
    assert stream.tuples_settled == len(first)
    assert 0 < stream.progress < 1
    for _ in stream:
        pass
    assert stream.progress == 1.0
    final = stream.result
    live = _stats_by_key(stream.stage_stats)
    fin = _stats_by_key(final.stage_stats)
    assert set(live) == set(fin)
    for key in fin:
        assert live[key].n_tuples == fin[key].n_tuples
        assert live[key].n_batches == fin[key].n_batches


# ---------------------------------------------------------------------------
# MeasuredBatchStore: the measure -> plan loop
# ---------------------------------------------------------------------------

def _stats_row(op, wall_s, n_tuples, n_batches, kv=0):
    return {"op_name": op, "logical_idx": 0, "stage": 0, "wall_s": wall_s,
            "n_tuples": n_tuples, "n_llm_calls": n_tuples, "kv_bytes": kv,
            "n_batches": n_batches,
            "mean_batch": n_tuples / max(n_batches, 1)}


def test_measured_store_aggregates_and_versions():
    store = MeasuredBatchStore()
    assert len(store) == 0 and store.mean_batch("x") is None
    store.record_stats([_stats_row("a", 1.0, 40, 4),
                        _stats_row("b", 0.5, 10, 10)])
    store.record_stats([_stats_row("a", 1.0, 20, 2)])
    assert store.version == 2
    assert store.mean_batch("a") == pytest.approx(10.0)   # 60 tuples / 6
    assert store.wall_per_tuple("a") == pytest.approx(2.0 / 60)
    assert store.mean_batch("b") == pytest.approx(1.0)
    # tuple-weighted blend: op a dominates
    assert store.blended_width(["a", "b"]) == pytest.approx(70 / 16)
    assert store.blended_width(["missing"]) is None
    # an op shared by several pipelines must not be double-weighted
    assert store.blended_width(["a", "a", "b"]) == \
        store.blended_width(["a", "b"])
    # StageStats objects are accepted alongside dict rows
    store.record_stats([StageStats("c", 0, 0, wall_s=0.2, n_tuples=6,
                                   n_llm_calls=6, kv_bytes=3, n_batches=2)])
    assert store.mean_batch("c") == pytest.approx(3.0)
    # zero-batch rows are ignored (never flushed: nothing measured)
    store.record_stats([_stats_row("dead", 0.0, 0, 0)])
    assert "dead" not in store


def test_measured_store_loads_trajectory_snapshots(tmp_path):
    flat = [_stats_row("op-x", 2.0, 100, 5)]
    snap = {"meta": {"git_sha": "abc"},
            "stages": [_stats_row("op-x", 1.0, 60, 3),
                       _stats_row("op-y", 0.1, 8, 8)]}
    # the flat "latest" file duplicates the newest snapshot's rows —
    # from_dir must fold in only the timestamped snapshots, or the most
    # recent run would carry double weight in the trajectory
    (tmp_path / "stage_stats.json").write_text(json.dumps(flat))
    (tmp_path / "stage_stats-20260101T000000-abc.json").write_text(
        json.dumps(snap))
    (tmp_path / "stage_stats-20260102T000000-def.json").write_text(
        json.dumps(flat))
    (tmp_path / "stage_stats-broken.json").write_text("{not json")
    store = MeasuredBatchStore.from_dir(str(tmp_path))
    assert store.mean_batch("op-x") == pytest.approx(160 / 8)
    assert store.mean_batch("op-y") == pytest.approx(1.0)
    # the flat file can still be folded in explicitly
    extra = MeasuredBatchStore()
    extra.load_file(str(tmp_path / "stage_stats.json"))
    assert extra.mean_batch("op-x") == pytest.approx(20.0)
    out = tmp_path / "agg.json"
    store.save(str(out))
    assert json.loads(out.read_text())["op-x"]["n_tuples"] == 160


def test_batch_drift_ratio():
    _, plan, _ = _world()       # f-cheap planned at exp_batch 16
    stats = [StageStats("f-cheap", 0, 0, wall_s=0.1, n_tuples=32,
                        n_llm_calls=32, kv_bytes=0, n_batches=8)]
    # measured mean batch 4 vs planned 16 -> drift 4x either way
    assert batch_drift(plan, stats) == pytest.approx(4.0)
    stats[0].n_batches = 2      # measured 16 == planned: no drift
    assert batch_drift(plan, stats) == pytest.approx(1.0)
    # stages without a planned batch expectation are skipped
    assert batch_drift(plan, [StageStats("unknown", 9, 9, n_tuples=5,
                                         n_batches=5)]) == 1.0


def test_plan_prices_measured_widths(tmp_path):
    """plan_query(measured=...) must price ops at their measured flush
    widths: a store claiming tiny real batches raises the amortized
    fixed cost and lowers exp_batch on the affected stages."""
    from repro.core import plan_query
    sess, items = _session_world()
    q = Query([SemFilter("f", 1)], target_recall=0.7, target_precision=0.7)
    base = plan_query(q, items, sess.backend, FASTCFG, sample_frac=0.5)
    store = MeasuredBatchStore()
    store.record_stats([_stats_row("f-cheap", 0.5, 30, 15),   # batch 2
                        _stats_row("f-gold", 0.5, 30, 15)])
    fed = plan_query(q, items, sess.backend, FASTCFG, sample_frac=0.5,
                     measured=store)
    by_op = {st.op_name: st for st in fed.stages}
    for name in ("f-cheap", "f-gold"):
        if name in by_op:
            assert by_op[name].exp_batch <= 2.0 + 1e-6
    base_ops = {st.op_name: st for st in base.stages}
    for name, st in by_op.items():
        if name in base_ops and base_ops[name].exp_batch > 2.0:
            assert st.exp_batch < base_ops[name].exp_batch


def test_session_replan_on_drift_feeds_measured_store():
    """Executing with flush batches far from the planned width must, with
    replan_on_drift set, record measured telemetry and re-plan against
    it — changing the BatchHint inputs (visible as shrunken exp_batch)."""
    sess, items = _session_world()
    frame = (sess.frame(items).sem_filter("f", task_id=1)
             .with_guarantees(recall=0.7, precision=0.7))
    plan0 = frame.plan()
    widths0 = {st.op_name: st.exp_batch for st in plan0.stages}
    assert len(sess.measured) == 0 and sess.n_replans == 0

    # coalesce=2 forces ~2-tuple flushes against a 64-wide planned batch
    res = frame.execute(partition_size=8, coalesce=2, replan_on_drift=4.0)
    assert sess.n_replans == 1
    assert len(sess.measured) > 0           # measured stats were recorded
    # the memoized plan now prices the measured (tiny) flush widths
    plan1 = frame.plan()
    assert plan1 is not plan0
    meas = {st.op_name: st.exp_batch for st in plan1.stages}
    for name, w in meas.items():
        mb = sess.measured.mean_batch(name)
        if mb is not None and widths0.get(name, 0) > 8:
            assert w < widths0[name]
            assert w <= mb + 1e-6
    # decisions are still a valid execution of the query
    assert res.accepted.shape == (len(items),)

    # a second execute at the planned widths should not re-trigger
    n = sess.n_replans
    frame.execute(partition_size=8, replan_on_drift=1e9)
    assert sess.n_replans == n
