"""RWKV-6 chunked-parallel form vs the sequential recurrence oracle.

The chunked form (GLA-style, C=32) is the trainable path; the step form is
the decode path. Equivalence between them is the correctness contract for
the beyond-paper chunked implementation (EXPERIMENTS §Roofline notes its
20x memory-traffic advantage over a naive time scan).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.models import layers as L


def _sequential_oracle(p, x, cfg):
    """Step-form recurrence applied position by position."""
    B, S, d = x.shape
    outs = []
    wkv = jnp.zeros((B, cfg.rwkv_n_heads, cfg.rwkv_head_size,
                     cfg.rwkv_head_size), jnp.float32)
    prev = jnp.zeros((B, d), x.dtype)
    for t in range(S):
        o, wkv, prev = L.rwkv6_mix_step(p, x[:, t:t + 1], cfg, wkv, prev)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), wkv


@pytest.mark.parametrize("S", [8, 33, 64])
def test_chunked_matches_sequential(S):
    cfg = get_config("rwkv6-1.6b").reduced(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a[0], params["layers"]["attn"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, cfg.d_model))
    out_chunk, (wkv_chunk, _) = L.rwkv6_mix_full(p, x, cfg)
    out_seq, wkv_seq = _sequential_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_seq),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(wkv_chunk), np.asarray(wkv_seq),
                               atol=2e-4, rtol=2e-3)


def test_decay_clamp_keeps_chunks_stable():
    """Adversarially strong decays must not overflow the chunked form."""
    cfg = get_config("rwkv6-1.6b").reduced(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = dict(jax.tree.map(lambda a: a[0], params["layers"]["attn"]))
    p["w0"] = jnp.full_like(p["w0"], 5.0)      # exp(-exp(5)) ~ hard decay
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(2),
                                (1, 64, cfg.d_model))
    out, _ = L.rwkv6_mix_full(p, x, cfg)
    assert bool(jnp.isfinite(out).all())
