import itertools

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.ordering import PhysOp, greedy_order, reorder


def _simulate(order, ops_by_id, n_logical, n):
    counts = [float(n)] * n_logical
    total = 0.0
    for oid in order:
        o = ops_by_id[oid]
        total += o.cost * counts[o.logical_id]
        for l in range(n_logical):
            counts[l] *= o.sel_intra if l == o.logical_id else o.sel_inter
    return total


def _random_instance(rng, n_logical=2, stages=2):
    ops = []
    for l in range(n_logical):
        for s in range(stages):
            ops.append(PhysOp(
                op_id=len(ops), logical_id=l, stage=s,
                cost=float(rng.uniform(0.01, 1.0) * (s + 1)),
                sel_inter=float(rng.uniform(0.3, 1.0)),
                sel_intra=float(rng.uniform(0.05, 0.9))))
    return ops


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_dp_beats_brute_force(seed):
    """DP result equals the best order found by exhaustive enumeration
    (respecting cascade stage precedence)."""
    rng = np.random.default_rng(seed)
    ops = _random_instance(rng)
    ops_by_id = {o.op_id: o for o in ops}
    n = 100.0
    order, cost = reorder(ops, n)
    assert sorted(order) == sorted(o.op_id for o in ops)

    best = np.inf
    for perm in itertools.permutations(range(len(ops))):
        seen_stage = {}
        ok = True
        for oid in perm:
            o = ops_by_id[oid]
            if o.stage != seen_stage.get(o.logical_id, 0):
                ok = False
                break
            seen_stage[o.logical_id] = o.stage + 1
        if not ok:
            continue
        best = min(best, _simulate(perm, ops_by_id, 2, n))
    sim = _simulate(order, ops_by_id, 2, n)
    assert sim <= best * (1 + 1e-9)
    assert abs(cost - sim) / max(sim, 1e-9) < 1e-6


def test_cheap_filtering_op_goes_first():
    ops = [
        PhysOp(0, 0, 0, cost=0.01, sel_inter=0.2, sel_intra=0.1),
        PhysOp(1, 1, 0, cost=1.0, sel_inter=0.9, sel_intra=0.2),
    ]
    order, _ = reorder(ops, 100)
    assert order[0] == 0


def test_greedy_respects_stage_order():
    rng = np.random.default_rng(0)
    ops = _random_instance(rng, n_logical=3, stages=3)
    order, _ = greedy_order(ops, 500)
    seen = {}
    for oid in order:
        o = next(x for x in ops if x.op_id == oid)
        assert o.stage == seen.get(o.logical_id, 0)
        seen[o.logical_id] = o.stage + 1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_dp_no_worse_than_greedy(seed):
    rng = np.random.default_rng(seed)
    ops = _random_instance(rng, n_logical=2, stages=3)
    _, c_dp = reorder(ops, 200)
    _, c_gr = greedy_order(ops, 200)
    assert c_dp <= c_gr + 1e-9
