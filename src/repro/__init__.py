"""repro — Stretto execution engine reproduction on JAX/TPU.

The documented entry point is the declarative API::

    import repro
    with repro.Session() as sess:
        result = (sess.frame(items)
                  .sem_filter("mentions topic 1", task_id=1)
                  .with_guarantees(recall=0.9, precision=0.9)
                  .execute())

Layers:
  repro.api       — Session / SemFrame / EXPLAIN / streaming results
                    (the single front door; compiles to the layers below)
  repro.core      — the paper's contribution (global optimizer + plan layer)
  repro.runtime   — streaming plan execution, backends, dispatch
  repro.scheduler — concurrent query admission, cross-query flush
                    coalescing, tiered tenants
  repro.models    — config-driven model zoo (10 assigned archs + paper arch)
  repro.cache     — KV-cache profiles (Expected-Attention compression ladder)
  repro.serving   — prefill-skip batched execution engine
  repro.kernels   — Pallas TPU kernels + jnp oracles
  repro.training  — train step / optimizer / checkpoints / fault tolerance
  repro.launch    — meshes, dry-run, launchers

Top-level attribute access is lazy (PEP 562): ``import repro`` stays
dependency-free; the api/serving stack (and jax) load on first use.
"""
__version__ = "1.1.0"

_EXPORTS = {
    "Session": "repro.api",
    "SessionConfig": "repro.api",
    "EngineSpec": "repro.api",
    "SemFrame": "repro.api",
    "ExplainReport": "repro.api",
    "ExplainStage": "repro.api",
    "QueryResult": "repro.api",
    "ResultStream": "repro.api",
    "PartitionResult": "repro.runtime",
    "QueryScheduler": "repro.scheduler",
    "QueryHandle": "repro.scheduler",
    "SchedulerSaturated": "repro.scheduler",
    "TenantSpec": "repro.scheduler",
    "MeasuredBatchStore": "repro.core",
    "PlannerConfig": "repro.core",
    "Query": "repro.core",
    "SemFilter": "repro.core",
    "SemMap": "repro.core",
    "RelFilter": "repro.core",
}

__all__ = sorted(_EXPORTS) + ["__version__"]


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(_EXPORTS[name])
        value = getattr(mod, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
