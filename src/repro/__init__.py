"""repro — Stretto execution engine reproduction on JAX/TPU.

Layers:
  repro.core      — the paper's contribution (global optimizer + plan layer)
  repro.models    — config-driven model zoo (10 assigned archs + paper arch)
  repro.cache     — KV-cache profiles (Expected-Attention compression ladder)
  repro.serving   — prefill-skip batched execution engine
  repro.kernels   — Pallas TPU kernels + jnp oracles
  repro.training  — train step / optimizer / checkpoints / fault tolerance
  repro.launch    — meshes, dry-run, launchers
"""
__version__ = "1.0.0"
