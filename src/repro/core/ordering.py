"""Operator reordering by subset dynamic programming (paper §4.3, Alg. 1).

After the gradient planner fixes the physical-operator selection, choose the
execution order minimizing total cost. Each physical operator o has
  inter-selectivity: fraction not *rejected* by o  (survivors for OTHER
                     logical operators)
  intra-selectivity: fraction left *unsure* by o   (work left for LATER
                     stages of the SAME logical operator)
DP state: for each subset S of physical operators, the minimal cost and the
remaining tuple count per logical operator. Exact for m <= ~16 operators.
A precedence constraint keeps each cascade's stages in cost order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class PhysOp:
    op_id: int               # index into the global physical-operator list
    logical_id: int          # which logical operator it implements
    stage: int               # position within its cascade (cost order)
    cost: float              # per-tuple cost (seconds)
    sel_inter: float         # P(not rejected)    = accept + unsure
    sel_intra: float         # P(unsure)


def reorder(ops: Sequence[PhysOp], n_tuples: float
            ) -> Tuple[List[int], float]:
    """Returns (op_ids in execution order, estimated total cost)."""
    m = len(ops)
    n_logical = 1 + max((o.logical_id for o in ops), default=0)
    full = (1 << m) - 1

    # DP over subsets: state = (cost, tuple counts per logical op)
    INF = float("inf")
    dp: List[Optional[Tuple[float, Tuple[float, ...]]]] = \
        [None] * (1 << m)
    parent: List[Tuple[int, int]] = [(-1, -1)] * (1 << m)
    dp[0] = (0.0, tuple([float(n_tuples)] * n_logical))

    # precedence: stage k of a cascade requires stages < k already executed
    stage_mask: Dict[Tuple[int, int], int] = {}
    for i, o in enumerate(ops):
        mask = 0
        for j, p in enumerate(ops):
            if p.logical_id == o.logical_id and p.stage < o.stage:
                mask |= 1 << j
        stage_mask[(o.logical_id, o.stage)] = mask

    order_bits = sorted(range(1 << m), key=lambda s: bin(s).count("1"))
    for S in order_bits:
        if dp[S] is None:
            continue
        cost_S, counts = dp[S]
        for i, o in enumerate(ops):
            if S & (1 << i):
                continue
            if (S & stage_mask[(o.logical_id, o.stage)]) != \
                    stage_mask[(o.logical_id, o.stage)]:
                continue
            S2 = S | (1 << i)
            c = cost_S + o.cost * counts[o.logical_id]
            if dp[S2] is None or c < dp[S2][0]:
                new_counts = list(counts)
                for l in range(n_logical):
                    if l == o.logical_id:
                        new_counts[l] = counts[l] * o.sel_intra
                    else:
                        new_counts[l] = counts[l] * o.sel_inter
                dp[S2] = (c, tuple(new_counts))
                parent[S2] = (S, i)

    assert dp[full] is not None
    # reconstruct
    order: List[int] = []
    S = full
    while S:
        S_prev, i = parent[S]
        order.append(ops[i].op_id)
        S = S_prev
    order.reverse()
    return order, dp[full][0]


def greedy_order(ops: Sequence[PhysOp], n_tuples: float
                 ) -> Tuple[List[int], float]:
    """Rank-based heuristic (cost / (1 - sel)) for m too large for exact DP;
    also the baseline the paper contrasts with."""
    def rank(o: PhysOp):
        sel = 0.5 * (o.sel_inter + o.sel_intra)
        return o.cost / max(1.0 - sel, 1e-6)

    by_logical: Dict[int, List[PhysOp]] = {}
    for o in ops:
        by_logical.setdefault(o.logical_id, []).append(o)
    for l in by_logical:
        by_logical[l].sort(key=lambda o: o.stage)
    # interleave cascades by rank of their next stage
    order = []
    counts = {l: float(n_tuples) for l in by_logical}
    total = 0.0
    heads = {l: 0 for l in by_logical}
    while any(heads[l] < len(by_logical[l]) for l in by_logical):
        cands = [(rank(by_logical[l][heads[l]]), l)
                 for l in by_logical if heads[l] < len(by_logical[l])]
        _, l = min(cands)
        o = by_logical[l][heads[l]]
        heads[l] += 1
        total += o.cost * counts[l]
        for l2 in counts:
            counts[l2] *= o.sel_intra if l2 == l else o.sel_inter
        order.append(o.op_id)
    return order, total
