"""Physical operator interface + plan representation.

A physical operator evaluates one semantic operator over a batch of corpus
items and returns raw decision scores (filters: log-odds; maps: values +
confidences). Implementations:

  repro.serving.operators.KVCacheLLMOperator   — the paper's contribution:
      batched forward over precomputed (compressed) KV caches, prefill
      skipped; one profile per (model, compression ratio)
  repro.serving.operators.EmbeddingFilterOperator — cosine-similarity filter
  repro.serving.operators.PythonMapOperator       — generated-code extractor

Costs are measured during profiling (wall-clock per tuple), exactly as the
paper's Step 2 does.
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class PhysicalOperator(abc.ABC):
    """One physical implementation of a semantic operator."""

    name: str
    is_gold: bool = False

    @abc.abstractmethod
    def run_filter(self, items: Sequence[Any], op) -> np.ndarray:
        """Return log-odds scores (N,) for a SemFilter."""

    def run_map(self, items: Sequence[Any], op
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Return (values (N,), confidences (N,)) for a SemMap."""
        raise NotImplementedError

    def cost_model(self) -> float:
        """Static per-tuple cost estimate (seconds); refined by profiling."""
        return 1.0


@dataclass
class ProfiledPipeline:
    """Profiling result for one logical operator (paper Step 2)."""
    logical_idx: int
    is_map: bool
    op_names: List[str]
    scores: np.ndarray            # (n_ops, N_sample)
    costs: np.ndarray             # (n_ops,) measured per-tuple seconds
    values: Optional[np.ndarray] = None     # (n_ops, N) map outputs
    correct: Optional[np.ndarray] = None    # (n_ops, N) value == gold value


@dataclass
class PhysicalPlanStage:
    logical_idx: int
    stage: int                    # position within the cascade
    op_name: str
    thr_hi: float
    thr_lo: float
    is_map: bool
    is_gold: bool
    cost: float                   # profiled per-tuple cost
    sel_inter: float = 1.0
    sel_intra: float = 1.0


@dataclass
class PhysicalPlan:
    stages: List[PhysicalPlanStage]      # in execution order
    relational: List[Any]                # RelFilter list (executed first)
    est_cost: float
    recall_bound: float
    precision_bound: float
    feasible: bool
    planning_time_s: float = 0.0

    def describe(self) -> str:
        lines = [f"PhysicalPlan(est_cost={self.est_cost:.2f}s, "
                 f"R>={self.recall_bound:.3f}, P>={self.precision_bound:.3f},"
                 f" feasible={self.feasible})"]
        for r in self.relational:
            lines.append(f"  rel: {r}")
        for s in self.stages:
            tag = " [gold]" if s.is_gold else ""
            lines.append(
                f"  L{s.logical_idx}/s{s.stage} {s.op_name}{tag} "
                f"thr=({s.thr_lo:+.2f},{s.thr_hi:+.2f}) "
                f"cost={s.cost * 1e3:.2f}ms/t")
        return "\n".join(lines)
