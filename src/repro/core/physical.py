"""Physical operator interface + plan representation.

A physical operator evaluates one semantic operator over a batch of corpus
items and returns raw decision scores (filters: log-odds; maps: values +
confidences). Implementations:

  repro.serving.operators.KVCacheLLMOperator   — the paper's contribution:
      batched forward over precomputed (compressed) KV caches, prefill
      skipped; one profile per (model, compression ratio)
  repro.serving.operators.EmbeddingFilterOperator — cosine-similarity filter
  repro.serving.operators.PythonMapOperator       — generated-code extractor

Costs are measured during profiling (wall-clock per tuple), exactly as the
paper's Step 2 does.
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class PhysicalOperator(abc.ABC):
    """One physical implementation of a semantic operator."""

    name: str
    is_gold: bool = False

    @abc.abstractmethod
    def run_filter(self, items: Sequence[Any], op) -> np.ndarray:
        """Return log-odds scores (N,) for a SemFilter."""

    def run_map(self, items: Sequence[Any], op
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Return (values (N,), confidences (N,)) for a SemMap."""
        raise NotImplementedError

    def cost_model(self) -> float:
        """Static per-tuple cost estimate (seconds); refined by profiling."""
        return 1.0

    def max_batch(self) -> Optional[int]:
        """Largest batch this operator can score per call, or None when
        unbounded. KV-cache operators derive it from the serving engine's
        memory budget: higher compression -> smaller caches -> larger
        batches (the paper's batching speedup, §5), which the batch-aware
        cost model exploits."""
        return None


@dataclass(frozen=True)
class CostCurve:
    """Batch-size-aware operator cost: one call on b tuples costs
    ``fixed_s + per_tuple_s * b`` seconds. Fitted from profiling the
    operator at several batch sizes; the planner amortizes ``fixed_s``
    over the coalesced flush width the executor will actually run
    (bounded by the operator's memory-budgeted max batch), instead of
    assuming the scalar per-tuple cost of one full-sample batch."""
    fixed_s: float          # per-call overhead (dispatch, cache load, jit)
    per_tuple_s: float      # marginal cost of one more tuple in the batch

    def per_tuple_at(self, batch: float) -> float:
        """Effective per-tuple seconds when flushed in batches of size b."""
        return self.per_tuple_s + self.fixed_s / max(float(batch), 1.0)

    def call_cost(self, batch: float) -> float:
        """Wall seconds for one call on a batch of size b."""
        return self.fixed_s + self.per_tuple_s * max(float(batch), 0.0)


@dataclass
class ProfiledPipeline:
    """Profiling result for one logical operator (paper Step 2)."""
    logical_idx: int
    is_map: bool
    op_names: List[str]
    scores: np.ndarray            # (n_ops, N_sample)
    costs: np.ndarray             # (n_ops,) measured per-tuple seconds
    values: Optional[np.ndarray] = None     # (n_ops, N) map outputs
    correct: Optional[np.ndarray] = None    # (n_ops, N) value == gold value
    cost_curves: Optional[List[CostCurve]] = None   # (n_ops,) batch-aware
    batch_caps: Optional[np.ndarray] = None  # (n_ops,) max batch (inf: none)
    op_engines: Optional[List[str]] = None   # (n_ops,) owning engine per op
    #                                          ("" / None: single-engine
    #                                          backend, no pool routing)


@dataclass
class PhysicalPlanStage:
    logical_idx: int
    stage: int                    # position within the cascade
    op_name: str
    thr_hi: float
    thr_lo: float
    is_map: bool
    is_gold: bool
    cost: float                   # effective per-tuple cost at exp_batch
    sel_inter: float = 1.0
    sel_intra: float = 1.0
    exp_batch: float = 0.0        # expected coalesced flush size (0: n/a)
    engine: str = ""              # owning engine of the physical operator
    #                               ("" for single-engine backends) — the
    #                               placement the planner decided, carried
    #                               through FlushTask / StageStats / EXPLAIN


@dataclass
class PhysicalPlan:
    stages: List[PhysicalPlanStage]      # in execution order
    relational: List[Any]                # RelFilter list (executed first)
    est_cost: float
    recall_bound: float
    precision_bound: float
    feasible: bool
    planning_time_s: float = 0.0
    # post-filters a checked pushdown could NOT move ahead of the LLM
    # stages: [(RelFilter, producing_map_logical_idx | None)]. An entry
    # with a map index filters that SemMap's extracted value; None means
    # a structured-row predicate pinned behind a SemTopK/SemAgg barrier.
    # Applied by the executor at result assembly, after the cascades.
    post_relational: List[Tuple[Any, Optional[int]]] = field(
        default_factory=list)

    def describe(self) -> str:
        lines = [f"PhysicalPlan(est_cost={self.est_cost:.2f}s, "
                 f"R>={self.recall_bound:.3f}, P>={self.precision_bound:.3f},"
                 f" feasible={self.feasible})"]
        for r in self.relational:
            lines.append(f"  rel: {r}")
        for s in self.stages:
            tag = " [gold]" if s.is_gold else ""
            batch = f" b~{s.exp_batch:.0f}" if s.exp_batch else ""
            lines.append(
                f"  L{s.logical_idx}/s{s.stage} {s.op_name}{tag} "
                f"thr=({s.thr_lo:+.2f},{s.thr_hi:+.2f}) "
                f"cost={s.cost * 1e3:.2f}ms/t{batch}")
        for r, li in self.post_relational:
            where = f"map L{li} value" if li is not None else "row"
            lines.append(f"  post-rel ({where}): {r}")
        return "\n".join(lines)


# role order of a join tree's pipelines: the planner concatenates
# profiles/params group-major in exactly this order
TREE_ROLES = ("left", "right", "pair")


@dataclass
class TreePlan:
    """A planned logical tree: one PhysicalPlan per role pipeline
    (`left` / `right` sides, then the `pair` cascade over blocked
    survivor pairs), plus the jointly optimized query-level bounds.

    The roles were optimized *together* through one grouped relaxation
    (`relaxation.tree_counts`), so the query-level recall/precision
    budget is split across them; `split` records each role's achieved
    sample-level (recall, precision) under the chosen thresholds — the
    visible budget allocation EXPLAIN renders."""
    roles: Dict[str, PhysicalPlan]       # keyed by TREE_ROLES
    queries: Dict[str, Any]              # role -> Query driving that plan
    join: Any                            # the SemJoin node
    est_cost: float                      # corpus-level expected seconds
    recall_bound: float                  # joint Bayesian lower bounds
    precision_bound: float
    feasible: bool
    split: Dict[str, Tuple[float, float]] = field(default_factory=dict)
    est_pairs: int = 0                   # expected blocked pair-corpus size
    planning_time_s: float = 0.0

    def role_base(self, role: str) -> int:
        """Logical-index offset of a role's pipelines in the flattened
        tree view (left ops first, then right, then pair) — the retag
        that keeps (logical_idx, stage, op_name) unique across roles in
        merged telemetry."""
        base = 0
        for r in TREE_ROLES:
            if r == role:
                return base
            base += len(self.queries[r].semantic_ops)
        raise ValueError(role)

    @property
    def stages(self) -> List[PhysicalPlanStage]:
        """Every role's stages with tree-unique logical indices
        (scheduler/EXPLAIN view; execution uses the role-local plans)."""
        import dataclasses as _dc
        out: List[PhysicalPlanStage] = []
        for role in TREE_ROLES:
            base = self.role_base(role)
            for s in self.roles[role].stages:
                out.append(_dc.replace(
                    s, logical_idx=s.logical_idx + base))
        return out

    @property
    def relational(self) -> List[Any]:
        return [r for role in TREE_ROLES
                for r in self.roles[role].relational]
