"""Physical operator interface + plan representation.

A physical operator evaluates one semantic operator over a batch of corpus
items and returns raw decision scores (filters: log-odds; maps: values +
confidences). Implementations:

  repro.serving.operators.KVCacheLLMOperator   — the paper's contribution:
      batched forward over precomputed (compressed) KV caches, prefill
      skipped; one profile per (model, compression ratio)
  repro.serving.operators.EmbeddingFilterOperator — cosine-similarity filter
  repro.serving.operators.PythonMapOperator       — generated-code extractor

Costs are measured during profiling (wall-clock per tuple), exactly as the
paper's Step 2 does.
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class PhysicalOperator(abc.ABC):
    """One physical implementation of a semantic operator."""

    name: str
    is_gold: bool = False

    @abc.abstractmethod
    def run_filter(self, items: Sequence[Any], op) -> np.ndarray:
        """Return log-odds scores (N,) for a SemFilter."""

    def run_map(self, items: Sequence[Any], op
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Return (values (N,), confidences (N,)) for a SemMap."""
        raise NotImplementedError

    def cost_model(self) -> float:
        """Static per-tuple cost estimate (seconds); refined by profiling."""
        return 1.0

    def max_batch(self) -> Optional[int]:
        """Largest batch this operator can score per call, or None when
        unbounded. KV-cache operators derive it from the serving engine's
        memory budget: higher compression -> smaller caches -> larger
        batches (the paper's batching speedup, §5), which the batch-aware
        cost model exploits."""
        return None


@dataclass(frozen=True)
class CostCurve:
    """Batch-size-aware operator cost: one call on b tuples costs
    ``fixed_s + per_tuple_s * b`` seconds. Fitted from profiling the
    operator at several batch sizes; the planner amortizes ``fixed_s``
    over the coalesced flush width the executor will actually run
    (bounded by the operator's memory-budgeted max batch), instead of
    assuming the scalar per-tuple cost of one full-sample batch."""
    fixed_s: float          # per-call overhead (dispatch, cache load, jit)
    per_tuple_s: float      # marginal cost of one more tuple in the batch

    def per_tuple_at(self, batch: float) -> float:
        """Effective per-tuple seconds when flushed in batches of size b."""
        return self.per_tuple_s + self.fixed_s / max(float(batch), 1.0)

    def call_cost(self, batch: float) -> float:
        """Wall seconds for one call on a batch of size b."""
        return self.fixed_s + self.per_tuple_s * max(float(batch), 0.0)


@dataclass
class ProfiledPipeline:
    """Profiling result for one logical operator (paper Step 2)."""
    logical_idx: int
    is_map: bool
    op_names: List[str]
    scores: np.ndarray            # (n_ops, N_sample)
    costs: np.ndarray             # (n_ops,) measured per-tuple seconds
    values: Optional[np.ndarray] = None     # (n_ops, N) map outputs
    correct: Optional[np.ndarray] = None    # (n_ops, N) value == gold value
    cost_curves: Optional[List[CostCurve]] = None   # (n_ops,) batch-aware
    batch_caps: Optional[np.ndarray] = None  # (n_ops,) max batch (inf: none)
    op_engines: Optional[List[str]] = None   # (n_ops,) owning engine per op
    #                                          ("" / None: single-engine
    #                                          backend, no pool routing)


@dataclass
class PhysicalPlanStage:
    logical_idx: int
    stage: int                    # position within the cascade
    op_name: str
    thr_hi: float
    thr_lo: float
    is_map: bool
    is_gold: bool
    cost: float                   # effective per-tuple cost at exp_batch
    sel_inter: float = 1.0
    sel_intra: float = 1.0
    exp_batch: float = 0.0        # expected coalesced flush size (0: n/a)
    engine: str = ""              # owning engine of the physical operator
    #                               ("" for single-engine backends) — the
    #                               placement the planner decided, carried
    #                               through FlushTask / StageStats / EXPLAIN


@dataclass
class PhysicalPlan:
    stages: List[PhysicalPlanStage]      # in execution order
    relational: List[Any]                # RelFilter list (executed first)
    est_cost: float
    recall_bound: float
    precision_bound: float
    feasible: bool
    planning_time_s: float = 0.0

    def describe(self) -> str:
        lines = [f"PhysicalPlan(est_cost={self.est_cost:.2f}s, "
                 f"R>={self.recall_bound:.3f}, P>={self.precision_bound:.3f},"
                 f" feasible={self.feasible})"]
        for r in self.relational:
            lines.append(f"  rel: {r}")
        for s in self.stages:
            tag = " [gold]" if s.is_gold else ""
            batch = f" b~{s.exp_batch:.0f}" if s.exp_batch else ""
            lines.append(
                f"  L{s.logical_idx}/s{s.stage} {s.op_name}{tag} "
                f"thr=({s.thr_lo:+.2f},{s.thr_hi:+.2f}) "
                f"cost={s.cost * 1e3:.2f}ms/t{batch}")
        return "\n".join(lines)
