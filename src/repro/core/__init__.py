"""Stretto core: the paper's contribution as a composable JAX module."""
from repro.core.bounds import (beta_lower_bound, betaincinv,
                               precision_lower_bound, recall_lower_bound)
from repro.core.executor import (ExecutionResult, evaluate_vs_gold,
                                 execute_plan)
from repro.core.logical import (AggNode, JoinNode, LogicalNode, PipelineLeaf,
                                Query, RelFilter, SemAgg, SemFilter, SemJoin,
                                SemMap, SemTopK, TopKNode, as_tree,
                                lower_tree, normalize, pull_up_semantic)
from repro.core.optimizer import OptimizedPlan, PlannerConfig, optimize_query
from repro.core.physical import (CostCurve, PhysicalOperator, PhysicalPlan,
                                 PhysicalPlanStage, ProfiledPipeline,
                                 TreePlan)
from repro.core.planner import plan_query, plan_tree
from repro.core.profiling import (MeasuredBatchStore, batch_drift,
                                  fit_cost_curve, profile_query)
from repro.core.relaxation import (BatchHint, PipelineData, PipelineParams,
                                   QueryCounts, query_counts,
                                   simulate_pipeline)
