"""Gradient-based global query optimizer (paper §4, Eq. 10-15).

Minimize expected cost subject to Bayesian lower bounds on global recall and
precision exceeding the user targets:

    L = L_cost + beta * ReLU(T_P - l_P) + beta * ReLU(T_R - l_R)

over pick logits and thresholds of every physical operator, through the soft
cascade simulation (relaxation.py) and the Beta credible bounds (bounds.py),
with Adam and an exponential temperature schedule. At tau -> 0 the plan is
extracted discretely and re-verified with *hard* counts; if the hard bounds
miss the targets the planner falls back to progressively more conservative
plans and ultimately the gold-only plan (which meets any target by
construction: it IS the reference).
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds as B
from repro.core import relaxation as R


@dataclasses.dataclass
class PlannerConfig:
    steps: int = 400
    lr: float = 5e-2
    beta: float = 25.0
    tau_start: float = 1.0
    tau_end: float = 0.02
    pick_tau: float = 1.0    # constant: annealing the pick sigmoid kills its
    #                          gradient once an op drifts off (sigmoid sat.)
    restarts: int = 6        # vmapped multi-start (local optima are real)
    snapshots: int = 4       # candidates along the annealing path — early
    #                          snapshots are conservative, late aggressive
    margin: float = 0.02     # optimize against target+margin: keeps slack
    #                          for the soft->hard extraction gap
    credibility: float = 0.95
    seed: int = 0


class OptimizedPlan(NamedTuple):
    params: List[R.PipelineParams]       # final (discrete-ready) parameters
    selected: List[np.ndarray]           # bool mask per pipeline
    sample_tp: float
    sample_fp: float
    sample_fn: float
    recall_bound: float
    precision_bound: float
    est_cost: float                      # expected cost on sample (s)
    feasible: bool
    loss_history: Optional[np.ndarray] = None


def flatten_params(params_list):
    """Concatenate per-pipeline (pick, thr_hi, thr_lo) into one flat vector
    — the optimizer's parameter layout, shared with the Exp 3 ablations."""
    return jnp.concatenate(
        [jnp.concatenate([p.pick_logits, p.thr_hi, p.thr_lo])
         for p in params_list])


def unflatten_params(flat, sizes):
    """Inverse of flatten_params given each pipeline's operator count."""
    out, off = [], 0
    for n in sizes:
        pick = flat[off:off + n]
        hi = flat[off + n:off + 2 * n]
        lo = flat[off + 2 * n:off + 3 * n]
        out.append(R.PipelineParams(pick, hi, lo))
        off += 3 * n
    return out


def init_pipeline_params(data: R.PipelineData, pick0: float = 0.5,
                         width: float = 0.5) -> R.PipelineParams:
    """Thresholds straddling the median score; everything mildly picked."""
    n = data.scores.shape[0]
    med = jnp.median(data.scores, axis=1)
    spread = jnp.maximum(jnp.std(data.scores, axis=1), 1e-3)
    return R.PipelineParams(
        pick_logits=jnp.zeros(n) + pick0,
        thr_hi=med + width * spread,
        thr_lo=med - width * spread,
    )


def optimize_query(pipelines: Sequence[R.PipelineData],
                   gold_membership: np.ndarray,
                   target_recall: float, target_precision: float,
                   cfg: Optional[PlannerConfig] = None,
                   batch_hint: Optional[R.BatchHint] = None,
                   groups: Optional[Sequence[R.TreeGroup]] = None
                   ) -> OptimizedPlan:
    """batch_hint activates the batch-size-aware cost model for pipelines
    carrying fixed per-call costs (see relaxation.BatchHint); pipelines
    without `fixed` data are costed exactly as before.

    groups switches the simulation from the linear `query_counts` chain
    to the grouped `tree_counts` (join trees: side pipelines reset their
    reach, the pairing cascade's entry mass is the product of the side
    survivals, and per-group cost weights/hints price each pipeline
    against its own corpus) — the query-level error budget is then
    allocated across every pipeline of the tree by the same joint
    gradient relaxation. Omitted (the default), behavior is unchanged."""
    # default constructed per call — a shared default instance would leak
    # mutations between unrelated optimizations
    cfg = cfg if cfg is not None else PlannerConfig()
    pipelines = list(pipelines)
    sizes = [p.scores.shape[0] for p in pipelines]
    g = jnp.asarray(gold_membership, jnp.float32)

    max_cost = sum(
        float(jnp.sum(p.costs))
        + (float(jnp.sum(p.fixed)) if p.fixed is not None else 0.0)
        for p in pipelines) * g.shape[0]
    max_cost = max(max_cost, 1e-9)

    def counts_fn(params_list, tau, hard=False, pick_tau=None):
        if groups is not None:
            return R.tree_counts(pipelines, params_list, g, groups, tau,
                                 hard=hard, pick_tau=pick_tau)
        return R.query_counts(pipelines, params_list, g, tau, hard=hard,
                              pick_tau=pick_tau, batch_hint=batch_hint)

    def loss_fn(flat, tau):
        params_list = unflatten_params(flat, sizes)
        c = counts_fn(params_list, tau, pick_tau=cfg.pick_tau)
        l_rec = B.recall_lower_bound(c.tp, c.fn, cfg.credibility)
        l_prec = B.precision_lower_bound(c.tp, c.fp, cfg.credibility)
        l_cost = c.cost / max_cost                                 # Eq. 12
        t_rec = min(target_recall + cfg.margin, 0.999)
        t_prec = min(target_precision + cfg.margin, 0.999)
        pen = (jax.nn.relu(t_rec - l_rec)                          # Eq. 13
               + jax.nn.relu(t_prec - l_prec))                     # Eq. 14
        return l_cost + cfg.beta * pen, (c, l_rec, l_prec)

    # multi-start inits: decision local optima are real (a collapsed pick
    # factor has a dead sigmoid gradient), so we vmap Adam over restarts
    inits = []
    grid = [(2.0, 0.3), (2.0, 1.0), (0.5, 0.5), (3.0, 0.1), (0.5, 1.5),
            (4.0, 0.6)][:max(1, cfg.restarts)]
    for pick0, width in grid:
        inits.append(flatten_params(
            [init_pipeline_params(p, pick0, width) for p in pipelines]))
    flat0 = jnp.stack(inits)                                   # (K, P)
    decay = (cfg.tau_end / cfg.tau_start) ** (1.0 / max(cfg.steps - 1, 1))

    snap_every = max(cfg.steps // max(cfg.snapshots, 1), 1)

    def run_one(flat_init):
        def opt_step(state, i):
            flat, m, v = state
            tau = cfg.tau_start * decay ** i
            (loss, _), grad = jax.value_and_grad(
                loss_fn, has_aux=True)(flat, tau)
            m = 0.9 * m + 0.1 * grad
            v = 0.999 * v + 0.001 * jnp.square(grad)
            t = i.astype(jnp.float32) + 1.0
            mhat = m / (1 - 0.9 ** t)
            vhat = v / (1 - 0.999 ** t)
            flat = flat - cfg.lr * mhat / (jnp.sqrt(vhat) + 1e-8)
            return (flat, m, v), (loss, flat)

        (flat, _, _), (losses, traj) = jax.lax.scan(
            opt_step, (flat_init, jnp.zeros_like(flat_init),
                       jnp.zeros_like(flat_init)), jnp.arange(cfg.steps))
        return flat, losses, traj

    flats, losses, trajs = jax.jit(jax.vmap(run_one))(flat0)

    def hard_eval(plist):
        c = counts_fn(plist, 0.0, hard=True)
        l_rec = B.recall_lower_bound(c.tp, c.fn, cfg.credibility)
        l_prec = B.precision_lower_bound(c.tp, c.fp, cfg.credibility)
        return c, float(l_rec), float(l_prec)

    # --- discrete extraction: cheapest feasible candidate wins ---
    candidates = [unflatten_params(flats[k], sizes)
                  for k in range(flats.shape[0])]
    # annealing-path snapshots per restart (conservative -> aggressive)
    for k in range(flats.shape[0]):
        for j in range(1, cfg.snapshots):
            step_i = j * snap_every - 1
            if 0 <= step_i < cfg.steps - 1:
                candidates.append(
                    unflatten_params(trajs[k, step_i], sizes))
    # fallback: gold-only — identical to the reference by construction
    gold_only = [R.PipelineParams(
        jnp.full_like(p.pick_logits, -10.0).at[-1].set(10.0),
        jnp.zeros_like(p.thr_hi), jnp.zeros_like(p.thr_lo))
        for p in candidates[0]]
    candidates.append(gold_only)

    best = None
    for cand in candidates:
        c, l_rec, l_prec = hard_eval(cand)
        if l_rec >= target_recall and l_prec >= target_precision:
            if best is None or float(c.cost) < best[1]:
                best = (cand, float(c.cost), c, l_rec, l_prec)

    feasible = best is not None
    if best is None:   # sample too small even for gold-only
        c, l_rec, l_prec = hard_eval(gold_only)
        best = (gold_only, float(c.cost), c, l_rec, l_prec)
    cand, cost, c, l_rec, l_prec = best
    sel = [np.array(jax.nn.sigmoid(p.pick_logits) > 0.5) for p in cand]
    for s in sel:
        s[-1] = True  # gold always on
    return OptimizedPlan(
        params=cand, selected=sel, sample_tp=float(c.tp),
        sample_fp=float(c.fp), sample_fn=float(c.fn), recall_bound=l_rec,
        precision_bound=l_prec, est_cost=cost, feasible=feasible,
        loss_history=np.asarray(losses[0]))
