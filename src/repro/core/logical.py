"""Logical plans: relational + semantic operators over multimodal corpora.

Mirrors the paper's execution model: a logical plan *tree* of relational
and semantic operators with natural-language parameters. Linear
filter/map pipelines remain first-class (a `Query` — what the gradient
relaxation optimizes directly); the tree IR (`LogicalNode`) generalizes
them:

  PipelineLeaf  — one pipeline over one corpus (a Query's nodes)
  JoinNode      — `SemJoin` over two corpora: each side is a sub-tree,
                  survivors are paired (optionally blocked by a cheap
                  structured equi-join column) and scored by a pairing
                  cascade
  TopKNode      — `SemTopK`: the k best-scoring survivors of the child
                  (reject-only early termination in the cascade; the
                  accept boundary is the global rank cut)
  AggNode       — `SemAgg` / group-wise aggregation of an extracted
                  value over the child's survivors

Single-corpus TopK/Agg lower into the child pipeline's node list
(`SemTopK`/`SemAgg` are legal `Query` nodes); only `SemJoin` genuinely
needs the tree, because it spans two corpora.

`normalize` subsumes the old `pull_up_semantic` with a *checked*
pushdown: cheap `RelFilter` predicates move ahead of LLM stages (so the
cascade prices a smaller corpus) only when legal — a predicate must not
cross a `SemMap` that defines the column it references, and nothing
crosses a `SemTopK`/`SemAgg` boundary (filtering before a rank cut is a
different query). Illegal-to-move predicates stay in place and execute
as post-filters.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class SemFilter:
    """LLM-powered predicate over an item's unstructured payload."""
    text: str                     # natural-language predicate
    task_id: int                  # dataset task the predicate evaluates
    modality: str = "text"        # text | image


@dataclass(frozen=True)
class SemMap:
    """LLM-powered extraction producing a new column."""
    text: str
    task_id: int
    out_column: str = "extracted"
    modality: str = "text"


@dataclass(frozen=True)
class SemTopK(SemFilter):
    """The k best items under an LLM-scored ranking criterion.

    Scored exactly like a SemFilter (same physical candidates), but the
    accept boundary is a global rank cut, not a per-item threshold: the
    cascade may only *reject* early (early termination — items whose
    cheap scores are hopeless never reach the gold scorer), and the
    final result is the k top gold-scored survivors. Recall is measured
    against the gold top-k; precision equals recall (both sets have at
    most k members)."""
    k: int = 10

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"SemTopK.k must be >= 1, got {self.k}")


@dataclass(frozen=True)
class SemAgg(SemMap):
    """Group-wise aggregate of an LLM-extracted value.

    Executes as the SemMap it subclasses (one extracted value per
    surviving item); the aggregation (`how` over `group_by` groups) is a
    cheap post-pass. The planner tightens the per-item budget so the
    *group-wise* guarantee holds: a group's aggregate is right when its
    members' extractions are, so per-item quality is raised to
    target^(1/mean_group_size)."""
    group_by: Optional[str] = None   # structured row column (None: global)
    how: str = "mode"                # mode | count

    def __post_init__(self):
        if self.how not in ("mode", "count"):
            raise ValueError(
                f"SemAgg.how must be 'mode' or 'count', got {self.how!r}")


@dataclass(frozen=True)
class SemJoin:
    """LLM-powered join predicate over pairs drawn from two corpora.

    `task_id` names the extraction task whose agreement defines the
    match (a pair joins when both sides express the same latent value).
    `on` optionally names a structured row column both corpora carry:
    pairs are then *blocked* on equality of that column before any LLM
    stage prices them — the structured pushdown that shrinks the pair
    corpus quadratically."""
    text: str
    task_id: int
    on: Optional[str] = None
    modality: str = "text"


_REL_OPS = ("==", "!=", "<", ">", "<=", ">=", "in", "contains")


@dataclass(frozen=True)
class RelFilter:
    """Classical relational predicate over structured columns (cheap).

    Missing columns never match (SQL semantics: a comparison against an
    absent value is not-true), so `<`/`>` on a row without the column is
    a clean reject instead of a TypeError."""
    column: str
    op: str                       # one of _REL_OPS
    value: Any

    def __post_init__(self):
        if self.op not in _REL_OPS:
            raise ValueError(
                f"RelFilter op {self.op!r} not supported (use one of "
                f"{', '.join(_REL_OPS)})")

    def apply(self, row: Dict[str, Any]) -> bool:
        v = row.get(self.column)
        if v is None:
            return False
        try:
            if self.op == "==":
                return v == self.value
            if self.op == "!=":
                return v != self.value
            if self.op == "<":
                return v < self.value
            if self.op == ">":
                return v > self.value
            if self.op == "<=":
                return v <= self.value
            if self.op == ">=":
                return v >= self.value
            if self.op == "in":
                return v in self.value
            if self.op == "contains":
                return self.value in v
        except TypeError:
            return False          # incomparable types: non-matching
        raise ValueError(self.op)


SemanticOp = Any   # SemFilter | SemMap | SemTopK | SemAgg | SemJoin
PlanNode = Any     # SemanticOp | RelFilter


@dataclass
class Query:
    nodes: List[PlanNode]
    target_recall: float = 0.9
    target_precision: float = 0.9

    @property
    def semantic_ops(self) -> List[SemanticOp]:
        return [n for n in self.nodes
                if isinstance(n, (SemFilter, SemMap, SemJoin))]

    @property
    def relational_ops(self) -> List[RelFilter]:
        return [n for n in self.nodes if isinstance(n, RelFilter)]


# ---------------------------------------------------------------------------
# the logical plan tree
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LogicalNode:
    """Base of the logical plan tree."""


@dataclass(frozen=True)
class PipelineLeaf(LogicalNode):
    """One linear pipeline over one corpus — a Query's node list."""
    nodes: Tuple[PlanNode, ...]

    def query(self, target_recall: float = 0.9,
              target_precision: float = 0.9) -> Query:
        return Query(list(self.nodes), target_recall, target_precision)


@dataclass(frozen=True)
class JoinNode(LogicalNode):
    """`SemJoin` over two sub-trees: survivors of each side are paired
    (blocked on `op.on` when declared) and scored by the pairing cascade
    `pair_nodes` (the SemJoin itself plus any post-join predicates)."""
    left: LogicalNode
    right: LogicalNode
    op: SemJoin
    pair_nodes: Tuple[PlanNode, ...] = ()

    def __post_init__(self):
        if not isinstance(self.left, LogicalNode) \
                or not isinstance(self.right, LogicalNode):
            raise ValueError("JoinNode children must be LogicalNodes")


@dataclass(frozen=True)
class TopKNode(LogicalNode):
    """`SemTopK` over the child's survivors."""
    child: LogicalNode
    op: SemTopK


@dataclass(frozen=True)
class AggNode(LogicalNode):
    """`SemAgg` over the child's survivors."""
    child: LogicalNode
    op: SemAgg


# ---------------------------------------------------------------------------
# normalization: checked relational pushdown
# ---------------------------------------------------------------------------

def _split_pushable(nodes: Sequence[PlanNode]
                    ) -> Tuple[List[RelFilter], List[PlanNode]]:
    """Partition a pipeline's nodes into (pushable relational prefilters,
    remaining nodes in original relative order).

    A RelFilter is pushable to the front iff moving it is legal:
      - it must not cross a SemMap that defines the column it references
        (the value it filters does not exist before the map runs);
      - it must not cross a SemTopK/SemAgg (filtering before a rank cut
        or an aggregation changes which items are ranked/aggregated).
    Unpushable RelFilters stay in place and execute as post-filters.
    """
    pushable: List[RelFilter] = []
    rest: List[PlanNode] = []
    defined: set = set()          # SemMap out_columns seen so far
    barrier = False               # a SemTopK/SemAgg has been crossed
    for n in nodes:
        if isinstance(n, RelFilter):
            if barrier or n.column in defined:
                rest.append(n)    # pinned: runs after its producer
            else:
                pushable.append(n)
            continue
        if isinstance(n, (SemTopK, SemAgg)):
            barrier = True
        elif isinstance(n, SemMap):
            defined.add(n.out_column)
        rest.append(n)
    return pushable, rest


def normalize(query: Query) -> Query:
    """Step 1 of optimization: run cheap relational predicates first so
    LLM-powered operators see fewer tuples (paper Fig. 2, step 1) —
    with the legality check `pull_up_semantic` used to skip.

    Pushable RelFilters move to the front (relative order preserved);
    a RelFilter referencing a SemMap's `out_column`, or one declared
    after a SemTopK/SemAgg, keeps its place and the planner executes it
    as a post-filter over the extracted values / surviving set."""
    pushable, rest = _split_pushable(query.nodes)
    return Query(nodes=pushable + rest,
                 target_recall=query.target_recall,
                 target_precision=query.target_precision)


def pull_up_semantic(query: Query) -> Query:
    """Backward-compatible alias of `normalize`.

    The historical version moved *every* RelFilter above the semantic
    operators and claimed the pull-up "always legal" — false once a
    RelFilter references a SemMap's out_column (the filtered value does
    not exist yet) or follows a SemTopK (pre-rank filtering changes the
    ranked set). `normalize` keeps those pinned in place."""
    return normalize(query)


def pinned_relational(query: Query) -> List[Tuple[RelFilter, Optional[int]]]:
    """The post-filters a normalized query retains among its semantic
    nodes: [(rel, producing_map_logical_idx | None)]. The index is the
    position (among `semantic_ops`) of the last SemMap before the
    RelFilter that defines its column — the filter then applies to that
    map's extracted value; None means it filters the structured row
    (pinned only by a SemTopK/SemAgg barrier)."""
    out: List[Tuple[RelFilter, Optional[int]]] = []
    producer: Dict[str, int] = {}
    li = -1
    for n in query.nodes:
        if isinstance(n, RelFilter):
            if li >= 0:       # leading prefilters run at ingestion instead
                out.append((n, producer.get(n.column)))
            continue
        li += 1
        if isinstance(n, SemMap):
            producer[n.out_column] = li
    return out


def leading_relational(query: Query) -> List[RelFilter]:
    """The relational prefilters of a normalized query: the RelFilters
    before the first semantic node (these run at ingestion and shrink
    the corpus every cascade stage prices)."""
    out: List[RelFilter] = []
    for n in query.nodes:
        if isinstance(n, RelFilter):
            out.append(n)
        else:
            break
    return out


def as_tree(query: Query) -> LogicalNode:
    """The degenerate tree of a linear query: one PipelineLeaf."""
    return PipelineLeaf(tuple(query.nodes))


def lower_tree(tree: LogicalNode) -> LogicalNode:
    """Normalize a logical tree: TopK/Agg wrappers lower into their
    child pipeline's node list (they are legal pipeline nodes), each
    leaf is relationally normalized, and joins recurse into both sides.
    The result is a PipelineLeaf or a JoinNode of lowered sub-trees."""
    if isinstance(tree, PipelineLeaf):
        return PipelineLeaf(tuple(normalize(Query(list(tree.nodes))).nodes))
    if isinstance(tree, TopKNode):
        child = lower_tree(tree.child)
        if not isinstance(child, PipelineLeaf):
            raise ValueError("SemTopK over a join is not supported yet — "
                             "apply .sem_topk to one corpus")
        return PipelineLeaf(child.nodes + (tree.op,))
    if isinstance(tree, AggNode):
        child = lower_tree(tree.child)
        if not isinstance(child, PipelineLeaf):
            raise ValueError("SemAgg over a join is not supported yet — "
                             "apply .sem_agg to one corpus")
        return PipelineLeaf(child.nodes + (tree.op,))
    if isinstance(tree, JoinNode):
        return JoinNode(lower_tree(tree.left), lower_tree(tree.right),
                        tree.op, tree.pair_nodes)
    raise ValueError(f"unknown logical node {tree!r}")
