"""Logical plans: relational + semantic operators over a multimodal corpus.

Mirrors the paper's execution model: a DAG (here: a pipeline, which is what
the optimizer operates on after pull-up) of relational operators and
semantic operators (filters / maps) with natural-language parameters.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class SemFilter:
    """LLM-powered predicate over an item's unstructured payload."""
    text: str                     # natural-language predicate
    task_id: int                  # dataset task the predicate evaluates
    modality: str = "text"        # text | image


@dataclass(frozen=True)
class SemMap:
    """LLM-powered extraction producing a new column."""
    text: str
    task_id: int
    out_column: str = "extracted"
    modality: str = "text"


@dataclass(frozen=True)
class RelFilter:
    """Classical relational predicate over structured columns (cheap)."""
    column: str
    op: str                       # == | != | < | > | in
    value: Any

    def apply(self, row: Dict[str, Any]) -> bool:
        v = row.get(self.column)
        if self.op == "==":
            return v == self.value
        if self.op == "!=":
            return v != self.value
        if self.op == "<":
            return v < self.value
        if self.op == ">":
            return v > self.value
        if self.op == "in":
            return v in self.value
        raise ValueError(self.op)


SemanticOp = Any   # SemFilter | SemMap
PlanNode = Any     # SemanticOp | RelFilter


@dataclass
class Query:
    nodes: List[PlanNode]
    target_recall: float = 0.9
    target_precision: float = 0.9

    @property
    def semantic_ops(self) -> List[SemanticOp]:
        return [n for n in self.nodes
                if isinstance(n, (SemFilter, SemMap))]

    @property
    def relational_ops(self) -> List[RelFilter]:
        return [n for n in self.nodes if isinstance(n, RelFilter)]


def pull_up_semantic(query: Query) -> Query:
    """Step 1 of optimization: execute relational operators first so that
    LLM-powered operators see fewer tuples (paper Fig. 2, step 1).

    For a pipeline of commuting filters this is exact; maps produce new
    columns that relational filters here never reference (enforced by
    construction of our workloads), so the pull-up is always legal.
    """
    rel = [n for n in query.nodes if isinstance(n, RelFilter)]
    sem = [n for n in query.nodes if not isinstance(n, RelFilter)]
    return Query(nodes=rel + sem,
                 target_recall=query.target_recall,
                 target_precision=query.target_precision)
