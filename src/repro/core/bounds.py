"""Bayesian credible lower bounds on precision/recall (paper §3.1, Eq. 8-9).

Recall_D | sample  ~  Beta(1 + TP, 1 + FN)      (uninformative Beta(1,1) prior)
lower bound  l_a   =  quantile(1 - a)  of that posterior
                   =  betaincinv(1 + TP, 1 + FN, 1 - a)

The paper optimizes *against* these bounds with gradient descent, so the
inverse regularized incomplete beta function must be differentiable in
(a, b) = (1+TP, 1+FN). scipy is not available; we implement betaincinv by
bisection (values) and attach gradients via the implicit function theorem:

    I(x; a, b) = q                      (q fixed)
    dx/da = -(dI/da) / pdf(x; a, b)     dI/da by central differences
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import betainc, betaln


def _beta_logpdf(x, a, b):
    return ((a - 1.0) * jnp.log(x) + (b - 1.0) * jnp.log1p(-x)
            - betaln(a, b))


def _betaincinv_bisect(a, b, q, iters: int = 60):
    lo = jnp.zeros_like(q)
    hi = jnp.ones_like(q)

    def body(i, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        below = betainc(a, b, mid) < q
        lo = jnp.where(below, mid, lo)
        hi = jnp.where(below, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


@jax.custom_vjp
def betaincinv(a, b, q):
    """x such that I(x; a, b) = q. Differentiable in a, b (and q)."""
    return _betaincinv_bisect(a, b, q)


def _fwd(a, b, q):
    x = _betaincinv_bisect(a, b, q)
    return x, (a, b, q, x)


def _bwd(res, g):
    a, b, q, x = res
    x = jnp.clip(x, 1e-12, 1.0 - 1e-12)
    pdf = jnp.exp(_beta_logpdf(x, a, b))
    pdf = jnp.maximum(pdf, 1e-30)
    # central differences for dI/da, dI/db (no closed form)
    ha = 1e-4 * jnp.maximum(a, 1.0)
    hb = 1e-4 * jnp.maximum(b, 1.0)
    dIda = (betainc(a + ha, b, x) - betainc(a - ha, b, x)) / (2 * ha)
    dIdb = (betainc(a, b + hb, x) - betainc(a, b - hb, x)) / (2 * hb)
    dxda = -dIda / pdf
    dxdb = -dIdb / pdf
    dxdq = 1.0 / pdf
    return (g * dxda, g * dxdb, g * dxdq)


betaincinv.defvjp(_fwd, _bwd)


def beta_lower_bound(successes, failures, credibility: float = 0.95):
    """l such that P(rate >= l | successes, failures) = credibility.

    Differentiable in (successes, failures) — soft counts welcome.
    """
    a = jnp.asarray(1.0 + successes, jnp.float32)
    b = jnp.asarray(1.0 + failures, jnp.float32)
    q = jnp.asarray(1.0 - credibility, jnp.float32)
    return betaincinv(a, b, q)


def recall_lower_bound(tp, fn, credibility: float = 0.95):
    return beta_lower_bound(tp, fn, credibility)


def precision_lower_bound(tp, fp, credibility: float = 0.95):
    return beta_lower_bound(tp, fp, credibility)
