"""Baseline optimizers from the paper's Exp 1 / Exp 3, integrated into the
same planning/execution stack as Stretto:

  LotusSupG       — per-operator guarantees, global target split evenly,
                    two-stage cascades (small uncompressed model -> gold),
                    thresholds from frequentist normal-approx bounds (SupG).
  ParetoCascades  — Abacus-style combinatorial search over cascade configs
                    with fixed default thresholds; picks the cheapest plan
                    meeting targets ON THE SAMPLE (no statistical guarantee).
  StrettoLocal    — ablation: the gradient optimizer, but per-operator with
                    evenly split targets (Exp 3).
  StrettoIndependent — ablation: joint optimization, but the global bound is
                    the product of per-operator bounds at credibility
                    alpha^(1/m) (independence assumption; Exp 3).
"""
from __future__ import annotations

import itertools
import time
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds as B
from repro.core import relaxation as R
from repro.core.logical import Query, SemFilter, SemMap, pull_up_semantic
from repro.core.optimizer import (OptimizedPlan, PlannerConfig,
                                  flatten_params, init_pipeline_params,
                                  optimize_query, unflatten_params)
from repro.core.physical import PhysicalPlan, PhysicalPlanStage
from repro.core.profiling import profile_query
from repro.runtime.plan_utils import gold_membership, pipelines_data


def _normal_lower(p_hat: float, n: int, z: float = 1.645) -> float:
    """One-sided 95% normal-approximation lower bound (Lotus/SupG style)."""
    if n == 0:
        return 0.0
    return p_hat - z * np.sqrt(max(p_hat * (1 - p_hat), 1e-9) / n)


def _plan_from_selection(profiles, selections, thresholds, items_n,
                         bounds=(0.0, 0.0), feasible=True,
                         est_cost=0.0, t_plan=0.0) -> PhysicalPlan:
    """selections: per logical op, list of chosen op indices (gold last).
    thresholds: dict (li, i) -> (thr_hi, thr_lo)."""
    stages = []
    for li, p in enumerate(profiles):
        n_ops = p.scores.shape[0]
        for stage_no, i in enumerate(selections[li]):
            hi, lo = thresholds.get((li, i), (0.0, 0.0))
            stages.append(PhysicalPlanStage(
                logical_idx=li, stage=stage_no, op_name=p.op_names[i],
                thr_hi=hi, thr_lo=lo, is_map=p.is_map,
                is_gold=(i == n_ops - 1), cost=float(p.costs[i]),
                engine=p.op_engines[i] if p.op_engines is not None else ""))
    return PhysicalPlan(stages=stages, relational=[], est_cost=est_cost,
                        recall_bound=bounds[0], precision_bound=bounds[1],
                        feasible=feasible, planning_time_s=t_plan)


# ---------------------------------------------------------------------------
# Lotus / SupG
# ---------------------------------------------------------------------------

def plan_lotus(query: Query, items, registry, sample_frac: float = 0.15,
               seed: int = 0, small_index: int = -2) -> PhysicalPlan:
    """Two-stage cascades (small uncompressed -> gold) with per-operator
    targets T^(1/m) and SupG-style threshold selection."""
    t0 = time.perf_counter()
    query = pull_up_semantic(query)
    profiles, sample_idx = profile_query(query, items, registry,
                                         sample_frac, seed)
    m = max(len(profiles), 1)
    t_rec = query.target_recall ** (1.0 / m)
    t_prec = query.target_precision ** (1.0 / m)

    selections, thresholds = [], {}
    for li, p in enumerate(profiles):
        n_ops = p.scores.shape[0]
        # "small model" = uncompressed small LLM: by convention the highest
        # -cost sm op; callers pass registries where that op exists.
        small = n_ops + small_index if small_index < 0 else small_index
        small = max(0, min(small, n_ops - 2))
        gold_i = n_ops - 1
        s_small = p.scores[small]
        if p.is_map:
            corr = p.correct[small]
            # threshold on confidence: commit only above thr; choose the
            # smallest thr whose committed accuracy has lb >= t_rec
            cand = np.quantile(s_small, np.linspace(0.0, 0.95, 24))
            thr = float("inf")
            for t in cand:
                mask = s_small > t
                if mask.sum() == 0:
                    continue
                acc = corr[mask].mean()
                if _normal_lower(acc, int(mask.sum())) >= min(t_rec, t_prec):
                    thr = float(t)
                    break
            thresholds[(li, small)] = (thr, -np.inf)
        else:
            gold_acc = p.scores[gold_i] > 0
            pos = gold_acc
            cand = np.quantile(s_small, np.linspace(0.02, 0.98, 33))
            # accept-threshold: precision of {s > hi} >= t_prec
            hi = float("inf")
            for t in cand[::-1]:
                mask = s_small > t
                if mask.sum() < 3:
                    continue
                prec = pos[mask].mean()
                if _normal_lower(prec, int(mask.sum())) >= t_prec:
                    hi = float(t)
            # reject-threshold: recall of kept positives >= t_rec
            lo = -float("inf")
            for t in cand:
                kept = s_small >= t
                if pos.sum() == 0:
                    break
                rec = (kept & pos).sum() / max(pos.sum(), 1)
                if _normal_lower(rec, int(pos.sum())) >= t_rec:
                    lo = float(t)
                else:
                    break
            thresholds[(li, small)] = (hi, lo)
        selections.append([small, gold_i])

    return _plan_from_selection(
        profiles, selections, thresholds, len(items),
        bounds=(t_rec ** m, t_prec ** m), feasible=True,
        t_plan=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Abacus Pareto-Cascades
# ---------------------------------------------------------------------------

DEFAULT_LLM_THR = (1.5, -1.5)
DEFAULT_MAP_THR = (1.0, -np.inf)


def plan_pareto_cascades(query: Query, items, registry,
                         sample_frac: float = 0.15, seed: int = 0,
                         max_stages: int = 2) -> PhysicalPlan:
    """Enumerate per-operator cascade configurations (fixed default
    thresholds — the method cannot tune continuous parameters), simulate on
    the sample, keep the Pareto frontier, pick the cheapest configuration
    that meets the targets on the sample. No statistical guarantee."""
    t0 = time.perf_counter()
    query = pull_up_semantic(query)
    profiles, sample_idx = profile_query(query, items, registry,
                                         sample_frac, seed)
    g = jnp.asarray(gold_membership(profiles))
    pipelines = pipelines_data(profiles)

    per_op_choices = []
    for p in profiles:
        n_ops = p.scores.shape[0]
        non_gold = list(range(n_ops - 1))
        choices = [()]
        choices += [(i,) for i in non_gold]
        choices += list(itertools.combinations(non_gold, 2))[:12]
        per_op_choices.append(choices[:16])

    def params_for(config) -> List[R.PipelineParams]:
        out = []
        for p, chosen in zip(profiles, config):
            n_ops = p.scores.shape[0]
            picks = np.full(n_ops, -10.0, np.float32)
            picks[-1] = 10.0
            hi = np.zeros(n_ops, np.float32)
            lo = np.zeros(n_ops, np.float32)
            for i in chosen:
                picks[i] = 10.0
                d = DEFAULT_MAP_THR if p.is_map else DEFAULT_LLM_THR
                hi[i], lo[i] = d
            out.append(R.PipelineParams(jnp.asarray(picks), jnp.asarray(hi),
                                        jnp.asarray(lo)))
        return out

    rng = np.random.default_rng(seed)
    all_configs = list(itertools.product(*per_op_choices))
    if len(all_configs) > 400:
        idx = rng.choice(len(all_configs), 400, replace=False)
        all_configs = [all_configs[i] for i in idx]

    # one jitted, vmapped evaluation over every candidate configuration
    stacked = [params_for(c) for c in all_configs]
    batched = [R.PipelineParams(
        jnp.stack([s[li].pick_logits for s in stacked]),
        jnp.stack([s[li].thr_hi for s in stacked]),
        jnp.stack([s[li].thr_lo for s in stacked]))
        for li in range(len(profiles))]

    @jax.jit
    def eval_all(*plists):
        def one(*plist):
            c = R.query_counts(pipelines, list(plist), g, 0.0, hard=True)
            return c.tp, c.fp, c.fn, c.cost
        return jax.vmap(one)(*plists)

    tp, fp, fn, cost = (np.asarray(x) for x in eval_all(*batched))
    prec_all = tp / np.maximum(tp + fp, 1e-9)
    rec_all = tp / np.maximum(tp + fn, 1e-9)
    ok = (rec_all >= query.target_recall) & \
         (prec_all >= query.target_precision)
    best = None
    if ok.any():
        i = int(np.argmin(np.where(ok, cost, np.inf)))
        best = (all_configs[i], float(cost[i]), float(rec_all[i]),
                float(prec_all[i]))
    if best is None:
        best = (tuple(() for _ in profiles), 0.0, 1.0, 1.0)

    config, cost, rec, prec = best
    selections, thresholds = [], {}
    for li, (p, chosen) in enumerate(zip(profiles, config)):
        n_ops = p.scores.shape[0]
        sel = sorted(chosen) + [n_ops - 1]
        selections.append(sel)
        for i in chosen:
            d = DEFAULT_MAP_THR if p.is_map else DEFAULT_LLM_THR
            thresholds[(li, i)] = d
    return _plan_from_selection(profiles, selections, thresholds, len(items),
                                bounds=(rec, prec), feasible=True,
                                est_cost=cost,
                                t_plan=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Exp 3 ablations
# ---------------------------------------------------------------------------

def plan_stretto_local(query: Query, items, registry,
                       cfg: Optional[PlannerConfig] = None,
                       sample_frac: float = 0.15, seed: int = 0
                       ) -> PhysicalPlan:
    """Gradient optimizer per logical operator with evenly split targets."""
    cfg = cfg if cfg is not None else PlannerConfig()
    t0 = time.perf_counter()
    query = pull_up_semantic(query)
    profiles, _ = profile_query(query, items, registry, sample_frac, seed)
    m = max(len(profiles), 1)
    t_rec = query.target_recall ** (1.0 / m)
    t_prec = query.target_precision ** (1.0 / m)

    selections, thresholds = [], {}
    tot_cost, rb, pb = 0.0, 1.0, 1.0
    feas = True
    for li, p in enumerate(profiles):
        data = pipelines_data([p])[0]
        g_local = ((p.scores[-1] > 0).astype(np.float32)
                   if not p.is_map else np.ones(p.scores.shape[1],
                                                np.float32))
        plan = optimize_query([data], g_local, t_rec, t_prec, cfg)
        sel = [i for i in range(p.scores.shape[0]) if plan.selected[0][i]]
        selections.append(sel)
        for i in sel[:-1]:
            thresholds[(li, i)] = (float(plan.params[0].thr_hi[i]),
                                   float(plan.params[0].thr_lo[i]))
        tot_cost += plan.est_cost
        rb *= plan.recall_bound
        pb *= plan.precision_bound
        feas &= plan.feasible
    return _plan_from_selection(profiles, selections, thresholds, len(items),
                                bounds=(rb, pb), feasible=feas,
                                est_cost=tot_cost,
                                t_plan=time.perf_counter() - t0)


def plan_stretto_independent(query: Query, items, registry,
                             cfg: Optional[PlannerConfig] = None,
                             sample_frac: float = 0.15, seed: int = 0
                             ) -> PhysicalPlan:
    """Joint gradient optimization, but the global bound is the product of
    per-operator bounds at credibility alpha^(1/m) (independence)."""
    cfg = cfg if cfg is not None else PlannerConfig()
    t0 = time.perf_counter()
    query = pull_up_semantic(query)
    profiles, _ = profile_query(query, items, registry, sample_frac, seed)
    pipelines = pipelines_data(profiles)
    m = max(len(profiles), 1)
    alpha = cfg.credibility ** (1.0 / m)
    sizes = [p.scores.shape[0] for p in profiles]
    gs = [(p.scores[-1] > 0).astype(np.float32) if not p.is_map
          else np.ones(p.scores.shape[1], np.float32) for p in profiles]
    N = gs[0].shape[0]
    max_cost = sum(float(jnp.sum(p.costs)) for p in pipelines) * N

    def loss_fn(flat, tau):
        plist = unflatten_params(flat, sizes)
        rb, pb = 1.0, 1.0
        cost = 0.0
        for data, params, g in zip(pipelines, plist, gs):
            accept, c, decided = R.simulate_pipeline(params, data, tau,
                                                     pick_tau=cfg.pick_tau)
            if data.is_map:
                pc = R.pipeline_value_correct(decided, data.correct)
                tp = jnp.sum(pc)
                fn = jnp.sum(1.0 - pc)
                fp = fn
            else:
                gj = jnp.asarray(g)
                tp = jnp.sum(accept * gj)
                fp = jnp.sum(accept * (1 - gj))
                fn = jnp.sum((1 - accept) * gj)
            rb = rb * B.recall_lower_bound(tp, fn, alpha)
            pb = pb * B.precision_lower_bound(tp, fp, alpha)
            cost = cost + jnp.sum(c)
        pen = (jax.nn.relu(query.target_recall + cfg.margin - rb)
               + jax.nn.relu(query.target_precision + cfg.margin - pb))
        return cost / max_cost + cfg.beta * pen, (rb, pb, cost)

    flat = flatten_params([init_pipeline_params(p, 2.0, 0.5)
                            for p in pipelines])
    mm = jnp.zeros_like(flat)
    vv = jnp.zeros_like(flat)
    decay = (cfg.tau_end / cfg.tau_start) ** (1.0 / max(cfg.steps - 1, 1))

    @jax.jit
    def step(state, i):
        flat, mm, vv = state
        tau = cfg.tau_start * decay ** i
        (_, aux), gr = jax.value_and_grad(loss_fn, has_aux=True)(flat, tau)
        mm = 0.9 * mm + 0.1 * gr
        vv = 0.999 * vv + 0.001 * jnp.square(gr)
        t = i.astype(jnp.float32) + 1
        flat = flat - cfg.lr * (mm / (1 - 0.9 ** t)) / (
            jnp.sqrt(vv / (1 - 0.999 ** t)) + 1e-8)
        return (flat, mm, vv), aux

    (flat, _, _), _ = jax.lax.scan(step, (flat, mm, vv),
                                   jnp.arange(cfg.steps))
    _, (rb, pb, cost) = loss_fn(flat, 0.0)
    plist = unflatten_params(flat, sizes)
    selections, thresholds = [], {}
    for li, (p, params) in enumerate(zip(profiles, plist)):
        n_ops = p.scores.shape[0]
        mask = np.array(jax.nn.sigmoid(params.pick_logits) > 0.5)
        mask[-1] = True
        sel = [i for i in range(n_ops) if mask[i]]
        selections.append(sel)
        for i in sel[:-1]:
            thresholds[(li, i)] = (float(params.thr_hi[i]),
                                   float(params.thr_lo[i]))
    return _plan_from_selection(
        profiles, selections, thresholds, len(items),
        bounds=(float(rb), float(pb)),
        feasible=bool(rb >= query.target_recall
                      and pb >= query.target_precision),
        est_cost=float(cost), t_plan=time.perf_counter() - t0)
