"""Continuous relaxation of cascade plans (paper §4.1, Eq. 1-7 & 16).

Everything here is a differentiable jnp program over the *profiled sample*:
given per-(operator, tuple) raw scores, pick logits and thresholds, simulate
the soft cascade and produce soft TP/FP/FN and expected cost. The planner
differentiates through this (and through the Beta credible bounds) with Adam.

Conventions
-----------
A *logical* operator is implemented by a pipeline (cascade) of physical
operators sorted by cost; the LAST one is the gold operator: always selected,
never unsure.

Per logical op j we have arrays over its pipeline of n_j physical ops:
  scores   (n_j, N)  raw decision scores on the sample (log-odds / cosine)
  gold_dec (n_j, N)  hard accept decision of each op at tau->0 given theta
  costs    (n_j,)    per-tuple cost seconds
and trainable params:
  pick_logits (n_j,)       sigma_i = sigmoid(pick/tau)
  thr_hi, thr_lo (n_j,)    accept if score > thr_hi, reject if < thr_lo

For maps, scores are *confidences* and correctness (n_j, N) in {0,1} says
whether op i's output value equals the gold op's value for tuple t; the
reject branch is disabled (a map commits or defers).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.dispatch import DEFAULT_COALESCE
from repro.runtime.kernel import decide_traced


class PipelineParams(NamedTuple):
    pick_logits: jax.Array   # (n,)
    thr_hi: jax.Array        # (n,)
    thr_lo: jax.Array        # (n,)


class PipelineData(NamedTuple):
    scores: jax.Array        # (n, N) raw scores per op per sample tuple
    costs: jax.Array         # (n,) marginal per-tuple cost (seconds)
    is_map: bool             # map pipelines have no reject branch
    correct: Optional[jax.Array] = None   # (n, N) for maps: value == gold
    fixed: Optional[jax.Array] = None     # (n,) per-call fixed cost (s);
    #                                       None: scalar cost model
    batch_cap: Optional[jax.Array] = None  # (n,) memory-budgeted max batch
    #                                        per op (inf: unbounded)
    meas_width: Optional[jax.Array] = None  # (n,) measured flush width per
    #                                         op from past executions
    #                                         (nan: unmeasured — fall back
    #                                         to BatchHint.width)
    no_accept: bool = False  # SemTopK pipelines: non-gold stages may only
    #                          reject (their accept mass stays unsure) —
    #                          the accept boundary is the global rank cut,
    #                          which only the gold scorer can place


class BatchHint(NamedTuple):
    """Execution-batching context for the batch-size-aware cost model.

    The streaming executor flushes each stage in coalesced batches of
    ~`width` tuples (capped by the op's own memory-budgeted max batch and
    by how many tuples actually reach the op); `scale` converts
    sample-tuple reach mass into corpus tuples (N_corpus / N_sample).
    With these, expected cost amortizes each op's fixed per-call cost
    over the batch size it will really see — the paper's §5 batching
    speedup (higher KV compression -> larger batches -> fewer calls) made
    visible to the optimizer."""
    # executor coalesce width (tuples per flush) — defaults to the
    # runtime's shared constant so planner and executor price/run the
    # same flush size out of the box
    width: float = float(DEFAULT_COALESCE)
    scale: float = 1.0       # corpus tuples per profiled sample tuple


def soft_decisions(scores, thr_hi, thr_lo, tau, is_map: bool):
    """Eq. 16: softmax_tau([s - thr_hi, thr_lo - s, 0]) -> (acc, rej, uns)."""
    z_acc = scores - thr_hi[:, None]
    z_rej = thr_lo[:, None] - scores
    z_uns = jnp.zeros_like(z_acc)
    if is_map:
        z_rej = jnp.full_like(z_rej, -1e9)
    z = jnp.stack([z_acc, z_rej, z_uns], axis=0) / jnp.maximum(tau, 1e-6)
    p = jax.nn.softmax(z, axis=0)
    return p[0], p[1], p[2]


def hard_decisions(scores, thr_hi, thr_lo, is_map: bool):
    """tau -> 0 limit of soft_decisions: argmax of the three logits, via
    the shared runtime decision kernel (the executor applies the exact
    same rule, so extraction and execution cannot drift)."""
    return decide_traced(scores, thr_hi[:, None], thr_lo[:, None], is_map)


def simulate_pipeline(params: PipelineParams, data: PipelineData, tau,
                      hard: bool = False, pick_tau=None,
                      batch_hint: Optional[BatchHint] = None,
                      reach_weight=None):
    """Soft cascade (Eq. 1-3) for one logical operator.

    Returns (p_accept (N,), expected_cost (N,), p_chosen (n, N)).
    p_chosen[i, t] = probability tuple t is *decided* by op i (its accept or
    reject fires) — used by maps to weight value correctness.

    When `data.fixed` is set, per-op cost is batch-size-aware: the
    expected flush batch at op i is min(reach_i * scale, width_i, cap_i)
    where reach_i is the expected number of sample tuples the op scores,
    and cost becomes per_tuple + fixed / batch — differentiable, so the
    optimizer feels that a rarely-reached (or memory-capped) op pays its
    per-call overhead on tiny batches. width_i is the op's *measured*
    flush width from past executions (`data.meas_width`) where one is
    recorded, else the hint's static coalesce width — the measured-batch
    feedback loop pricing ops at the batches they really saw.
    `reach_weight` (N,) is each tuple's probability of reaching this
    pipeline at all (upstream filters' survival, supplied by
    query_counts); the executor never scores upstream-rejected tuples,
    so they must not inflate the expected batch.
    """
    n, N = data.scores.shape
    hint = batch_hint if batch_hint is not None else BatchHint()
    fixed = data.fixed if data.fixed is not None \
        else jnp.zeros_like(data.costs)
    cap = data.batch_cap if data.batch_cap is not None \
        else jnp.full_like(data.costs, jnp.inf)
    base_w = jnp.full_like(data.costs, hint.width) \
        if data.meas_width is None \
        else jnp.where(jnp.isnan(data.meas_width), hint.width,
                       data.meas_width)
    width = jnp.minimum(cap, base_w)        # (n,) max feasible flush size
    weight = jnp.ones(N) if reach_weight is None else reach_weight
    if hard:
        sigma = (jax.nn.sigmoid(params.pick_logits) > 0.5).astype(jnp.float32)
        acc_i, rej_i, uns_i = hard_decisions(
            data.scores, params.thr_hi, params.thr_lo, data.is_map)
        acc_i = acc_i.astype(jnp.float32)
        rej_i = rej_i.astype(jnp.float32)
        uns_i = uns_i.astype(jnp.float32)
    else:
        pt = tau if pick_tau is None else pick_tau
        sigma = jax.nn.sigmoid(params.pick_logits / jnp.maximum(pt, 1e-6))
        acc_i, rej_i, uns_i = soft_decisions(
            data.scores, params.thr_hi, params.thr_lo, tau, data.is_map)
    if data.no_accept:
        # reject-only cascade (SemTopK): a non-gold accept is illegal —
        # only the gold rank cut admits — so its mass stays unsure. The
        # gold override below still applies (its scores are pre-shifted
        # by the sample rank threshold, so >0 means "in the top k").
        uns_i = uns_i + acc_i
        acc_i = jnp.zeros_like(acc_i)
    # gold (last) op: always selected, never unsure, decides at its natural
    # boundary (log-odds 0) — it defines the reference, so no learned
    # thresholds apply to it. Maps always commit.
    sigma = sigma.at[-1].set(1.0)
    if data.is_map:
        gold_acc = jnp.ones_like(acc_i[-1])
    elif hard:
        gold_acc = (data.scores[-1] > 0.0).astype(jnp.float32)
    else:
        gold_acc = jax.nn.sigmoid(data.scores[-1] / jnp.maximum(tau, 1e-6))
    acc_i = acc_i.at[-1].set(gold_acc)
    rej_i = rej_i.at[-1].set(1.0 - gold_acc)
    uns_i = uns_i.at[-1].set(0.0)

    def step(carry, xs):
        accept, reject, unsure, cost = carry
        s, a_i, r_i, c_i, f_i, w_i = xs
        reach = unsure * s       # P(op i scores tuple t | reaches pipeline)
        # expected coalesced flush batch at this op: how many corpus
        # tuples reach it (upstream survival included), clipped by
        # coalesce width and its memory cap
        b_i = jnp.maximum(
            jnp.minimum(jnp.sum(reach * weight) * hint.scale, w_i), 1.0)
        cost = cost + reach * (c_i + f_i / b_i)           # Eq. 4 (w/ sigma,
        #                                                   amortized fixed)
        new_accept = accept + unsure * s * a_i            # Eq. 1
        new_reject = reject + unsure * s * r_i            # Eq. 2
        new_unsure = 1.0 - new_accept - new_reject        # Eq. 3
        decided_here = unsure * s * (a_i + r_i)
        return (new_accept, new_reject, new_unsure, cost), decided_here

    init = (jnp.zeros(N), jnp.zeros(N), jnp.ones(N), jnp.zeros(N))
    (accept, reject, unsure, cost), decided = jax.lax.scan(
        step, init, (sigma, acc_i, rej_i, data.costs, fixed, width))
    # numerical guard: any residual unsure mass goes to reject
    accept = jnp.clip(accept, 0.0, 1.0)
    return accept, cost, decided


def pipeline_value_correct(decided: jax.Array, correct: jax.Array):
    """Maps: P(value correct) = sum_i P(decided by i) * correct_i."""
    total = jnp.maximum(decided.sum(0), 1e-9)
    return (decided * correct).sum(0) / total * jnp.clip(decided.sum(0), 0, 1)


class QueryCounts(NamedTuple):
    tp: jax.Array
    fp: jax.Array
    fn: jax.Array
    cost: jax.Array          # total expected cost over sample (seconds)


def query_counts(pipelines, params_list, gold_membership, tau,
                 hard: bool = False, pick_tau=None,
                 batch_hint: Optional[BatchHint] = None) -> QueryCounts:
    """Global soft TP/FP/FN over a query with several logical operators.

    pipelines: list[PipelineData]; params_list: list[PipelineParams]
    gold_membership: (N,) {0,1} — tuple in the gold plan's result set
    (all gold filters accept AND all gold map values correct, i.e. 1 by
    construction for maps vs themselves).

    TP_t = prod_j p_agree_j(t) * g_t ; FP_t = p_in_o(t) - TP_t ;
    FN_t = g_t - TP_t (paper §4.2 — no independence assumption: the product
    is per-tuple over the *same* sample, capturing correlations).
    """
    N = gold_membership.shape[0]
    p_in = jnp.ones(N)
    p_good = jnp.ones(N)
    total_cost = jnp.zeros(N)
    survive = jnp.ones(N)    # tuples reaching this pipeline (plan order)
    for data, params in zip(pipelines, params_list):
        accept, cost, decided = simulate_pipeline(params, data, tau, hard,
                                                  pick_tau, batch_hint,
                                                  reach_weight=survive)
        total_cost = total_cost + survive * cost
        if data.is_map:
            p_corr = pipeline_value_correct(decided, data.correct)
            p_good = p_good * p_corr
        else:
            p_in = p_in * accept
            p_good = p_good * accept
            survive = survive * accept
    g = gold_membership.astype(jnp.float32)
    tp = jnp.sum(p_good * g)
    fp = jnp.sum(jnp.maximum(p_in - p_good * g, 0.0))
    fn = jnp.sum(jnp.maximum(g - p_good * g, 0.0))
    return QueryCounts(tp, fp, fn, jnp.sum(total_cost))


class TreeGroup(NamedTuple):
    """One pipeline group of a tree-shaped query in the relaxation.

    The join relaxation runs over *pair coordinates*: every sample tuple
    t = (i, j) pairs a left-sample item with a right-sample item, and
    each side's per-op scores are broadcast onto those coordinates
    (score[op, t] = score[op, i]). Groups structure the survive chain:

      kind "side" — an independent input pipeline (a join side). Its
        reach resets to 1 (the side scans its own corpus regardless of
        the other side's outcomes) and its survival multiplies the
        downstream entry mass.
      kind "pair" — a downstream pairing cascade: a pair is only scored
        when BOTH sides survived, so its entry reach is the product of
        the completed side survivals.

    cost_weight converts summed pair-coordinate reach mass into corpus
    tuples for this group (a left op's reach is constant across the j
    axis, so its pair-coordinate sum overcounts by n_right_sample; the
    weight divides that back out and folds in the sample->corpus scale),
    making QueryCounts.cost the corpus-level expected cost directly.
    hint is the group's own BatchHint (each group flushes against its
    own corpus, so each amortizes fixed costs over its own widths)."""
    count: int               # number of pipelines in this group
    kind: str                # "side" | "pair"
    cost_weight: float       # pair-coordinate reach -> corpus tuples
    hint: BatchHint          # group-local batch context


def tree_counts(pipelines, params_list, gold_membership, groups, tau,
                hard: bool = False, pick_tau=None) -> QueryCounts:
    """`query_counts` generalized to a grouped plan tree (paper's
    query-level budget allocation across pipelines, extended past the
    linear chain).

    pipelines/params_list are concatenated group-major ([left ops...,
    right ops..., pair ops...]); `groups` names the boundaries. TP/FP/FN
    keep the exact per-tuple product form of `query_counts` — a pair is
    in the result iff its left side passes, its right side passes, and
    the pairing cascade accepts, which is precisely the product of
    accepts over all three groups on the shared pair coordinates — so
    the recall/precision budget splits across the tree's pipelines
    through one joint optimization rather than per-pipeline heuristics.
    """
    N = gold_membership.shape[0]
    p_in = jnp.ones(N)
    p_good = jnp.ones(N)
    total_cost = jnp.zeros(N)
    entry_acc = jnp.ones(N)  # product of completed side-group survivals
    idx = 0
    for grp in groups:
        survive = jnp.ones(N) if grp.kind == "side" else entry_acc
        for _ in range(grp.count):
            data, params = pipelines[idx], params_list[idx]
            idx += 1
            accept, cost, decided = simulate_pipeline(
                params, data, tau, hard, pick_tau, grp.hint,
                reach_weight=survive)
            total_cost = total_cost + grp.cost_weight * survive * cost
            if data.is_map:
                p_corr = pipeline_value_correct(decided, data.correct)
                p_good = p_good * p_corr
            else:
                p_in = p_in * accept
                p_good = p_good * accept
                survive = survive * accept
        if grp.kind == "side":
            entry_acc = entry_acc * survive
    g = gold_membership.astype(jnp.float32)
    tp = jnp.sum(p_good * g)
    fp = jnp.sum(jnp.maximum(p_in - p_good * g, 0.0))
    fn = jnp.sum(jnp.maximum(g - p_good * g, 0.0))
    return QueryCounts(tp, fp, fn, jnp.sum(total_cost))
