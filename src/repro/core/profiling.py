"""Profiling of semantic operators on a data sample (paper Fig. 2, step 2).

Runs every available physical operator on an i.i.d. sample through the
runtime's single operator-invocation path (`repro.runtime.run_operator`),
recording raw outputs (log-odds / values) and measured per-tuple cost.
Storing outputs lets the planner simulate any search-space configuration
without further LLM calls — exactly the paper's approach — and because
profiling and execution share one invocation path, profiled costs are
measured under the same batching/telemetry regime the executor uses.

`registry` may be a legacy `op -> [PhysicalOperator]` callable or any
runtime Backend.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import numpy as np

from repro.core.logical import Query, SemMap
from repro.core.physical import ProfiledPipeline


def profile_query(query: Query, items: Sequence[Any],
                  registry, sample_frac: float = 0.15,
                  seed: int = 0, min_sample: int = 20):
    """Returns (profiles: list[ProfiledPipeline], sample_idx).

    registry: Backend, or callable (semantic_op) -> list[PhysicalOperator]
    sorted by cost_model(), gold LAST.
    """
    # deferred import: the runtime depends on core's plan dataclasses, so
    # importing it at module load would cycle through repro.core.__init__
    from repro.runtime.backend import as_backend
    from repro.runtime.executor import run_operator

    backend = as_backend(registry)
    rng = np.random.default_rng(seed)
    n = len(items)
    k = max(min_sample, int(round(sample_frac * n)))
    k = min(k, n)
    sample_idx = np.sort(rng.choice(n, size=k, replace=False))
    sample = [items[i] for i in sample_idx]

    profiles: List[ProfiledPipeline] = []
    for li, op in enumerate(query.semantic_ops):
        ops = backend.candidates(op)
        assert ops[-1].is_gold, "gold operator must be last in the registry"
        scores, costs, values = [], [], []
        for phys in ops:
            out = run_operator(backend, op, phys.name, sample)
            scores.append(np.asarray(out.scores, np.float32))
            costs.append(max(out.wall_s / max(len(sample), 1), 1e-9))
            if out.values is not None:
                values.append(np.asarray(out.values))
        is_map = isinstance(op, SemMap)
        prof = ProfiledPipeline(
            logical_idx=li, is_map=is_map,
            op_names=[p.name for p in ops],
            scores=np.stack(scores),
            costs=np.asarray(costs, np.float32),
        )
        if is_map:
            vals = np.stack(values)
            prof.values = vals
            prof.correct = (vals == vals[-1][None, :]).astype(np.float32)
        profiles.append(prof)
    return profiles, sample_idx
