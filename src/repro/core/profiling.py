"""Profiling of semantic operators on a data sample (paper Fig. 2, step 2).

Runs every available physical operator on an i.i.d. sample through the
runtime's single operator-invocation path (`repro.runtime.run_operator`),
recording raw outputs (log-odds / values) and measured per-tuple cost.
Storing outputs lets the planner simulate any search-space configuration
without further LLM calls — exactly the paper's approach — and because
profiling and execution share one invocation path, profiled costs are
measured under the same batching/telemetry regime the executor uses.

Cost is batch-size-aware: each operator is timed at two warmed sub-sample
batch sizes (so jit compilation pollutes neither point) and a
`CostCurve(fixed_s, per_tuple_s)` is fitted through them, so the planner
can amortize fixed
per-call overhead over the coalesced flush width the executor will really
use — a scalar per-tuple cost from one full-sample batch hides exactly
the batching speedup (paper §5) the KV-compression ladder buys.
Operators also report their memory-budgeted `max_batch` (higher
compression -> larger batches), recorded as the pipeline's batch caps.

`registry` may be a legacy `op -> [PhysicalOperator]` callable or any
runtime Backend.
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import numpy as np

from repro.core.logical import Query, SemMap
from repro.core.physical import CostCurve, ProfiledPipeline


def fit_cost_curve(points: Sequence[Tuple[int, float]]) -> CostCurve:
    """Least-squares fit of wall = fixed + per_tuple * batch through
    (batch_size, wall_s) points; both coefficients clamped non-negative
    (timing noise can produce a negative intercept or slope)."""
    if len(points) < 2:
        b, w = points[0]
        return CostCurve(0.0, max(w / max(b, 1), 1e-9))
    bs = np.asarray([p[0] for p in points], np.float64)
    ws = np.asarray([p[1] for p in points], np.float64)
    var = float(np.sum((bs - bs.mean()) ** 2))
    slope = float(np.sum((bs - bs.mean()) * (ws - ws.mean()))) / max(var,
                                                                     1e-12)
    slope = max(slope, 1e-9)
    fixed = max(float(ws.mean()) - slope * float(bs.mean()), 0.0)
    return CostCurve(fixed, slope)


def profile_query(query: Query, items: Sequence[Any],
                  registry, sample_frac: float = 0.15,
                  seed: int = 0, min_sample: int = 20):
    """Returns (profiles: list[ProfiledPipeline], sample_idx).

    registry: Backend, or callable (semantic_op) -> list[PhysicalOperator]
    sorted by cost_model(), gold LAST.
    """
    # deferred import: the runtime depends on core's plan dataclasses, so
    # importing it at module load would cycle through repro.core.__init__
    from repro.runtime.backend import as_backend
    from repro.runtime.executor import run_operator

    backend = as_backend(registry)
    rng = np.random.default_rng(seed)
    n = len(items)
    k = max(min_sample, int(round(sample_frac * n)))
    k = min(k, n)
    sample_idx = np.sort(rng.choice(n, size=k, replace=False))
    sample = [items[i] for i in sample_idx]
    # cost-curve points: two sub-sample batch sizes, each timed on a
    # *second* (warmed) call so jit compilation lands in neither point —
    # the full-sample scoring run stays cold (its compile would otherwise
    # masquerade as per-tuple cost in the fit)
    b_small = max(2, k // 8) if k >= 9 else 0
    b_mid = max(b_small + 1, k // 3) if b_small else 0

    profiles: List[ProfiledPipeline] = []
    for li, op in enumerate(query.semantic_ops):
        ops = backend.candidates(op)
        assert ops[-1].is_gold, "gold operator must be last in the registry"
        scores, costs, values, curves, caps = [], [], [], [], []
        for phys in ops:
            out = run_operator(backend, op, phys.name, sample)
            scores.append(np.asarray(out.scores, np.float32))
            costs.append(max(out.wall_s / max(len(sample), 1), 1e-9))
            if out.values is not None:
                values.append(np.asarray(out.values))
            points = []
            if b_small:
                for b in (b_small, b_mid):
                    run_operator(backend, op, phys.name, sample[:b])  # warm
                    timed = run_operator(backend, op, phys.name, sample[:b])
                    points.append((b, timed.wall_s))
            else:       # sample too small to fit a line: scalar model
                points.append((len(sample), out.wall_s))
            curves.append(fit_cost_curve(points))
            cap_fn = getattr(phys, "max_batch", None)
            cap = cap_fn() if callable(cap_fn) else None
            caps.append(float(cap) if cap else np.inf)
        is_map = isinstance(op, SemMap)
        prof = ProfiledPipeline(
            logical_idx=li, is_map=is_map,
            op_names=[p.name for p in ops],
            scores=np.stack(scores),
            costs=np.asarray(costs, np.float32),
            cost_curves=curves,
            batch_caps=np.asarray(caps, np.float64),
        )
        if is_map:
            vals = np.stack(values)
            prof.values = vals
            prof.correct = (vals == vals[-1][None, :]).astype(np.float32)
        profiles.append(prof)
    return profiles, sample_idx
