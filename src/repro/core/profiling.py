"""Profiling of semantic operators on a data sample (paper Fig. 2, step 2).

Runs every available physical operator on an i.i.d. sample through the
runtime's single operator-invocation path (`repro.runtime.run_operator`),
recording raw outputs (log-odds / values) and measured per-tuple cost.
Storing outputs lets the planner simulate any search-space configuration
without further LLM calls — exactly the paper's approach — and because
profiling and execution share one invocation path, profiled costs are
measured under the same batching/telemetry regime the executor uses.

Cost is batch-size-aware: each operator is timed at two warmed sub-sample
batch sizes (so jit compilation pollutes neither point) and a
`CostCurve(fixed_s, per_tuple_s)` is fitted through them, so the planner
can amortize fixed
per-call overhead over the coalesced flush width the executor will really
use — a scalar per-tuple cost from one full-sample batch hides exactly
the batching speedup (paper §5) the KV-compression ladder buys.
Operators also report their memory-budgeted `max_batch` (higher
compression -> larger batches), recorded as the pipeline's batch caps.

Sample profiling predicts; `MeasuredBatchStore` remembers. The store
aggregates per-operator StageStats from *real* executions — fed live by
`Session` or loaded from the benchmark trajectory's
``stage_stats-<ts>-<sha>.json`` snapshots — and answers the two questions
the planner's batch-aware cost model otherwise guesses from static
defaults: what flush batch does this op actually see (`mean_batch`), and
what does a tuple actually cost there (`wall_per_tuple`). That closes the
measure -> plan loop: `plan_query(measured=...)` prices operators at
their measured flush widths instead of the static coalesce width.

`registry` may be a legacy `op -> [PhysicalOperator]` callable or any
runtime Backend.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.logical import Query, SemMap
from repro.core.physical import CostCurve, ProfiledPipeline


@dataclass
class _OpMeasure:
    """Accumulated measured telemetry for one physical operator."""
    wall_s: float = 0.0
    n_tuples: int = 0
    n_batches: int = 0
    kv_bytes: int = 0

    def add(self, wall_s: float, n_tuples: int, n_batches: int,
            kv_bytes: int = 0) -> None:
        self.wall_s += float(wall_s)
        self.n_tuples += int(n_tuples)
        self.n_batches += int(n_batches)
        self.kv_bytes += int(kv_bytes)


class MeasuredBatchStore:
    """Per-operator measured execution feedback (paper's measure->plan
    loop; cf. cost-aware re-optimization in agentic query execution).

    Accumulates StageStats — from live RuntimeResults or from the
    benchmark trajectory's ``stage_stats*.json`` artifacts — keyed by
    physical operator name, and exposes the measured flush width
    (`mean_batch`) and measured per-tuple wall cost (`wall_per_tuple`)
    the planner's batch-aware cost model can price against instead of
    static defaults. `version` increments on every record/load so plan
    memoizers can key on the store's state.
    """

    def __init__(self) -> None:
        self._by_op: Dict[str, _OpMeasure] = {}
        self.version = 0

    # ---------------- recording ----------------

    def record_stats(self, stage_stats: Sequence[Any]) -> None:
        """Fold in per-stage stats: StageStats objects or their as_dict /
        trajectory-row form (anything with op_name/wall_s/n_tuples/
        n_batches [+ kv_bytes])."""
        for s in stage_stats:
            row = s if isinstance(s, dict) else s.as_dict()
            if not row.get("n_batches"):
                continue            # never flushed: nothing measured
            m = self._by_op.setdefault(row["op_name"], _OpMeasure())
            m.add(row["wall_s"], row["n_tuples"], row["n_batches"],
                  row.get("kv_bytes", 0))
        self.version += 1

    def record_result(self, result: Any) -> None:
        """Fold in a RuntimeResult's stage_stats."""
        self.record_stats(result.stage_stats)

    # ---------------- persistence (the benchmark trajectory) ----------

    def load_file(self, path: str) -> None:
        """Fold in one stage-stats artifact: either the flat list
        ``stage_stats.json`` writes or a timestamped snapshot
        ``{"meta": ..., "stages": [...]}``."""
        with open(path) as f:
            data = json.load(f)
        rows = data.get("stages", []) if isinstance(data, dict) else data
        self.record_stats(rows)

    @classmethod
    def from_dir(cls, root: str, pattern: str = "stage_stats-*.json"
                 ) -> "MeasuredBatchStore":
        """Aggregate every *timestamped* trajectory snapshot under
        `root` (oldest first; the store sums, so order only matters for
        reproducibility of float accumulation). The pattern deliberately
        excludes the flat ``stage_stats.json`` "latest" file — the
        benchmark harness writes the same rows to both, and folding both
        in would double-weight the most recent run against the rest of
        the trajectory."""
        store = cls()
        for path in sorted(glob.glob(os.path.join(root, pattern))):
            try:
                store.load_file(path)
            except (OSError, ValueError):
                continue            # unreadable snapshot: skip, don't fail
        return store

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({op: vars(m) for op, m in self._by_op.items()}, f,
                      indent=1)

    # ---------------- queries the planner asks ----------------

    def __len__(self) -> int:
        return len(self._by_op)

    def __contains__(self, op_name: str) -> bool:
        return op_name in self._by_op

    def op_names(self) -> List[str]:
        return sorted(self._by_op)

    def mean_batch(self, op_name: str) -> Optional[float]:
        """Measured mean coalesced flush width for this op, or None."""
        m = self._by_op.get(op_name)
        if m is None or m.n_batches == 0:
            return None
        return m.n_tuples / m.n_batches

    def wall_per_tuple(self, op_name: str) -> Optional[float]:
        """Measured wall seconds per scored tuple, or None."""
        m = self._by_op.get(op_name)
        if m is None or m.n_tuples == 0:
            return None
        return m.wall_s / m.n_tuples

    def blended_width(self, op_names: Optional[Sequence[str]] = None
                      ) -> Optional[float]:
        """Tuple-weighted mean measured flush width over `op_names` (or
        every recorded op) — the scalar BatchHint.width replacement when
        per-op widths are unavailable downstream. None if nothing
        measured. Duplicate names (an op shared by several logical
        pipelines) are counted once — the store's totals are already
        cross-pipeline sums."""
        names = dict.fromkeys(op_names) if op_names is not None \
            else list(self._by_op)
        tot_t = tot_b = 0
        for name in names:
            m = self._by_op.get(name)
            if m is not None:
                tot_t += m.n_tuples
                tot_b += m.n_batches
        if tot_b == 0:
            return None
        return tot_t / tot_b


def batch_drift(plan, stage_stats: Sequence[Any]) -> float:
    """Largest planned-vs-measured flush-width divergence across a plan's
    executed stages: max over stages of ratio(mean_batch, exp_batch),
    taken both ways so shrink and growth both count. 1.0 = perfect
    agreement; stages the planner gave no batch expectation (exp_batch 0)
    or that never flushed are skipped.
    """
    planned = {(st.logical_idx, st.stage, st.op_name): st.exp_batch
               for st in plan.stages}
    worst = 1.0
    for sg in stage_stats:
        exp = planned.get((sg.logical_idx, sg.stage, sg.op_name), 0.0)
        if not exp or not sg.n_batches:
            continue
        measured = max(sg.mean_batch, 1e-9)
        worst = max(worst, measured / exp, exp / measured)
    return worst


def fit_cost_curve(points: Sequence[Tuple[int, float]]) -> CostCurve:
    """Least-squares fit of wall = fixed + per_tuple * batch through
    (batch_size, wall_s) points; both coefficients clamped non-negative
    (timing noise can produce a negative intercept or slope)."""
    if len(points) < 2:
        b, w = points[0]
        return CostCurve(0.0, max(w / max(b, 1), 1e-9))
    bs = np.asarray([p[0] for p in points], np.float64)
    ws = np.asarray([p[1] for p in points], np.float64)
    var = float(np.sum((bs - bs.mean()) ** 2))
    slope = float(np.sum((bs - bs.mean()) * (ws - ws.mean()))) / max(var,
                                                                     1e-12)
    slope = max(slope, 1e-9)
    fixed = max(float(ws.mean()) - slope * float(bs.mean()), 0.0)
    return CostCurve(fixed, slope)


def profile_query(query: Query, items: Sequence[Any],
                  registry, sample_frac: float = 0.15,
                  seed: int = 0, min_sample: int = 20):
    """Returns (profiles: list[ProfiledPipeline], sample_idx).

    registry: Backend, or callable (semantic_op) -> list[PhysicalOperator]
    sorted by cost_model(), gold LAST.
    """
    # deferred import: the runtime depends on core's plan dataclasses, so
    # importing it at module load would cycle through repro.core.__init__
    from repro.runtime.backend import as_backend
    from repro.runtime.executor import run_operator

    backend = as_backend(registry)
    rng = np.random.default_rng(seed)
    n = len(items)
    k = max(min_sample, int(round(sample_frac * n)))
    k = min(k, n)
    sample_idx = np.sort(rng.choice(n, size=k, replace=False))
    sample = [items[i] for i in sample_idx]
    # cost-curve points: two sub-sample batch sizes, each timed on a
    # *second* (warmed) call so jit compilation lands in neither point —
    # the full-sample scoring run stays cold (its compile would otherwise
    # masquerade as per-tuple cost in the fit)
    b_small = max(2, k // 8) if k >= 9 else 0
    b_mid = max(b_small + 1, k // 3) if b_small else 0

    profiles: List[ProfiledPipeline] = []
    for li, op in enumerate(query.semantic_ops):
        ops = backend.candidates(op)
        assert ops[-1].is_gold, "gold operator must be last in the registry"
        scores, costs, values, curves, caps = [], [], [], [], []
        for phys in ops:
            out = run_operator(backend, op, phys.name, sample)
            scores.append(np.asarray(out.scores, np.float32))
            costs.append(max(out.wall_s / max(len(sample), 1), 1e-9))
            if out.values is not None:
                values.append(np.asarray(out.values))
            points = []
            if b_small:
                for b in (b_small, b_mid):
                    run_operator(backend, op, phys.name, sample[:b])  # warm
                    timed = run_operator(backend, op, phys.name, sample[:b])
                    points.append((b, timed.wall_s))
            else:       # sample too small to fit a line: scalar model
                points.append((len(sample), out.wall_s))
            curves.append(fit_cost_curve(points))
            cap_fn = getattr(phys, "max_batch", None)
            cap = cap_fn() if callable(cap_fn) else None
            caps.append(float(cap) if cap else np.inf)
        is_map = isinstance(op, SemMap)
        prof = ProfiledPipeline(
            logical_idx=li, is_map=is_map,
            op_names=[p.name for p in ops],
            scores=np.stack(scores),
            costs=np.asarray(costs, np.float32),
            cost_curves=curves,
            batch_caps=np.asarray(caps, np.float64),
            op_engines=[getattr(p, "engine_name", "") for p in ops],
        )
        if is_map:
            vals = np.stack(values)
            prof.values = vals
            prof.correct = (vals == vals[-1][None, :]).astype(np.float32)
        profiles.append(prof)
    return profiles, sample_idx
