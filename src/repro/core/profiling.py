"""Profiling of semantic operators on a data sample (paper Fig. 2, step 2).

Runs every available physical operator on an i.i.d. sample, records raw
outputs (log-odds / values) and measured per-tuple cost. Storing outputs
lets the planner simulate any search-space configuration without further
LLM calls — exactly the paper's approach.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Sequence

import numpy as np

from repro.core.logical import Query, SemFilter, SemMap
from repro.core.physical import PhysicalOperator, ProfiledPipeline


def profile_query(query: Query, items: Sequence[Any],
                  registry, sample_frac: float = 0.15,
                  seed: int = 0, min_sample: int = 20):
    """Returns (profiles: list[ProfiledPipeline], sample_idx).

    registry: callable (semantic_op) -> list[PhysicalOperator], sorted by
    cost_model(), gold LAST.
    """
    rng = np.random.default_rng(seed)
    n = len(items)
    k = max(min_sample, int(round(sample_frac * n)))
    k = min(k, n)
    sample_idx = np.sort(rng.choice(n, size=k, replace=False))
    sample = [items[i] for i in sample_idx]

    profiles: List[ProfiledPipeline] = []
    for li, op in enumerate(query.semantic_ops):
        ops = registry(op)
        assert ops[-1].is_gold, "gold operator must be last in the registry"
        scores, costs = [], []
        values, correct = [], []
        for phys in ops:
            t0 = time.perf_counter()
            if isinstance(op, SemFilter):
                s = np.asarray(phys.run_filter(sample, op), np.float32)
                v = None
            else:
                v, conf = phys.run_map(sample, op)
                v = np.asarray(v)
                s = np.asarray(conf, np.float32)
            dt = (time.perf_counter() - t0) / max(len(sample), 1)
            scores.append(s)
            costs.append(max(dt, 1e-9))
            if v is not None:
                values.append(v)
        is_map = isinstance(op, SemMap)
        prof = ProfiledPipeline(
            logical_idx=li, is_map=is_map,
            op_names=[p.name for p in ops],
            scores=np.stack(scores),
            costs=np.asarray(costs, np.float32),
        )
        if is_map:
            vals = np.stack(values)
            prof.values = vals
            prof.correct = (vals == vals[-1][None, :]).astype(np.float32)
        profiles.append(prof)
    return profiles, sample_idx
