"""Compatibility shim over the streaming runtime (repro.runtime.executor).

The cascade execution loop that lived here moved into the runtime
subsystem, which adds partitioned streaming, cross-stage batch coalescing,
pluggable backends and uniform StageStats telemetry. `execute_plan` keeps
the original signature (plan, query, items, registry) and result shape so
existing callers and tests continue to work; new code should call
`repro.runtime.run_plan` directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.logical import Query, SemMap
from repro.core.physical import PhysicalPlan


@dataclass
class ExecutionResult:
    accepted: np.ndarray                   # (N,) bool — in the result set
    map_values: Dict[int, np.ndarray]      # logical idx -> values (N,)
    runtime_s: float                       # sum of measured operator time
    stage_times: List[Tuple[str, float, int]]   # (op, seconds, n_tuples)
    n_llm_tuples: int                      # tuples processed by LLM ops


def _decide(scores: np.ndarray, thr_hi: float, thr_lo: float, is_map: bool):
    """Pre-runtime numpy decision rule, kept as the reference the shared
    jit kernel (repro.runtime.kernel.decide) is unit-tested against."""
    z_acc = scores - thr_hi
    z_rej = thr_lo - scores
    if is_map:
        z_rej = np.full_like(z_rej, -np.inf)
    acc = (z_acc > 0) & (z_acc >= z_rej)
    rej = (z_rej > 0) & (z_rej > z_acc)
    return acc, rej


def execute_plan(plan: PhysicalPlan, query: Query, items: Sequence[Any],
                 registry: Callable,
                 partition_size: Optional[int] = None,
                 coalesce: Optional[int] = None) -> ExecutionResult:
    """Execute a plan through the streaming runtime; seed-shaped result."""
    # deferred import: the runtime depends on core's plan dataclasses, so
    # importing it at module load would cycle through repro.core.__init__
    from repro.runtime.backend import as_backend
    from repro.runtime.executor import run_plan
    rr = run_plan(plan, query, items, as_backend(registry),
                  partition_size=partition_size, coalesce=coalesce)
    return ExecutionResult(
        accepted=rr.accepted, map_values=rr.map_values,
        runtime_s=rr.runtime_s, stage_times=rr.stage_times,
        n_llm_tuples=rr.n_llm_tuples)


def evaluate_vs_gold(result, gold, sem_ops: Sequence[Any]) -> Dict[str, float]:
    """Global precision/recall of an executed plan vs the gold execution
    (paper's quality metric — result-set comparison incl. map values).

    Accepts any result objects exposing `.accepted` and `.map_values`
    (ExecutionResult or runtime RuntimeResult)."""
    ours, theirs = result.accepted, gold.accepted
    good = ours & theirs
    # map values must match gold for a tuple to count as a true positive
    for li, op in enumerate(sem_ops):
        if isinstance(op, SemMap):
            gv = gold.map_values.get(li)
            ov = result.map_values.get(li)
            if gv is None:
                continue
            if ov is None:
                good &= False
            else:
                good = good & (ov == gv)
    tp = float(np.sum(good))
    fp = float(np.sum(ours & ~good))
    fn = float(np.sum(theirs & ~good))
    precision = tp / max(tp + fp, 1e-9)
    recall = tp / max(tp + fn, 1e-9)
    return {"tp": tp, "fp": fp, "fn": fn,
            "precision": precision, "recall": recall}
