"""Cascade execution engine (paper Fig. 1 bottom).

Executes a PhysicalPlan over the full dataset: relational operators first,
then the DP-ordered physical stages. Each stage runs *batched* on exactly
the tuples that (a) survived every other logical filter so far and (b) are
still unsure for its own logical operator. accept/reject/unsure use the same
argmax rule as the planner; gold stages always decide.

Returns the result set, measured per-stage wall time, and tuple counts —
the runtime metric of Exp 1.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.logical import Query, SemFilter, SemMap
from repro.core.physical import PhysicalPlan


@dataclass
class ExecutionResult:
    accepted: np.ndarray                   # (N,) bool — in the result set
    map_values: Dict[int, np.ndarray]      # logical idx -> values (N,)
    runtime_s: float                       # sum of measured operator time
    stage_times: List[Tuple[str, float, int]]   # (op, seconds, n_tuples)
    n_llm_tuples: int                      # tuples processed by LLM ops


def _decide(scores: np.ndarray, thr_hi: float, thr_lo: float, is_map: bool):
    z_acc = scores - thr_hi
    z_rej = thr_lo - scores
    if is_map:
        z_rej = np.full_like(z_rej, -np.inf)
    acc = (z_acc > 0) & (z_acc >= z_rej)
    rej = (z_rej > 0) & (z_rej > z_acc)
    return acc, rej


def execute_plan(plan: PhysicalPlan, query: Query, items: Sequence[Any],
                 registry: Callable) -> ExecutionResult:
    sem_ops = query.semantic_ops
    N = len(items)

    # relational operators first (pull-up already ordered them first)
    alive = np.ones(N, bool)
    for rel in plan.relational:
        alive &= np.array([rel.apply(getattr(it, "row", {}) or {})
                           for it in items])

    # per-logical-op state
    n_logical = len(sem_ops)
    accepted = {li: np.zeros(N, bool) for li in range(n_logical)}
    rejected = {li: np.zeros(N, bool) for li in range(n_logical)}
    unsure = {li: alive.copy() for li in range(n_logical)}
    map_values: Dict[int, np.ndarray] = {}
    map_done: Dict[int, np.ndarray] = {
        li: np.zeros(N, bool) for li in range(n_logical)}

    ops_by_name = {}
    for li, op in enumerate(sem_ops):
        for phys in registry(op):
            ops_by_name[(li, phys.name)] = (phys, op)

    stage_times: List[Tuple[str, float, int]] = []
    total = 0.0
    n_llm = 0
    for st in plan.stages:
        li = st.logical_idx
        op_obj, sem = ops_by_name[(li, st.op_name)]
        # survivors of every OTHER logical filter, still unsure here
        mask = unsure[li].copy()
        for lj in range(n_logical):
            if lj != li and not isinstance(sem_ops[lj], SemMap):
                mask &= ~rejected[lj]
        idx = np.nonzero(mask)[0]
        if idx.size == 0:
            continue
        batch = [items[i] for i in idx]
        t0 = time.perf_counter()
        if isinstance(sem, SemFilter):
            scores = np.asarray(op_obj.run_filter(batch, sem), np.float32)
            vals = None
        else:
            vals, conf = op_obj.run_map(batch, sem)
            vals = np.asarray(vals)
            scores = np.asarray(conf, np.float32)
        dt = time.perf_counter() - t0
        total += dt
        stage_times.append((st.op_name, dt, int(idx.size)))
        if getattr(op_obj, "uses_llm", True):
            n_llm += int(idx.size)

        if st.is_gold:
            acc = (scores > 0) if not st.is_map else np.ones(len(idx), bool)
            rej = ~acc if not st.is_map else np.zeros(len(idx), bool)
        else:
            acc, rej = _decide(scores, st.thr_hi, st.thr_lo, st.is_map)
        if st.is_map:
            if li not in map_values:
                map_values[li] = np.zeros(N, object)
            commit = acc | (st.is_gold)
            commit_idx = idx[commit]
            map_values[li][commit_idx] = vals[commit]
            map_done[li][commit_idx] = True
            unsure[li][commit_idx] = False
        else:
            accepted[li][idx[acc]] = True
            rejected[li][idx[rej]] = True
            unsure[li][idx[acc]] = False
            unsure[li][idx[rej]] = False

    result = alive.copy()
    for li, op in enumerate(sem_ops):
        if isinstance(op, SemFilter):
            result &= accepted[li]
    return ExecutionResult(
        accepted=result, map_values=map_values, runtime_s=total,
        stage_times=stage_times, n_llm_tuples=n_llm)


def evaluate_vs_gold(result: ExecutionResult, gold: ExecutionResult,
                     sem_ops: Sequence[Any]) -> Dict[str, float]:
    """Global precision/recall of an executed plan vs the gold execution
    (paper's quality metric — result-set comparison incl. map values)."""
    ours, theirs = result.accepted, gold.accepted
    good = ours & theirs
    # map values must match gold for a tuple to count as a true positive
    for li, op in enumerate(sem_ops):
        if isinstance(op, SemMap):
            gv = gold.map_values.get(li)
            ov = result.map_values.get(li)
            if gv is None:
                continue
            if ov is None:
                good &= False
            else:
                good = good & (ov == gv)
    tp = float(np.sum(good))
    fp = float(np.sum(ours)) - float(np.sum(good & ours))
    fp = float(np.sum(ours & ~good))
    fn = float(np.sum(theirs & ~good))
    precision = tp / max(tp + fp, 1e-9)
    recall = tp / max(tp + fn, 1e-9)
    return {"tp": tp, "fp": fp, "fn": fn,
            "precision": precision, "recall": recall}
