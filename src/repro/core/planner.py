"""End-to-end planner: pull-up -> profile -> gradient optimize -> reorder.

This is the paper's Figure 2 pipeline, producing a PhysicalPlan the executor
can run over the full dataset.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import ordering as ORD
from repro.core import relaxation as R
from repro.core.logical import Query, SemFilter, SemMap, pull_up_semantic
from repro.core.optimizer import OptimizedPlan, PlannerConfig, optimize_query
from repro.core.physical import (PhysicalPlan, PhysicalPlanStage,
                                 ProfiledPipeline)
from repro.core.profiling import profile_query


def _gold_membership(profiles: Sequence[ProfiledPipeline]) -> np.ndarray:
    g = None
    for p in profiles:
        if p.is_map:
            continue
        acc = (p.scores[-1] > 0).astype(np.float32)
        g = acc if g is None else g * acc
    if g is None:   # map-only query: every tuple is in the gold result
        g = np.ones(profiles[0].scores.shape[1], np.float32)
    return g


def _pipelines_data(profiles) -> List[R.PipelineData]:
    out = []
    for p in profiles:
        out.append(R.PipelineData(
            scores=jnp.asarray(p.scores),
            costs=jnp.asarray(p.costs),
            is_map=p.is_map,
            correct=None if p.correct is None else jnp.asarray(p.correct)))
    return out


def _selectivities(profiles, plan: OptimizedPlan):
    """Hard-simulate the chosen cascades on the sample to estimate each
    selected op's inter/intra selectivity over the tuples reaching it."""
    sel = []
    for p, params, mask in zip(profiles, plan.params, plan.selected):
        import jax
        acc_i, rej_i, uns_i = R.hard_decisions(
            jnp.asarray(p.scores), params.thr_hi, params.thr_lo, p.is_map)
        acc_i, rej_i = np.asarray(acc_i), np.asarray(rej_i)
        n_ops, N = p.scores.shape
        unsure = np.ones(N, bool)
        per_op = {}
        for i in range(n_ops):
            if not mask[i]:
                continue
            if i == n_ops - 1:   # gold decides at its natural boundary
                acc = p.scores[-1] > 0 if not p.is_map else np.ones(N, bool)
                rej = ~acc
            else:
                acc, rej = acc_i[i], rej_i[i]
            reach = unsure
            n_reach = max(int(reach.sum()), 1)
            n_rej = int((reach & rej).sum())
            n_uns = int((reach & ~acc & ~rej).sum())
            per_op[i] = (1.0 - n_rej / n_reach,   # inter: not rejected
                         n_uns / n_reach)         # intra: still unsure
            unsure = reach & ~acc & ~rej
        sel.append(per_op)
    return sel


def plan_query(query: Query, items: Sequence[Any], registry: Callable,
               cfg: PlannerConfig = PlannerConfig(),
               sample_frac: float = 0.15, seed: int = 0,
               reorder: bool = True) -> PhysicalPlan:
    t0 = time.perf_counter()
    query = pull_up_semantic(query)                       # step 1
    profiles, sample_idx = profile_query(                 # step 2
        query, items, registry, sample_frac, seed)
    g = _gold_membership(profiles)
    pipelines = _pipelines_data(profiles)
    plan = optimize_query(pipelines, g,                   # step 3
                          query.target_recall, query.target_precision, cfg)
    sel = _selectivities(profiles, plan)

    # build stage list (cascades in cost order) for the DP reorderer
    phys_ops: List[ORD.PhysOp] = []
    stage_meta = []
    for li, (p, params, mask) in enumerate(
            zip(profiles, plan.params, plan.selected)):
        stage_no = 0
        for i in range(p.scores.shape[0]):
            if not mask[i]:
                continue
            inter, intra = sel[li][i]
            phys_ops.append(ORD.PhysOp(
                op_id=len(phys_ops), logical_id=li, stage=stage_no,
                cost=float(p.costs[i]), sel_inter=inter, sel_intra=intra))
            is_gold = i == p.scores.shape[0] - 1
            stage_meta.append(PhysicalPlanStage(
                logical_idx=li, stage=stage_no, op_name=p.op_names[i],
                thr_hi=float(params.thr_hi[i]), thr_lo=float(params.thr_lo[i]),
                is_map=p.is_map, is_gold=is_gold, cost=float(p.costs[i]),
                sel_inter=inter, sel_intra=intra))
            stage_no += 1

    if reorder and len(phys_ops) <= 14:                   # step 4
        order, _ = ORD.reorder(phys_ops, n_tuples=float(len(items)))
    elif reorder:
        order, _ = ORD.greedy_order(phys_ops, n_tuples=float(len(items)))
    else:
        order = list(range(len(phys_ops)))
    stages = [stage_meta[i] for i in order]

    return PhysicalPlan(
        stages=stages, relational=list(query.relational_ops),
        est_cost=plan.est_cost / max(len(sample_idx), 1) * len(items),
        recall_bound=plan.recall_bound,
        precision_bound=plan.precision_bound,
        feasible=plan.feasible,
        planning_time_s=time.perf_counter() - t0)
