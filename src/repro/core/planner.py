"""End-to-end planner: normalize -> profile -> gradient optimize -> reorder.

This is the paper's Figure 2 pipeline, producing a PhysicalPlan the
streaming runtime can execute over the full dataset. Profile/plan helpers
shared with the baselines live in repro.runtime.plan_utils.

`plan_query` plans one linear pipeline (filters / maps / top-k / agg over
one corpus). `plan_tree` plans a logical join tree: both side pipelines
and the pairing cascade are profiled on their own samples and optimized
*jointly* through the grouped relaxation (`relaxation.tree_counts`), so
the query-level recall/precision budget is allocated across every
pipeline of the tree by one gradient descent instead of per-pipeline
heuristics.
"""
from __future__ import annotations

import dataclasses
import time
from types import SimpleNamespace
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ordering as ORD
from repro.core import relaxation as R
from repro.core.logical import (JoinNode, PipelineLeaf, Query, SemAgg,
                                SemTopK, leading_relational, lower_tree,
                                normalize, pinned_relational,
                                pull_up_semantic)
from repro.core.optimizer import PlannerConfig, optimize_query
from repro.core.physical import (PhysicalPlan, PhysicalPlanStage, TreePlan,
                                 TREE_ROLES)
from repro.core.profiling import profile_query
from repro.runtime.dispatch import DEFAULT_COALESCE
from repro.runtime.plan_utils import (estimate_selectivities,
                                      gold_membership, pipelines_data)


def _effective_targets(query: Query, items: Sequence[Any]
                       ) -> Tuple[float, float]:
    """Group-wise guarantee tightening for SemAgg: a group's aggregate is
    right when its members' extractions are, so a per-*group* target T
    over groups of mean size n needs per-item quality >= T^(1/n)
    (p_item^n >= T). Queries without a grouped SemAgg keep their declared
    targets untouched."""
    mean_gs = 0.0
    for op in query.semantic_ops:
        if isinstance(op, SemAgg) and op.group_by is not None:
            groups = {}
            for it in items:
                key = getattr(it, "row", {}).get(op.group_by)
                groups[key] = groups.get(key, 0) + 1
            if groups:
                mean_gs = max(mean_gs, len(items) / len(groups))
    if mean_gs <= 1.0:
        return query.target_recall, query.target_precision
    rec = min(query.target_recall ** (1.0 / mean_gs), 0.999)
    prec = min(query.target_precision ** (1.0 / mean_gs), 0.999)
    return rec, prec


def _shift_topk_gold(profiles, sem_ops, n_items: int) -> None:
    """Re-anchor each SemTopK pipeline's gold scores at the sample rank
    cut, in place: with k' = k scaled to the sample and tau the midpoint
    between the k'-th and (k'+1)-th best gold scores (among tuples the
    *other* gold filters admit), shifted scores make `score > 0` mean
    "in the sample top-k" — so the unchanged gold-membership /
    gold-accept machinery composes the rank cut with the rest of the
    query."""
    if not profiles:
        return                 # bare pipeline (no semantic operators)
    n_sample = profiles[0].scores.shape[1]
    for li, op in enumerate(sem_ops):
        if not isinstance(op, SemTopK):
            continue
        base = np.ones(n_sample, bool)
        for lj, other in enumerate(sem_ops):
            if lj == li or profiles[lj].is_map \
                    or isinstance(other, SemTopK):
                continue
            base &= profiles[lj].scores[-1] > 0
        gold = profiles[li].scores[-1]
        n_base = int(base.sum())
        if n_base == 0:
            tau = float(gold.max()) + 1.0      # nothing survives: empty
        else:
            kk = max(1, int(round(op.k * n_sample / max(n_items, 1))))
            kk = min(kk, n_base)
            ranked = np.sort(gold[base])[::-1]
            if kk >= n_base:
                tau = float(ranked[-1]) - 1.0  # everything in base passes
            else:
                tau = float(ranked[kk - 1] + ranked[kk]) / 2.0
        scores = profiles[li].scores.copy()
        scores[-1] = scores[-1] - tau
        profiles[li].scores = scores


def _build_stages(profiles, plan, sel, hint: R.BatchHint, n_items: int,
                  measured, sem_ops=None):
    """The planner's stage-materialization tail, shared verbatim between
    `plan_query` and each `plan_tree` role: per selected physical op,
    derive the expected coalesced flush batch (measured width if the
    feedback store has seen the op, else the hint width; capped by the
    op's memory budget and by how many tuples reach it), price the stage
    at that batch on its fitted cost curve, and emit the DP reorderer's
    PhysOp next to the runtime's PhysicalPlanStage.

    SemTopK pipelines (via `sem_ops`) are reject-only: every non-gold
    stage's accept boundary is forced to +inf so the shared decision
    kernel can never admit early — admission is the global rank cut."""
    phys_ops: List[ORD.PhysOp] = []
    stage_meta: List[PhysicalPlanStage] = []
    for li, (p, params, mask) in enumerate(
            zip(profiles, plan.params, plan.selected)):
        topk = sem_ops is not None and isinstance(sem_ops[li], SemTopK)
        stage_no = 0
        for i in range(p.scores.shape[0]):
            if not mask[i]:
                continue
            inter, intra, reach = sel[li][i]
            cap = float(p.batch_caps[i]) if p.batch_caps is not None \
                else np.inf
            w_i = hint.width
            if measured is not None:
                meas = measured.mean_batch(p.op_names[i])
                if meas is not None:
                    w_i = max(meas, 1.0)
            exp_batch = max(1.0, min(w_i, cap, reach * n_items))
            curve = p.cost_curves[i] if p.cost_curves is not None else None
            cost = curve.per_tuple_at(exp_batch) if curve is not None \
                else float(p.costs[i])
            phys_ops.append(ORD.PhysOp(
                op_id=len(phys_ops), logical_id=li, stage=stage_no,
                cost=cost, sel_inter=inter, sel_intra=intra))
            is_gold = i == p.scores.shape[0] - 1
            thr_hi = float(params.thr_hi[i])
            if topk and not is_gold:
                thr_hi = float("inf")
            engine = p.op_engines[i] if p.op_engines is not None else ""
            stage_meta.append(PhysicalPlanStage(
                logical_idx=li, stage=stage_no, op_name=p.op_names[i],
                thr_hi=thr_hi, thr_lo=float(params.thr_lo[i]),
                is_map=p.is_map, is_gold=is_gold, cost=cost,
                sel_inter=inter, sel_intra=intra, exp_batch=exp_batch,
                engine=engine))
            stage_no += 1
    return phys_ops, stage_meta


def _order_stages(phys_ops, stage_meta, n_items: int, reorder: bool):
    if reorder and len(phys_ops) <= 14:                   # step 4
        order, _ = ORD.reorder(phys_ops, n_tuples=float(n_items))
    elif reorder:
        order, _ = ORD.greedy_order(phys_ops, n_tuples=float(n_items))
    else:
        order = list(range(len(phys_ops)))
    return [stage_meta[i] for i in order]


def _hint_width(profiles, coalesce: int, measured) -> float:
    """The static BatchHint width: the coalesce default unless the
    measured store has seen these ops execute."""
    width = float(max(coalesce, 1))
    if measured is not None and len(measured):
        all_ops = [name for p in profiles for name in p.op_names]
        blended = measured.blended_width(all_ops)
        if blended is not None:
            width = max(blended, 1.0)
    return width


def plan_query(query: Query, items: Sequence[Any], registry: Callable,
               cfg: Optional[PlannerConfig] = None,
               sample_frac: float = 0.15, seed: int = 0,
               reorder: bool = True,
               coalesce: int = DEFAULT_COALESCE,
               measured=None) -> PhysicalPlan:
    """Plan `query` over `items`. `measured` (an optional
    core.profiling.MeasuredBatchStore) activates the measured-batch
    feedback loop: operators with recorded execution telemetry are priced
    at their *measured* mean flush width instead of the static `coalesce`
    default, both inside the gradient optimizer's differentiable cost
    (per-op, via PipelineData.meas_width) and in the DP reorderer's
    per-stage `exp_batch`."""
    # default constructed per call — a shared default instance would leak
    # mutations between unrelated plans
    cfg = cfg if cfg is not None else PlannerConfig()
    t0 = time.perf_counter()
    query = normalize(query)                              # step 1 (checked)
    sem_ops = query.semantic_ops
    profiles, sample_idx = profile_query(                 # step 2
        query, items, registry, sample_frac, seed)
    _shift_topk_gold(profiles, sem_ops, len(items))
    g = gold_membership(profiles)
    pipelines = pipelines_data(profiles, measured, sem_ops=sem_ops)
    # batch-size-aware costing: amortize fixed per-call cost over the
    # coalesced flush batches the streaming executor will actually run.
    # The hint width is the static coalesce default unless the measured
    # store has seen these ops execute, in which case their tuple-weighted
    # measured flush width seeds the hint (per-op measured widths override
    # it again inside the relaxation where individual ops were recorded).
    hint = R.BatchHint(width=_hint_width(profiles, coalesce, measured),
                       scale=len(items) / max(len(sample_idx), 1))
    t_rec, t_prec = _effective_targets(query, items)
    plan = optimize_query(pipelines, g,                   # step 3
                          t_rec, t_prec, cfg,
                          batch_hint=hint)
    sel = estimate_selectivities(profiles, plan, sem_ops=sem_ops)

    # build stage list (cascades in cost order) for the DP reorderer
    phys_ops, stage_meta = _build_stages(
        profiles, plan, sel, hint, len(items), measured, sem_ops)
    stages = _order_stages(phys_ops, stage_meta, len(items), reorder)

    return PhysicalPlan(
        stages=stages, relational=leading_relational(query),
        est_cost=plan.est_cost / max(len(sample_idx), 1) * len(items),
        recall_bound=plan.recall_bound,
        precision_bound=plan.precision_bound,
        feasible=plan.feasible,
        planning_time_s=time.perf_counter() - t0,
        post_relational=pinned_relational(query))


# ---------------------------------------------------------------------------
# tree planning (joins)
# ---------------------------------------------------------------------------

def _block_pairs(sample_l, sample_r, on: Optional[str], seed: int,
                 max_pairs: int = 256):
    """Sample pair coordinates (i into sample_l, j into sample_r) after
    equi-join blocking on `on`; uniformly subsampled to `max_pairs` so
    pair profiling stays bounded."""
    ii, jj = [], []
    for i, l in enumerate(sample_l):
        lv = getattr(l, "row", {}).get(on) if on else None
        if on is not None and lv is None:
            continue          # rows missing the block column never pair
        for j, r in enumerate(sample_r):
            if on is not None \
                    and getattr(r, "row", {}).get(on) != lv:
                continue
            ii.append(i)
            jj.append(j)
    ii = np.asarray(ii, np.int64)
    jj = np.asarray(jj, np.int64)
    if len(ii) > max_pairs:
        keep = np.sort(np.random.default_rng(seed).choice(
            len(ii), size=max_pairs, replace=False))
        ii, jj = ii[keep], jj[keep]
    return ii, jj


def _broadcast_profile(p, idx: np.ndarray):
    """A side profile re-indexed onto pair coordinates (score[op, t] =
    score[op, side_index(t)]) — the relaxation then optimizes all roles
    over one shared coordinate set."""
    return dataclasses.replace(
        p,
        scores=p.scores[:, idx],
        values=None if p.values is None else p.values[:, idx],
        correct=None if p.correct is None else p.correct[:, idx])


def plan_tree(tree, left_items: Sequence[Any], right_items: Sequence[Any],
              registry: Callable, cfg: Optional[PlannerConfig] = None, *,
              target_recall: float = 0.9, target_precision: float = 0.9,
              sample_frac: float = 0.15, seed: int = 0,
              reorder: bool = True, coalesce: int = DEFAULT_COALESCE,
              measured=None) -> TreePlan:
    """Plan a logical join tree over two corpora.

    Both sides and the pairing cascade are profiled on their own samples;
    side scores are broadcast onto the blocked sample-pair coordinates
    and ONE grouped gradient optimization (`optimize_query(groups=...)`)
    places thresholds for every pipeline at once against the pair-level
    gold membership — the error budget allocation across the tree the
    paper formulates, generalized past the linear chain. Each role then
    materializes its own PhysicalPlan (reordered independently) for the
    runtime to execute in sequence: left side, right side, pair cascade
    over blocked survivor pairs.
    """
    cfg = cfg if cfg is not None else PlannerConfig()
    t0 = time.perf_counter()
    tree = lower_tree(tree)
    if not isinstance(tree, JoinNode):
        raise ValueError("plan_tree expects a join tree; linear pipelines "
                         "go through plan_query")
    if not isinstance(tree.left, PipelineLeaf) \
            or not isinstance(tree.right, PipelineLeaf):
        raise ValueError("nested joins are not supported yet — each join "
                         "side must be a linear pipeline")
    join = tree.op
    queries = {
        "left": normalize(Query(list(tree.left.nodes),
                                target_recall, target_precision)),
        "right": normalize(Query(list(tree.right.nodes),
                                 target_recall, target_precision)),
        "pair": Query([join, *tree.pair_nodes],
                      target_recall, target_precision),
    }
    corpora = {"left": left_items, "right": right_items}

    # profile each side on its own sample
    profiles_l, sidx_l = profile_query(queries["left"], left_items,
                                       registry, sample_frac, seed)
    profiles_r, sidx_r = profile_query(queries["right"], right_items,
                                       registry, sample_frac, seed + 1)
    sample_l = [left_items[i] for i in sidx_l]
    sample_r = [right_items[i] for i in sidx_r]
    _shift_topk_gold(profiles_l, queries["left"].semantic_ops,
                     len(left_items))
    _shift_topk_gold(profiles_r, queries["right"].semantic_ops,
                     len(right_items))

    # blocked sample-pair corpus + pair-cascade profiling over it
    ii, jj = _block_pairs(sample_l, sample_r, join.on, seed)
    if len(ii) == 0:
        raise ValueError(
            f"join blocking on {join.on!r} eliminated every sample pair — "
            f"the corpora share no block values; drop `on` or check the "
            f"column")
    from repro.runtime.tree import make_pairs
    pair_sample = make_pairs([sample_l[i] for i in ii],
                             [sample_r[j] for j in jj])
    profiles_p, _ = profile_query(queries["pair"], pair_sample, registry,
                                  sample_frac=1.0, seed=seed)

    n_l, n_r = len(left_items), len(right_items)
    n_ls, n_rs, n_p = len(sidx_l), len(sidx_r), len(ii)
    block_frac = n_p / max(n_ls * n_rs, 1)

    # pair-level gold membership: both sides' gold plans admit AND the
    # gold pair scorer matches — the per-tuple product form, unchanged.
    # A bare side (no semantic operators) admits everything.
    g = ((gold_membership(profiles_l)[ii] if profiles_l
          else np.ones(len(ii), np.float32))
         * (gold_membership(profiles_r)[jj] if profiles_r
            else np.ones(len(jj), np.float32))
         * gold_membership(profiles_p))

    sem_ops_all = (queries["left"].semantic_ops
                   + queries["right"].semantic_ops
                   + queries["pair"].semantic_ops)
    pipelines_all = pipelines_data(
        [_broadcast_profile(p, ii) for p in profiles_l]
        + [_broadcast_profile(p, jj) for p in profiles_r]
        + list(profiles_p),
        measured, sem_ops=sem_ops_all)

    # per-group reach->corpus weights (see relaxation.TreeGroup): a side
    # op's pair-coordinate reach sum overcounts by its pairing degree,
    # so sides weigh n_side / n_pairs; the pair cascade scales straight
    # from sample pairs to the blocked corpus pair count
    width = _hint_width(profiles_l + profiles_r + profiles_p, coalesce,
                        measured)
    cw = {"left": n_l / max(n_p, 1), "right": n_r / max(n_p, 1),
          "pair": (n_l * n_r) / max(n_ls * n_rs, 1)}
    groups = [
        R.TreeGroup(len(profiles_l), "side", cw["left"],
                    R.BatchHint(width, cw["left"])),
        R.TreeGroup(len(profiles_r), "side", cw["right"],
                    R.BatchHint(width, cw["right"])),
        R.TreeGroup(len(profiles_p), "pair", cw["pair"],
                    R.BatchHint(width, cw["pair"])),
    ]
    plan = optimize_query(pipelines_all, g, target_recall,
                          target_precision, cfg, groups=groups)

    # slice the joint solution back into roles and materialize each
    role_profiles = {"left": profiles_l, "right": profiles_r,
                     "pair": profiles_p}
    counts = [len(profiles_l), len(profiles_r), len(profiles_p)]
    offsets = np.cumsum([0] + counts)
    role_plans, split = {}, {}
    # side survivor fractions drive the expected pair-corpus size
    surv = {}
    for role, lo, hi in zip(TREE_ROLES, offsets[:-1], offsets[1:]):
        profs = role_profiles[role]
        if not profs:
            # bare side (no semantic operators): nothing to optimize —
            # every item survives its (at most relational) pipeline
            split[role] = (1.0, 1.0)
            surv[role] = 1.0
            role_plans[role] = PhysicalPlan(
                stages=[], relational=leading_relational(queries[role]),
                est_cost=0.0, recall_bound=1.0, precision_bound=1.0,
                feasible=plan.feasible,
                post_relational=pinned_relational(queries[role]))
            continue
        rp = SimpleNamespace(params=plan.params[lo:hi],
                             selected=plan.selected[lo:hi])
        role_ops = queries[role].semantic_ops
        # role-local hard evaluation on the role's own sample: the
        # budget split EXPLAIN renders, and the role's own cost estimate
        role_data = pipelines_data(profs, measured, sem_ops=role_ops)
        role_gold = gold_membership(profs)
        c = R.query_counts(role_data, rp.params,
                           np.asarray(role_gold, np.float32), 0.0,
                           hard=True,
                           batch_hint=R.BatchHint(width, 1.0))
        tp, fp, fn = float(c.tp), float(c.fp), float(c.fn)
        split[role] = (tp / max(tp + fn, 1e-9), tp / max(tp + fp, 1e-9))
        n_sample = profs[0].scores.shape[1]
        surv[role] = (tp + fp) / max(n_sample, 1)

        sel = estimate_selectivities(profs, rp, sem_ops=role_ops)
        if role == "pair":
            n_role = max(1, int(round(block_frac
                                      * surv["left"] * n_l
                                      * surv["right"] * n_r)))
        else:
            n_role = len(corpora[role])
        phys_ops, stage_meta = _build_stages(
            profs, rp, sel, R.BatchHint(width, 1.0), n_role, measured,
            role_ops)
        stages = _order_stages(phys_ops, stage_meta, n_role, reorder)
        role_plans[role] = PhysicalPlan(
            stages=stages, relational=leading_relational(queries[role]),
            est_cost=float(c.cost) / max(n_sample, 1) * n_role,
            recall_bound=split[role][0], precision_bound=split[role][1],
            feasible=plan.feasible,
            post_relational=pinned_relational(queries[role]))

    est_pairs = max(1, int(round(block_frac * surv["left"] * n_l
                                 * surv["right"] * n_r)))
    return TreePlan(
        roles=role_plans, queries=queries, join=join,
        est_cost=plan.est_cost,
        recall_bound=plan.recall_bound,
        precision_bound=plan.precision_bound,
        feasible=plan.feasible, split=split, est_pairs=est_pairs,
        planning_time_s=time.perf_counter() - t0)
