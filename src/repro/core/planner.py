"""End-to-end planner: pull-up -> profile -> gradient optimize -> reorder.

This is the paper's Figure 2 pipeline, producing a PhysicalPlan the
streaming runtime can execute over the full dataset. Profile/plan helpers
shared with the baselines live in repro.runtime.plan_utils.
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from repro.core import ordering as ORD
from repro.core import relaxation as R
from repro.core.logical import Query, pull_up_semantic
from repro.core.optimizer import PlannerConfig, optimize_query
from repro.core.physical import PhysicalPlan, PhysicalPlanStage
from repro.core.profiling import profile_query
from repro.runtime.dispatch import DEFAULT_COALESCE
from repro.runtime.plan_utils import (estimate_selectivities,
                                      gold_membership, pipelines_data)


def plan_query(query: Query, items: Sequence[Any], registry: Callable,
               cfg: Optional[PlannerConfig] = None,
               sample_frac: float = 0.15, seed: int = 0,
               reorder: bool = True,
               coalesce: int = DEFAULT_COALESCE,
               measured=None) -> PhysicalPlan:
    """Plan `query` over `items`. `measured` (an optional
    core.profiling.MeasuredBatchStore) activates the measured-batch
    feedback loop: operators with recorded execution telemetry are priced
    at their *measured* mean flush width instead of the static `coalesce`
    default, both inside the gradient optimizer's differentiable cost
    (per-op, via PipelineData.meas_width) and in the DP reorderer's
    per-stage `exp_batch`."""
    # default constructed per call — a shared default instance would leak
    # mutations between unrelated plans
    cfg = cfg if cfg is not None else PlannerConfig()
    t0 = time.perf_counter()
    query = pull_up_semantic(query)                       # step 1
    profiles, sample_idx = profile_query(                 # step 2
        query, items, registry, sample_frac, seed)
    g = gold_membership(profiles)
    pipelines = pipelines_data(profiles, measured)
    # batch-size-aware costing: amortize fixed per-call cost over the
    # coalesced flush batches the streaming executor will actually run.
    # The hint width is the static coalesce default unless the measured
    # store has seen these ops execute, in which case their tuple-weighted
    # measured flush width seeds the hint (per-op measured widths override
    # it again inside the relaxation where individual ops were recorded).
    width = float(max(coalesce, 1))
    if measured is not None and len(measured):
        all_ops = [name for p in profiles for name in p.op_names]
        blended = measured.blended_width(all_ops)
        if blended is not None:
            width = max(blended, 1.0)
    hint = R.BatchHint(width=width,
                       scale=len(items) / max(len(sample_idx), 1))
    plan = optimize_query(pipelines, g,                   # step 3
                          query.target_recall, query.target_precision, cfg,
                          batch_hint=hint)
    sel = estimate_selectivities(profiles, plan)

    # build stage list (cascades in cost order) for the DP reorderer
    phys_ops: List[ORD.PhysOp] = []
    stage_meta = []
    for li, (p, params, mask) in enumerate(
            zip(profiles, plan.params, plan.selected)):
        stage_no = 0
        for i in range(p.scores.shape[0]):
            if not mask[i]:
                continue
            inter, intra, reach = sel[li][i]
            cap = float(p.batch_caps[i]) if p.batch_caps is not None \
                else np.inf
            w_i = hint.width
            if measured is not None:
                meas = measured.mean_batch(p.op_names[i])
                if meas is not None:
                    w_i = max(meas, 1.0)
            exp_batch = max(1.0, min(w_i, cap, reach * len(items)))
            curve = p.cost_curves[i] if p.cost_curves is not None else None
            cost = curve.per_tuple_at(exp_batch) if curve is not None \
                else float(p.costs[i])
            phys_ops.append(ORD.PhysOp(
                op_id=len(phys_ops), logical_id=li, stage=stage_no,
                cost=cost, sel_inter=inter, sel_intra=intra))
            is_gold = i == p.scores.shape[0] - 1
            engine = p.op_engines[i] if p.op_engines is not None else ""
            stage_meta.append(PhysicalPlanStage(
                logical_idx=li, stage=stage_no, op_name=p.op_names[i],
                thr_hi=float(params.thr_hi[i]), thr_lo=float(params.thr_lo[i]),
                is_map=p.is_map, is_gold=is_gold, cost=cost,
                sel_inter=inter, sel_intra=intra, exp_batch=exp_batch,
                engine=engine))
            stage_no += 1

    if reorder and len(phys_ops) <= 14:                   # step 4
        order, _ = ORD.reorder(phys_ops, n_tuples=float(len(items)))
    elif reorder:
        order, _ = ORD.greedy_order(phys_ops, n_tuples=float(len(items)))
    else:
        order = list(range(len(phys_ops)))
    stages = [stage_meta[i] for i in order]

    return PhysicalPlan(
        stages=stages, relational=list(query.relational_ops),
        est_cost=plan.est_cost / max(len(sample_idx), 1) * len(items),
        recall_bound=plan.recall_bound,
        precision_bound=plan.precision_bound,
        feasible=plan.feasible,
        planning_time_s=time.perf_counter() - t0)
