"""Model / shape configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. The model zoo
(`repro.models.transformer`) consumes these configs; nothing else in the
system hard-codes architecture details.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    n_shared_experts: int = 0     # always-on experts (DeepSeek-style)
    top_k: int = 0
    d_ff_expert: int = 0          # per-expert FFN width
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 0
    q_lora_rank: int = 0          # 0 = direct q projection
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2               # mamba inner expansion


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attn-free archs)
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # token-mixer kind: gqa | mla | hymba | rwkv6
    attn_kind: str = "gqa"

    # sliding-window / local:global structure.
    # window == 0  -> full causal attention everywhere.
    # window  > 0  -> local layers attend within `window`; layers whose index
    #                 is in `global_every`-step positions are global.
    window: int = 0
    global_every: int = 0         # e.g. 6 -> every 6th layer is global (gemma3 5:1)
    global_layers: Tuple[int, ...] = ()  # explicit global layer ids (hymba)

    mla: MLAConfig = field(default_factory=MLAConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    frontend: str = "none"        # none | vision | audio (stub embeddings)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # rwkv6 head size (d_model must divide)
    rwkv_head_size: int = 64

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests / executed experiments."""
        small = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.attn_kind == "mla":
            small["mla"] = MLAConfig(
                kv_lora_rank=16, q_lora_rank=0, qk_nope_dim=16,
                qk_rope_dim=8, v_head_dim=16)
        if self.moe.n_experts:
            small["moe"] = MoEConfig(
                n_experts=4, n_shared_experts=min(self.moe.n_shared_experts, 1),
                top_k=2, d_ff_expert=32, capacity_factor=2.0)
        if self.attn_kind == "hymba":
            small["ssm"] = SSMConfig(d_state=4, d_conv=4, expand=2)
            small["global_layers"] = (0,)
        if self.window:
            small["window"] = 8
        if self.global_every:
            small["global_every"] = 2
        if self.attn_kind == "rwkv6":
            small["rwkv_head_size"] = 16
            small["n_heads"] = 0
            small["n_kv_heads"] = 0
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-reduced", **small)

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so vocab-sharded embedding /
        head tables divide any reasonable TP degree."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_mla(self) -> bool:
        return self.attn_kind == "mla"

    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0

    @property
    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                 # lm head
        per_layer = 2 * d                            # two RMSNorm scales
        if self.attn_kind == "gqa" or self.attn_kind == "hymba":
            q = d * self.n_heads * self.d_head
            kv = 2 * d * self.n_kv_heads * self.d_head
            o = self.n_heads * self.d_head * d
            per_layer += q + kv + o
            if self.attn_kind == "hymba":
                di = self.ssm.expand * d
                per_layer += d * 2 * di + di * self.ssm.d_conv \
                    + di * (2 * self.ssm.d_state + 2) + di * d
        elif self.attn_kind == "mla":
            m = self.mla
            qdim = self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            per_layer += (d * m.q_lora_rank + m.q_lora_rank * qdim) if m.q_lora_rank else d * qdim
            per_layer += d * (m.kv_lora_rank + m.qk_rope_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        elif self.attn_kind == "rwkv6":
            per_layer += 6 * d * d + 2 * d * self.d_ff_channel_mix
        if self.is_moe:
            e = self.moe
            per_layer += d * e.n_experts                                  # router
            per_layer += 3 * d * e.d_ff_expert * (e.n_experts + e.n_shared_experts)
        elif self.attn_kind != "rwkv6":
            per_layer += 3 * d * self.d_ff                                # swiglu
        return n + L * per_layer

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k + shared experts)."""
        if not self.is_moe:
            return self.n_params
        e = self.moe
        inactive = (e.n_experts - e.top_k) * 3 * self.d_model * e.d_ff_expert
        return self.n_params - self.n_layers * inactive

    @property
    def d_ff_channel_mix(self) -> int:
        return self.d_ff

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_size


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k is only runnable for sub-quadratic archs (SSM/hybrid/local)."""
    if cfg.attn_kind in ("rwkv6", "hymba"):
        return True
    if cfg.global_every or cfg.window:   # local:global (gemma3)
        return True
    return False


def applicable_shapes(cfg: ModelConfig):
    out = []
    for s in ALL_SHAPES:
        if s.name == "long_500k" and not supports_long_context(cfg):
            continue
        out.append(s)
    return tuple(out)
