"""rwkv6-1.6b (Finch) — attn-free data-dependent-decay linear recurrence."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_head=0,
    d_ff=7168,              # channel-mix width
    vocab_size=65536,
    attn_kind="rwkv6",
    rwkv_head_size=64,      # 32 wkv heads
)
