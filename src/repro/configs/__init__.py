"""Architecture config registry: ``get_config("<arch-id>")``."""
from repro.configs.base import (ALL_SHAPES, SHAPES, ModelConfig, ShapeConfig,
                                applicable_shapes, supports_long_context)

from repro.configs import (dbrx_132b, deepseek_v2_lite_16b, gemma3_27b,
                           granite_8b, hymba_1p5b, llava_next_34b,
                           minicpm3_4b, minitron_8b, musicgen_medium,
                           rwkv6_1p6b, stretto_llama_8b)

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_8b, minicpm3_4b, gemma3_27b, minitron_8b, llava_next_34b,
        hymba_1p5b, musicgen_medium, deepseek_v2_lite_16b, dbrx_132b,
        rwkv6_1p6b, stretto_llama_8b,
    )
}

ASSIGNED = tuple(n for n in REGISTRY if n != "stretto-llama-8b")


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = [
    "ModelConfig", "ShapeConfig", "REGISTRY", "ASSIGNED", "get_config",
    "SHAPES", "ALL_SHAPES", "applicable_shapes", "supports_long_context",
]
