"""dbrx-132b — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=10752,
    vocab_size=100352,
    attn_kind="gqa",
    moe=MoEConfig(
        n_experts=16,
        n_shared_experts=0,
        top_k=4,
        d_ff_expert=10752,
    ),
)
