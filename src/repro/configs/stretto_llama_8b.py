"""Paper's own operator model — Llama-3.1-8B [arXiv:2407.21783].

Stretto's KV-cache-enabled operators in the paper are built on Llama-3.1
8B/70B; this is the 8B config used as the paper-faithful reference arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stretto-llama-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=128256,
    attn_kind="gqa",
    rope_theta=500_000.0,
)
