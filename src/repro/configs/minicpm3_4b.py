"""minicpm3-4b — dense MLA model [hf:openbmb/MiniCPM3-4B]."""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,          # MLA: per-head K/V reconstructed from shared latent
    d_head=64,
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    mla=MLAConfig(
        kv_lora_rank=256,
        q_lora_rank=768,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
    ),
)
