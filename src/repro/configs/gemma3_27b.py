"""gemma3-27b — dense 5:1 local:global GQA, 128k ctx [hf:google/gemma-3]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab_size=262144,
    attn_kind="gqa",
    window=1024,            # local layers: 1k sliding window
    global_every=6,         # every 6th layer is global  -> 5:1 local:global
    rope_theta=1_000_000.0,
)
