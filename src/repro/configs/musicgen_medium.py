"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec frontend is a stub: input_specs() provides precomputed frame
embeddings; the backbone is a plain MHA decoder (kv == q heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_head=64,
    d_ff=6144,
    vocab_size=2048,
    attn_kind="gqa",
    frontend="audio",
)
