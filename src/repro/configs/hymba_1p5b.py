"""hymba-1.5b — hybrid parallel attention+mamba heads [arXiv:2411.13676]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    attn_kind="hymba",
    window=1024,                    # sliding-window attention heads
    global_layers=(0, 15, 31),      # full-attention layers (hymba paper)
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)
