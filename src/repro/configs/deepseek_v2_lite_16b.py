"""deepseek-v2-lite-16b — MLA kv_lora=512 + MoE 64e top-6 (+2 shared)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,              # per-expert width (assigned)
    vocab_size=102400,
    attn_kind="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,      # v2-lite projects q directly
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_ff_expert=1408,
    ),
)
