"""llava-next-34b — VLM backbone (anyres tiling frontend is a stub)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab_size=64000,
    attn_kind="gqa",
    frontend="vision",      # input_specs() hands precomputed patch embeddings
)
