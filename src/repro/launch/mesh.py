"""Production mesh definitions.

A function, not a module-level constant, so importing this module never
touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> "jax.sharding.Mesh":
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> "jax.sharding.Mesh":
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
