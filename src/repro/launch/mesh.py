"""Production mesh definitions + the hardware peak numbers.

Mesh builders are functions, not module-level constants, so importing
this module never touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; everything else sees the real device count.

This module is also the single source of hardware peak numbers: the
dry-run roofline (launch/dryrun.py) and the kernels/dispatch perf gates
(benchmarks/roofline.py) both price against a `HardwarePeaks` set from
here — `resolve_peaks()` applies the ``STRETTO_ROOFLINE_*`` env
overrides and names the resulting set, so every roofline report can say
which peaks it measured against.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import jax


def make_production_mesh(*, multi_pod: bool = False) -> "jax.sharding.Mesh":
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> "jax.sharding.Mesh":
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_dispatch_mesh(n_shards: int) -> "jax.sharding.Mesh":
    """The runtime's data-parallel dispatch mesh (MeshDispatcher): up to
    `n_shards` devices on the "data" axis, model axis 1-wide. Degenerates
    to the local 1-device mesh on single-device hosts, and promotes to
    the full production mesh when the host actually has a pod's worth of
    chips — the same axis names either way, so the logical-axis sharding
    rules (distributed/sharding.py) resolve identically."""
    import numpy as np
    devs = jax.devices()
    if len(devs) <= 1 or n_shards <= 1:
        return make_local_mesh()
    if n_shards >= 256 and len(devs) >= 256:
        return make_production_mesh()
    n = min(int(n_shards), len(devs))
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(n, 1),
                             ("data", "model"))


@dataclass(frozen=True)
class HardwarePeaks:
    """One hardware peak set a roofline can price against."""
    name: str           # which peak set this is ("tpu-v5e", "ci-cpu", ...)
    flops: float        # FLOP/s (per chip)
    hbm_bw: float       # B/s (per chip)
    ici_bw: float = 0.0  # B/s per interconnect link (0: single chip)


# TPU v5e per-chip peaks — what the dry-run roofline prices against
TPU_V5E = HardwarePeaks("tpu-v5e", flops=197e12, hbm_bw=819e9, ici_bw=50e9)

# deliberately conservative CPU-class peaks — what the CI perf gates on
# CPU runners price against (a bound that is meaningful on the runner)
CI_CPU = HardwarePeaks("ci-cpu", flops=100e9, hbm_bw=20e9)


def resolve_peaks(default: HardwarePeaks = CI_CPU) -> HardwarePeaks:
    """The peak set a roofline run prices against: `default` unless the
    ``STRETTO_ROOFLINE_GFLOPS`` / ``STRETTO_ROOFLINE_BW_GBS`` env
    overrides are set (a TPU run gates against HBM bandwidth by
    exporting them); the returned name records that overrides applied."""
    gflops = os.environ.get("STRETTO_ROOFLINE_GFLOPS")
    bw_gbs = os.environ.get("STRETTO_ROOFLINE_BW_GBS")
    if gflops is None and bw_gbs is None:
        return default
    return HardwarePeaks(
        name=f"{default.name}+env",
        flops=float(gflops) * 1e9 if gflops else default.flops,
        hbm_bw=float(bw_gbs) * 1e9 if bw_gbs else default.hbm_bw,
        ici_bw=default.ici_bw)
