"""CLI: serve one engine to RemoteEngineMember clients.

    python -m repro.launch.remote_worker --host 127.0.0.1 --port 9410 \
        --name fast --models sm --sm-ratios 0.8,0.5 --lg-ratios ''

Prints ``LISTENING host:port`` once the socket is bound (port 0 picks a
free one — parse the line to learn it), then serves until interrupted.
Launch it with the same model zoo / ladder / seed as the local
EngineSpec it stands in for: the member's scores are then bit-identical
to serving that spec locally.
"""
from __future__ import annotations

import argparse
from typing import List, Optional, Sequence


def _ratio_list(text: str) -> List[float]:
    return [float(r) for r in text.split(",") if r.strip() != ""]


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Serve one Stretto engine over the wire protocol")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (printed as LISTENING)")
    ap.add_argument("--name", default="remote",
                    help="engine name reported to clients")
    ap.add_argument("--models", default="sm,lg",
                    help="comma-separated planted model names "
                         "(first = sm tier, last = lg tier)")
    ap.add_argument("--sm-ratios", type=_ratio_list, default=[0.8, 0.5, 0.0])
    ap.add_argument("--lg-ratios", type=_ratio_list, default=[0.8, 0.5, 0.3])
    ap.add_argument("--sm-int8", type=_ratio_list, default=[])
    ap.add_argument("--lg-int8", type=_ratio_list, default=[])
    ap.add_argument("--no-cheap", action="store_true",
                    help="drop the non-LLM cheap candidates")
    ap.add_argument("--prefill-batch", type=int, default=16)
    ap.add_argument("--memory-budget-bytes", type=float, default=2e9)
    ap.add_argument("--max-batch", type=int, default=128)
    ap.add_argument("--model-seed", type=int, default=1)
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--kernels", default=None,
                    choices=(None, "auto", "pallas", "interpret", "ref"))
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.remote.server import RemoteWorker, start_server
    worker = RemoteWorker(
        args.name,
        models=tuple(m for m in args.models.split(",") if m),
        sm_ratios=tuple(args.sm_ratios), lg_ratios=tuple(args.lg_ratios),
        include_cheap=not args.no_cheap,
        sm_int8=tuple(args.sm_int8), lg_int8=tuple(args.lg_int8),
        prefill_batch=args.prefill_batch,
        memory_budget_bytes=args.memory_budget_bytes,
        max_batch=args.max_batch, model_seed=args.model_seed,
        cache_dir=args.cache_dir, kernels=args.kernels,
        verbose=args.verbose)
    server, thread, address = start_server(worker, args.host, args.port)
    print(f"LISTENING {address}", flush=True)
    try:
        thread.join()
    except KeyboardInterrupt:
        server.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
