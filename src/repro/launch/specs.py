"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

No device allocation happens here — these are the inputs to
``jax.jit(...).lower()`` in the dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as sh
from repro.models import cache_axes, init_cache, param_axes
from repro.models.transformer import is_spec, model_template
from repro.training.optimizer import AdamWState

PyTree = Any


def params_sds(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct tree matching init_params exactly."""
    dtype = jnp.dtype(cfg.dtype)

    def mk(spec):
        dt = jnp.float32 if spec.init == "alog" else dtype
        return jax.ShapeDtypeStruct(spec.shape, dt)

    return jax.tree.map(mk, model_template(cfg), is_leaf=is_spec)


def opt_state_sds(cfg: ModelConfig) -> AdamWState:
    p = params_sds(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(f32, p),
        v=jax.tree.map(f32, p),
    )


def cache_sds(cfg: ModelConfig, batch: int, max_len: int,
              quant: bool = False) -> PyTree:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len,
                                             quant=quant))


def batch_sds(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        if cfg.frontend == "none":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                               jnp.dtype(cfg.dtype))}
    if cfg.frontend == "none":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    else:
        out = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                              jnp.dtype(cfg.dtype)),
               "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return out


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "decode":
        if cfg.frontend == "none":
            return {"tokens": ("batch", None)}
        return {"embeds": ("batch", None, None)}
    if cfg.frontend == "none":
        return {"tokens": ("batch", "seq")}
    return {"embeds": ("batch", "seq", None),
            "labels": ("batch", "seq")}


def rules_for(cfg: ModelConfig, shape: ShapeConfig, mesh,
              fsdp: Optional[str] = "data") -> Dict[str, Any]:
    """Sharding rules for one dry-run cell.

    - train/prefill: DP over (pod, data), TP/EP over model, FSDP over data.
    - decode: params replicated over data (serving replicas); cache batch
      over (pod, data). When n_kv_heads doesn't divide the model axis (GQA
      kv=8 vs TP=16, or MLA latent caches), the cache *sequence* dim is
      sharded over model instead (split-S / flash-decoding style).
    - long_500k (batch=1): sequence parallelism — cache_seq additionally
      over data.
    """
    model_size = mesh.shape["model"]
    dp_size = mesh.devices.size // model_size
    overrides: Dict[str, Any] = {}
    if shape.kind == "decode":
        # serving: weights replicated across data for latency — unless the
        # model is too big for TP alone (dbrx: 264 GB bf16 / 16 = 16.5 GB >
        # HBM), in which case ZeRO-inference FSDP-shards them over data and
        # re-gathers per layer (amortized over the decode batch).
        tp_bytes = 2.0 * cfg.n_params / model_size
        overrides["fsdp"] = "data" if tp_bytes > 8e9 else None
        seq_axes = []
        kv_shardable = (cfg.attn_kind in ("gqa", "hymba")
                        and cfg.n_kv_heads % model_size == 0)
        if not kv_shardable:
            overrides["kv_heads"] = None
            seq_axes.append("model")
        if shape.global_batch % dp_size != 0:
            # can't shard tiny batch: sequence parallelism on the cache
            overrides["batch"] = None
            overrides["cache_batch"] = None
            seq_axes.insert(0, "data")
        if seq_axes:
            overrides["cache_seq"] = (tuple(seq_axes) if len(seq_axes) > 1
                                      else seq_axes[0])
    else:
        overrides["fsdp"] = fsdp
    return sh.make_rules(**overrides)


def shardings_for(tree_axes: PyTree, mesh) -> PyTree:
    """Logical-axes pytree -> NamedSharding pytree (active rules required)."""
    def mk(axes):
        return NamedSharding(mesh, sh.resolve(axes))
    return jax.tree.map(
        mk, tree_axes,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(x, (str, type(None))) for x in v))
