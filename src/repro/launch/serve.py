"""Concurrent serving launcher: build cache profiles for a corpus, then
admit a stream of SemFrame queries through the QueryScheduler (the
paper's online phase, many tenants sharing one engine pool).

    python -m repro.launch.serve --items 200 --ratios 0.0,0.5,0.8 \\
        --requests 8 --concurrency 4

Each request is a declarative SemFrame query planned and executed by the
Session; requests overlap under the scheduler, so flushes from different
queries that target the same (engine, operator) coalesce into merged
engine calls. The summary line reports how many engine calls the
coalescing saved and the per-tenant fairness accounting. On a TPU fleet
this runs one engine per model replica group; the CPU path drives the
planted reduced models end to end.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from repro.api import Session, SessionConfig
from repro.core import PlannerConfig
from repro.data.synthetic import make_dataset
from repro.scheduler import TenantSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=200)
    ap.add_argument("--ratios", type=str, default="0.0,0.5,0.8")
    ap.add_argument("--cache-dir", type=str, default=None)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--concurrency", type=int, default=4,
                    help="scheduler driver slots (queries in flight)")
    ap.add_argument("--recall", type=float, default=0.7)
    ap.add_argument("--precision", type=float, default=0.7)
    args = ap.parse_args()
    ratios = tuple(float(r) for r in args.ratios.split(","))

    ds = make_dataset("serve", args.items, seed=0)
    session = Session(SessionConfig(
        cache_dir=args.cache_dir or tempfile.mkdtemp(),
        profile_ratios=ratios,
        sm_ratios=ratios, lg_ratios=ratios,
        planner=PlannerConfig(steps=150, restarts=2, snapshots=2),
        sample_frac=0.3,
        tenants=(TenantSpec("premium", tier="premium"),
                 TenantSpec("standard"),
                 TenantSpec("batch", tier="cold"))))
    t0 = time.time()
    session.prepare(ds.items)
    print(f"[serve] offline phase: {time.time() - t0:.1f}s "
          f"({args.items} items x {len(session.config.models)} models "
          f"x {len(ratios)} ratios)")

    rng = np.random.default_rng(0)
    tenants = ("premium", "standard", "batch")
    t0 = time.time()
    with session, session.scheduler(
            max_concurrent=args.concurrency) as sched:
        handles = []
        for i in range(args.requests):
            task = int(rng.integers(0, ds.n_filter_tasks))
            frame = (session.frame(ds.items)
                     .sem_filter(f"filter task {task}", task_id=task)
                     .with_guarantees(recall=args.recall,
                                      precision=args.precision))
            tenant = tenants[i % len(tenants)]
            handles.append((i, task, tenant, sched.submit(frame,
                                                          tenant=tenant)))
        for i, task, tenant, h in handles:
            res = h.result(timeout=600)
            s = res.sched
            print(f"[serve] req{i}: filter task={task} tenant={tenant} "
                  f"-> {int(res.accepted.sum())}/{len(ds.items)} accepted, "
                  f"wait={s.queue_wait_s * 1e3:.0f}ms "
                  f"run={s.run_wall_s:.2f}s "
                  f"shared_batches={s.shared_batches}")
        stats = sched.stats()
    wall = time.time() - t0
    print(f"[serve] online phase: {args.requests} queries in {wall:.1f}s "
          f"({args.requests / max(wall, 1e-9):.2f} q/s) — "
          f"{stats['n_flushes']} flushes -> {stats['n_calls']} engine "
          f"calls ({stats['saved_calls']} saved by coalescing)")
    for name, t in sorted(stats["tenants"].items()):
        if not t["n_queries"]:
            continue
        print(f"[serve]   tenant {name} ({t['tier']}, w={t['weight']}): "
              f"{t['n_queries']} queries, {t['n_tuples']} tuples, "
              f"vtime={t['vtime']:.0f}, warm_batches={t['warm_batches']}, "
              f"evictions={t['evictions']}")


if __name__ == "__main__":
    main()
