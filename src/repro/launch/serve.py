"""Serving launcher: build cache profiles for a corpus, then serve
semantic-operator requests (the paper's online phase).

    python -m repro.launch.serve --items 200 --ratios 0.0,0.5,0.8

On a TPU fleet this runs one engine per model replica group; the CPU path
drives the planted reduced models end to end.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from repro.cache.store import CacheStore
from repro.data.synthetic import (TOK_NO, TOK_YES, filter_query_token,
                                  make_dataset, make_planted_params,
                                  planted_config)
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=200)
    ap.add_argument("--ratios", type=str, default="0.0,0.5,0.8")
    ap.add_argument("--cache-dir", type=str, default=None)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()
    ratios = [float(r) for r in args.ratios.split(",")]

    ds = make_dataset("serve", args.items, seed=0)
    store = CacheStore(args.cache_dir or tempfile.mkdtemp())
    engine = ServingEngine(store)
    t0 = time.time()
    for size in ("sm", "lg"):
        cfg = planted_config(size)
        engine.register_model(size, cfg, make_planted_params(cfg, seed=1))
        engine.build_profiles(size, ds.items, ratios=ratios)
    print(f"[serve] offline phase: {time.time() - t0:.1f}s "
          f"({args.items} items x 2 models x {len(ratios)} ratios)")

    ids = [it.item_id for it in ds.items]
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        task = int(rng.integers(0, ds.n_filter_tasks))
        size = ("sm", "lg")[i % 2]
        ratio = ratios[i % len(ratios)]
        t0 = time.time()
        lo = engine.run_filter(size, ratio, ids,
                               [filter_query_token(task)], TOK_YES, TOK_NO)
        dt = time.time() - t0
        print(f"[serve] req{i}: filter task={task} profile={size}-r{ratio} "
              f"-> {int((lo > 0).sum())}/{len(ids)} accepted, "
              f"{len(ids) / dt:.0f} items/s")


if __name__ == "__main__":
    main()
