"""Training launcher.

On a real fleet, run one process per host with jax.distributed; on CPU this
drives the reduced configs end-to-end (examples/train_lm.py uses it).

    python -m repro.launch.train --arch granite-8b --reduced \
        --steps 200 --ckpt-dir /tmp/ckpt

XLA flags recorded for the TPU target (collective/compute overlap is
delegated to XLA's latency-hiding scheduler):
    --xla_tpu_enable_latency_hiding_scheduler=true
    --xla_tpu_megacore_fusion_allow_ags=true
    --xla_enable_async_collective_permute=true
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import lm_batches
from repro.models import init_params
from repro.training.loop import LoopConfig, run_training
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name}: {cfg.n_params / 1e6:.2f}M params, "
          f"{jax.device_count()} device(s)")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, lr=args.lr, remat=False))

    embeds_dim = cfg.d_model if cfg.frontend != "none" else None
    batches_iter = lm_batches(cfg.vocab_size, args.batch, args.seq,
                              embeds_dim=embeds_dim)

    def batch_stream():
        for b in batches_iter:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir)
    params, opt, report = run_training(step_fn, params, opt, batch_stream(),
                                       loop_cfg)
    print(f"[train] ran {report.steps_run} steps "
          f"(resumed_from={report.resumed_from}); "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}; "
          f"stragglers={report.straggler_events} retries={report.retries}")
    return report


if __name__ == "__main__":
    main()
