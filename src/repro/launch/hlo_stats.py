"""Loop-aware HLO accounting for the roofline analysis.

``compiled.cost_analysis()`` visits a ``while`` body ONCE — it does not
multiply by trip count (verified empirically; a 10-step scan of a 128^3
matmul reports 1-iteration FLOPs). Our models scan over the layer stack, so
all roofline terms must multiply loop bodies by their trip counts. This
module parses ``compiled.as_text()`` (the SPMD-partitioned, per-device
module) and computes, bottom-up over the call graph:

  flops      — 2*M*N*K for every dot (+ convolutions), x enclosing trips
  bytes      — per top-level (post-fusion) instruction: result bytes +
               operand bytes (models one HBM write + one read)
  coll_bytes — per collective: result bytes (all-reduce x2 for ring),
               x enclosing trips

Shapes in the partitioned module are per-device, so all three terms are
per-device quantities — exactly what the roofline denominator wants.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f4e2m1fn": 1, "f8e8m0fnu": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+?)\s+([\w\-]+)\((.*)$")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ring all-reduce moves ~2x the buffer; others ~1x
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0,
                "all-reduce-start": 2.0, "all-gather-start": 1.0,
                "collective-permute-start": 1.0}

_ZERO_TRAFFIC_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuple types."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    # scalar like "f32[]" -> regex gives dims ''
    return total


def shape_elems(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


@dataclass
class Stats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        # computation header: "%name (args) -> type {" or "ENTRY %name ..."
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, type_str, opcode, rest = mi.groups()
        # operands: %refs inside the first parenthesized group
        depth, i, args = 1, 0, ""
        while i < len(rest) and depth:
            ch = rest[i]
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
            i += 1
        tail = rest[i:]
        ins = Instr(name, type_str, opcode, tail,
                    operands=_OPERAND_RE.findall(args))
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = math.prod(shape_elems(ins.type_str)) or 1
    mc = _CONTRACT_RE.search(ins.rest)
    contracted = 1
    if mc and ins.operands:
        lhs = comp.by_name.get(ins.operands[0])
        if lhs is not None:
            lhs_dims = shape_elems(lhs.type_str)
            for d in (mc.group(1).split(",") if mc.group(1) else []):
                di = int(d)
                if di < len(lhs_dims):
                    contracted *= lhs_dims[di]
    return 2.0 * out_elems * contracted


def _conv_flops(ins: Instr, comp: Computation) -> float:
    # output elems x 2 x (kernel spatial x in_channels): approximate via
    # rhs (kernel) elems / out_channels
    out = math.prod(shape_elems(ins.type_str)) or 1
    if len(ins.operands) >= 2:
        rhs = comp.by_name.get(ins.operands[1])
        if rhs is not None:
            kdims = shape_elems(rhs.type_str)
            if kdims:
                return 2.0 * out * math.prod(kdims[:-1])
    return 2.0 * out


def analyze(text: str, tpu_model: bool = True) -> Stats:
    """Analyze a partitioned HLO module.

    tpu_model=True applies three corrections for XLA:CPU artifacts that do
    not exist on the TPU target (documented in EXPERIMENTS.md §Roofline):
      1. ``copy`` ops / copy-rooted fusions are zero-traffic — on TPU the
         donated cache and scan carries alias in place; XLA:CPU materializes
         f32 upcast copies of every bf16 argument.
      2. ``broadcast``-rooted fusions of scalars (loop output-buffer init)
         are zero-traffic (aliased with donation).
      3. ``dot`` traffic is counted at 2 bytes/element for f32 operands —
         XLA:CPU upcasts bf16 matmuls to f32; on TPU the MXU reads bf16.
    """
    comps = parse_module(text)
    # constants: re-parse raw text for s32[] constants per computation
    const_vals: Dict[str, List[int]] = {}
    cur_name = None
    for line in text.splitlines():
        s = line.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
            cur_name = m.group(1) if m else None
            continue
        if s == "}":
            cur_name = None
            continue
        if cur_name:
            m = re.search(r"=\s*s32\[\]\s*constant\((\d+)\)", s)
            if m:
                const_vals.setdefault(cur_name, []).append(int(m.group(1)))

    memo: Dict[str, Stats] = {}

    def comp_root_opcode(name: str) -> str:
        comp = comps.get(name)
        if comp is None or not comp.instrs:
            return ""
        return comp.instrs[-1].opcode

    def nonscalar_operand_bytes(ins: Instr, comp: Computation):
        vals = []
        for op in ins.operands:
            src = comp.by_name.get(op)
            if src is not None:
                b = shape_bytes(src.type_str)
                if b > 64:
                    vals.append(b)
        return vals

    def comp_stats(name: str) -> Stats:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        st = Stats()
        memo[name] = st
        if comp is None:
            return st
        for ins in comp.instrs:
            opc = ins.opcode
            if opc == "dot":
                st.flops += _dot_flops(ins, comp)
            elif opc == "convolution":
                st.flops += _conv_flops(ins, comp)
            elif opc == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mcnd = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                trips = 1
                if mcnd and mcnd.group(1) in const_vals:
                    trips = max(const_vals[mcnd.group(1)] + [1])
                if mb:
                    st.add(comp_stats(mb.group(1)), trips)
                continue
            elif opc in ("call", "async-start"):
                mc = _CALL_RE.search(ins.rest)
                if mc:
                    st.add(comp_stats(mc.group(1)))
            elif opc == "conditional":
                mb = _BRANCH_RE.search(ins.rest)
                if mb:
                    subs = [comp_stats(c.strip().lstrip("%"))
                            for c in mb.group(1).split(",")]
                    if subs:
                        # execute one branch; take the max as upper bound
                        worst = max(subs, key=lambda s: s.flops)
                        st.add(worst)
            elif opc == "fusion":
                mc = _CALL_RE.search(ins.rest)
                if mc:
                    inner = comp_stats(mc.group(1))
                    st.flops += inner.flops       # dots inside fusions
                    st.coll_bytes += inner.coll_bytes

            base = opc.replace("-start", "")
            if base in COLLECTIVES or opc in _COLL_FACTOR:
                b = shape_bytes(ins.type_str) * _COLL_FACTOR.get(
                    opc, _COLL_FACTOR.get(base, 1.0))
                if tpu_model and ins.type_str.startswith("f32"):
                    b //= 2   # XLA:CPU upcast; TPU moves bf16 activations
                st.coll_bytes += b
                st.coll_counts[base] = st.coll_counts.get(base, 0) + 1

            # ---- bytes (HBM traffic model) ----
            if opc in _ZERO_TRAFFIC_OPS or opc == "while":
                continue   # while carries are aliased in place
            # in-place slice ops: traffic = slice size, not buffer size
            root = opc
            if opc == "fusion":
                mc = _CALL_RE.search(ins.rest)
                if mc:
                    root = comp_root_opcode(mc.group(1))
            if root == "convert" or opc == "convert":
                # XLA:CPU upcasts bf16 weights/caches to f32 with standalone
                # convert fusions; on TPU converts fuse into consumers with
                # no extra HBM pass. Zero-traffic by the TPU model.
                continue
            if tpu_model and (root == "copy" or opc == "copy"
                              or root == "broadcast"):
                continue
            if opc == "dot" and tpu_model:
                # count dot traffic at bf16 width (MXU reads bf16 on TPU;
                # XLA:CPU upcast made these buffers f32). Operands whose
                # producer dequantizes an s8 buffer count at 1 B/elem — the
                # fused TPU kernel streams the int8 cache directly.
                def elems(ts):
                    return max(shape_bytes(ts) // max(_DTYPE_BYTES.get(
                        _SHAPE_RE.search(ts).group(1), 4), 1), 1) \
                        if _SHAPE_RE.search(ts) else 0

                b = shape_bytes(ins.type_str)
                if ins.type_str.startswith("f32"):
                    b //= 2
                for op in ins.operands:
                    src = comp.by_name.get(op)
                    if src is None:
                        continue
                    n_el = elems(src.type_str)
                    width = 2 if src.type_str.startswith(("f32", "bf16")) \
                        else _DTYPE_BYTES.get(
                            _SHAPE_RE.search(src.type_str).group(1), 2)
                    if src.opcode in ("fusion", "convert"):
                        for op2 in src.operands:
                            s2 = comp.by_name.get(op2)
                            if s2 is not None and s2.type_str.startswith(
                                    ("s8[", "u8[")) \
                                    and elems(s2.type_str) == n_el:
                                width = 1
                                break
                    b += n_el * width
                st.bytes += b
                continue
            if root in ("dynamic-slice", "gather"):
                st.bytes += 2 * shape_bytes(ins.type_str)   # read + write out
                continue
            if root in ("dynamic-update-slice", "scatter"):
                ops_b = nonscalar_operand_bytes(ins, comp)
                upd = min(ops_b) if ops_b else shape_bytes(ins.type_str)
                st.bytes += 2 * upd                          # read + write in
                continue
            if tpu_model and opc == "fusion":
                # dequantization fusions (s8 -> wide elementwise) fuse into
                # their consumer on TPU: zero extra HBM pass
                out_el = shape_bytes(ins.type_str) // 4 \
                    if ins.type_str.startswith("f32") else None
                is_deq = False
                for op in ins.operands:
                    src = comp.by_name.get(op)
                    if (src is not None and src.type_str.startswith(
                            ("s8[", "u8["))
                            and out_el is not None
                            and shape_bytes(src.type_str) == out_el):
                        is_deq = True
                        break
                if is_deq:
                    continue
            st.bytes += shape_bytes(ins.type_str)
            for op in ins.operands:
                src = comp.by_name.get(op)
                if src is not None and src.opcode not in (
                        "constant", "get-tuple-element", "tuple"):
                    st.bytes += shape_bytes(src.type_str)
        return st

    # evaluate from entry; fused computations are only reached via their
    # call sites (flops), never directly for bytes
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comp_stats(entry.name)
