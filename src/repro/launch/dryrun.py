import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count on first init). Do not move them.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --arch granite-8b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all --out results/dryrun

Each cell emits a JSON record: per-device memory analysis, loop-aware HLO
flops/bytes/collective-bytes (see hlo_stats), raw cost_analysis values, and
the three roofline terms.
"""
import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, SHAPES, applicable_shapes, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as sh
from repro.launch import specs as SP
from repro.launch.hlo_stats import analyze
from repro.launch.mesh import TPU_V5E, make_production_mesh
from repro.models import cache_axes, decode_step, param_axes, prefill
from repro.training.optimizer import opt_state_axes
from repro.training.train_step import make_train_step


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE); D = tokens processed."""
    n = cfg.n_active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    tokens = shape.global_batch            # one token per item
    return 2.0 * n * tokens


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               opts: Optional[Dict[str, str]] = None):
    """Returns (fn, args_sds, in_shardings, rules).

    opts — perf-iteration knobs (EXPERIMENTS.md §Perf):
      fsdp=none|data        weight sharding over the data axis
      remat_policy=none|dots  activation-checkpoint policy
      mb=<int>              gradient-accumulation microbatches
      flash_block=<int>     flash-attention block size (q and k)
      moe=dense|scatter|auto  MoE dispatch implementation
      kv_quant=1            int8 KV cache for decode shapes
    """
    opts = opts or {}
    fsdp = {"none": None, "data": "data"}.get(opts.get("fsdp", "data"),
                                              "data")
    rules = SP.rules_for(cfg, shape, mesh, fsdp=fsdp)
    if opts.get("moe_shard") == "2d":
        # 2D expert sharding: experts over data, per-expert FFN over model
        # (DeepSpeed-MoE-style EP=DP + TP inside the expert)
        rules["expert"] = "data"
        rules["ffe"] = "model"
    if "flash_block" in opts:
        from repro.models import layers as L
        L.FLASH_BLOCK = int(opts["flash_block"])
    if "moe" in opts:
        from repro.models import layers as L
        L.MOE_IMPL = opts["moe"]
    kv_quant = bool(int(opts.get("kv_quant", "0")))
    with sh.use_rules(rules, mesh):
        p_sds = SP.params_sds(cfg)
        p_shard = SP.shardings_for(param_axes(cfg), mesh)
        if shape.kind == "train":
            o_sds = SP.opt_state_sds(cfg)
            o_shard = SP.shardings_for(opt_state_axes(param_axes(cfg)), mesh)
            b_sds = SP.batch_sds(cfg, shape)
            b_shard = SP.shardings_for(SP.batch_axes(cfg, shape), mesh)
            # grad-accumulate in microbatches: 1M-token global steps do not
            # fit activations otherwise (16 leaves 1 batch row per device)
            mb = int(opts.get("mb", 16))
            mb = mb if shape.global_batch % mb == 0 else 1
            fn = make_train_step(cfg, microbatches=mb,
                                 remat_policy=opts.get("remat_policy",
                                                       "none"))
            return (fn, (p_sds, o_sds, b_sds),
                    (p_shard, o_shard, b_shard), rules)
        if shape.kind == "prefill":
            b_sds = SP.batch_sds(cfg, shape)
            b_shard = SP.shardings_for(SP.batch_axes(cfg, shape), mesh)

            def fn(params, batch):
                return prefill(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"))
            return fn, (p_sds, b_sds), (p_shard, b_shard), rules
        # decode
        c_sds = SP.cache_sds(cfg, shape.global_batch, shape.seq_len,
                             quant=kv_quant)
        c_shard = SP.shardings_for(cache_axes(cfg, quant=kv_quant), mesh)
        b_sds = SP.batch_sds(cfg, shape)
        b_shard = SP.shardings_for(SP.batch_axes(cfg, shape), mesh)

        def fn(params, cache, batch):
            return decode_step(params, cfg, cache,
                               tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"), uniform_pos=True)
        return fn, (p_sds, c_sds, b_sds), (p_shard, c_shard, b_shard), rules


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             verbose: bool = True,
             opts: Optional[Dict[str, str]] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if opts and "tp" in opts:
        # perf-iteration knob: re-balance the 256 chips between DP and TP
        import jax as _jax
        tp = int(opts["tp"])
        total = 512 if multi_pod else 256
        per_pod = total // (2 if multi_pod else 1)
        if multi_pod:
            mesh = _jax.make_mesh((2, per_pod // tp, tp),
                                  ("pod", "data", "model"))
        else:
            mesh = _jax.make_mesh((per_pod // tp, tp), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    fn, args_sds, in_shardings, rules = build_cell(cfg, shape, mesh, opts)

    # buffer donation: decode steps donate the cache (in-place KV update);
    # train steps donate params + optimizer state (in-place weight update)
    donate = ()
    if shape.kind == "decode":
        donate = (1,)
    elif shape.kind == "train":
        donate = (0, 1)

    t0 = time.time()
    with sh.use_rules(rules, mesh), mesh:
        lowered = jax.jit(fn, in_shardings=in_shardings,
                          donate_argnums=donate).lower(*args_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax < 0.5 returns [dict]
        ca = ca[0] if ca else {}
    hlo = analyze(compiled.as_text())

    # roofline terms (per device; hlo stats are already per-device)
    t_compute = hlo.flops / TPU_V5E.flops
    t_memory = hlo.bytes / TPU_V5E.hbm_bw
    t_coll = hlo.coll_bytes / TPU_V5E.ici_bw
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "opts": opts or {},
        "mesh": "2x16x16" if multi_pod else "16x16",
        "peaks": TPU_V5E.name,
        "n_devices": n_dev,
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device_bytes": {
            "arguments": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "total": mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes,
            # XLA:CPU materializes f32 upcast copies of bf16 args in temp;
            # on TPU those don't exist. Estimate = temp - 2x(bf16 args).
            "temp_tpu_estimate": max(
                0, mem.temp_size_in_bytes - 2 * mem.argument_size_in_bytes),
        },
        "hlo_flops_per_dev": hlo.flops,
        "hlo_bytes_per_dev": hlo.bytes,
        "coll_bytes_per_dev": hlo.coll_bytes,
        "coll_counts": hlo.coll_counts,
        "raw_cost_analysis": {k: ca.get(k) for k in
                              ("flops", "bytes accessed")},
        "roofline": {
            "compute_s": t_compute,
            "memory_s": t_memory,
            "collective_s": t_coll,
            "dominant": dominant,
            "bound_s": max(t_compute, t_memory, t_coll),
        },
        "model_flops_total": mf,
        "model_flops_per_dev": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / hlo.flops if hlo.flops else 0.0,
    }
    if verbose:
        print(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--opt", action="append", default=[],
                    help="perf knob key=val (repeatable)")
    ap.add_argument("--tag", type=str, default="",
                    help="suffix for output filenames")
    args = ap.parse_args()
    opts = dict(kv.split("=", 1) for kv in args.opt)

    cells = []
    if args.all:
        for arch in ASSIGNED:
            cfg = get_config(arch)
            for shape in applicable_shapes(cfg):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    records = []
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           verbose=not args.out, opts=opts)
        except Exception as e:  # noqa: BLE001 — record the failure
            rec = {"arch": arch, "shape": shape, "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            print(json.dumps(rec))
        records.append(rec)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = "mp" if args.multi_pod else "sp"
            if args.tag:
                tag += "__" + args.tag
            with open(f"{args.out}/{arch}__{shape}__{tag}.json", "w") as f:
                json.dump(rec, f, indent=2)
            print(f"[dryrun] {arch} x {shape} ({tag}) -> "
                  f"{'OK' if rec.get('ok') else 'FAIL'}")


if __name__ == "__main__":
    main()
