"""Shared accept/reject/unsure decision kernel (paper Eq. 16, tau -> 0).

One jit-compiled, vectorized implementation of the cascade decision rule
used everywhere a plan's thresholds are applied to raw operator scores:
the streaming executor, the relaxation's hard-decision extraction, and the
planner's selectivity simulation. Before this module the rule lived in
three hand-rolled copies (core/executor.py, core/relaxation.py,
core/planner._selectivities) that could — and did — drift.

The rule is the argmax of the three logits [s - thr_hi, thr_lo - s, 0]
(NOT simply `s > thr_hi`: the learned thresholds may cross, and the
softmax tau->0 limit is the argmax — keeping hard and soft semantics
identical removes the extraction gap). Maps have no reject branch: a map
commits (accept) or defers (unsure).

This module is deliberately dependency-free (jax/numpy only) so it can be
imported from anywhere in the tree without cycles.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def decide_traced(scores, thr_hi, thr_lo, is_map: bool):
    """Traceable argmax rule; broadcasts thresholds against ``scores``.

    Returns boolean arrays (accept, reject, unsure) of ``scores``' shape.
    Usable inside other jit regions (it inlines).
    """
    z_acc = scores - thr_hi
    z_rej = thr_lo - scores
    if is_map:
        z_rej = jnp.full_like(z_rej, -jnp.inf)
    acc = (z_acc > 0) & (z_acc >= z_rej)
    rej = (z_rej > 0) & (z_rej > z_acc)
    uns = ~(acc | rej)
    return acc, rej, uns


_decide_jit = jax.jit(decide_traced, static_argnames="is_map")


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def decide(scores, thr_hi, thr_lo, is_map: bool
           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy-facing jit entry point: (accept, reject, unsure) bool arrays.

    1-D inputs are padded to the next power of two before dispatch so the
    streaming executor's ever-varying flush sizes hit O(log N) compiled
    shapes instead of one compile per batch size; the rule is elementwise,
    so padding lanes cannot perturb real ones.
    """
    scores = np.asarray(scores, np.float32)
    n = scores.shape[0] if scores.ndim == 1 else None
    if n is not None and _bucket(n) != n:
        scores = np.pad(scores, (0, _bucket(n) - n))
    acc, rej, uns = _decide_jit(jnp.asarray(scores), thr_hi, thr_lo, is_map)
    acc, rej, uns = np.asarray(acc), np.asarray(rej), np.asarray(uns)
    if n is not None:
        acc, rej, uns = acc[:n], rej[:n], uns[:n]
    return acc, rej, uns


def gold_decide(scores, is_map: bool) -> Tuple[np.ndarray, np.ndarray]:
    """Gold operators decide at their natural boundary (log-odds 0) and
    are never unsure; gold maps always commit. Returns (accept, reject)."""
    scores = np.asarray(scores)
    if is_map:
        return np.ones(scores.shape, bool), np.zeros(scores.shape, bool)
    acc = scores > 0
    return acc, ~acc
