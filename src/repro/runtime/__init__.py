"""Stretto runtime: the single execution path for plans and operators.

Layout
------
  kernel.py     — jit-compiled accept/reject/unsure decision kernel
  backend.py    — Backend protocol + Oracle / KVCache / Reference backends
  executor.py   — streaming partitioned cascade executor (StageStats)
  dispatch.py   — pluggable flush dispatch: inline / thread pool /
                  sharded partition scatter / jax-mesh device scatter
                  (STRETTO_DISPATCHER)
  plan_utils.py — public profile/plan helpers (gold membership,
                  pipeline data, selectivity estimation)

Attribute access is lazy (PEP 562) so leaf modules — notably the
dependency-free kernel — can be imported from inside repro.core without
dragging the whole runtime (and its serving imports) into the cycle.
"""
from __future__ import annotations

_EXPORTS = {
    "decide": "repro.runtime.kernel",
    "gold_decide": "repro.runtime.kernel",
    "Backend": "repro.runtime.backend",
    "OracleBackend": "repro.runtime.backend",
    "KVCacheBackend": "repro.runtime.backend",
    "ReferenceBackend": "repro.runtime.backend",
    "RegistryBackend": "repro.runtime.backend",
    "PoolBackend": "repro.runtime.backend",
    "EngineTaggedOperator": "repro.runtime.backend",
    "as_backend": "repro.runtime.backend",
    "StageStats": "repro.runtime.executor",
    "RuntimeResult": "repro.runtime.executor",
    "PartitionResult": "repro.runtime.executor",
    "run_plan": "repro.runtime.executor",
    "iter_plan": "repro.runtime.executor",
    "run_operator": "repro.runtime.executor",
    "merge_stage_stats": "repro.runtime.executor",
    "stage_stats_by_engine": "repro.runtime.executor",
    "DEFAULT_COALESCE": "repro.runtime.dispatch",
    "FlushTask": "repro.runtime.dispatch",
    "backend_engines": "repro.runtime.dispatch",
    "InlineDispatcher": "repro.runtime.dispatch",
    "ThreadPoolDispatcher": "repro.runtime.dispatch",
    "ShardedDispatcher": "repro.runtime.dispatch",
    "MeshDispatcher": "repro.runtime.dispatch",
    "resolve_dispatcher": "repro.runtime.dispatch",
    "effective_spec": "repro.runtime.dispatch",
    "DISPATCHER_ENV": "repro.runtime.dispatch",
    "PairItem": "repro.runtime.tree",
    "TreeResult": "repro.runtime.tree",
    "make_pairs": "repro.runtime.tree",
    "survivor_pairs": "repro.runtime.tree",
    "run_tree": "repro.runtime.tree",
    "run_gold_tree": "repro.runtime.tree",
    "evaluate_pairs": "repro.runtime.tree",
    "gold_membership": "repro.runtime.plan_utils",
    "gold_plan_for": "repro.runtime.plan_utils",
    "pipelines_data": "repro.runtime.plan_utils",
    "estimate_selectivities": "repro.runtime.plan_utils",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(_EXPORTS[name])
        value = getattr(mod, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
