"""Join-tree execution over the streaming runtime.

A planned `TreePlan` executes as three streaming cascade runs through the
*same* run_plan machinery (same FlushTask dispatch, same StageStats
telemetry, same decision kernel):

  1. the `left` side plan over the left corpus,
  2. the `right` side plan over the right corpus,
  3. the `pair` plan over the blocked survivor pairs — every (l, r) with
     both sides accepted and (when the join declares `on`) equal block
     column values, wrapped as `PairItem`s.

Per-tuple decisions of each run are dispatcher-invariant (the runtime's
standing parity guarantee), the survivor pair-corpus is built in
deterministic left-major order from those decisions, so the whole tree's
result is bit-identical across inline / threads / sharded / mesh
dispatchers with zero extra machinery.

`PairItem` is the pair corpus's item type: `item_id` is the
``(left_id, right_id)`` tuple (side corpora must use disjoint id spaces —
serving profiles are keyed by item id), and `row` merges both sides'
structured rows under ``left_`` / ``right_`` prefixes (columns whose
values agree on both sides additionally keep their bare name, so
relational predicates over shared/blocked columns keep working on pairs).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.physical import TREE_ROLES, TreePlan
from repro.runtime.backend import as_backend
from repro.runtime.executor import RuntimeResult, StageStats, run_plan
from repro.runtime.plan_utils import gold_plan_for


@dataclass(frozen=True)
class PairItem:
    """One candidate join pair — the pair cascade's corpus element."""
    left: Any
    right: Any
    item_id: Tuple[Any, Any]            # (left.item_id, right.item_id)
    row: Dict[str, Any]


def make_pair(left: Any, right: Any) -> PairItem:
    lrow = getattr(left, "row", {}) or {}
    rrow = getattr(right, "row", {}) or {}
    row = {f"left_{k}": v for k, v in lrow.items()}
    row.update({f"right_{k}": v for k, v in rrow.items()})
    for k, v in lrow.items():           # agreeing shared columns: bare name
        if k in rrow and rrow[k] == v:
            row[k] = v
    return PairItem(left, right,
                    (getattr(left, "item_id", None),
                     getattr(right, "item_id", None)), row)


def make_pairs(left_items: Sequence[Any],
               right_items: Sequence[Any]) -> List[PairItem]:
    """Zip two equal-length item lists into PairItems (the planner's
    sample-pair construction; survivor pairing goes through
    `survivor_pairs`)."""
    if len(left_items) != len(right_items):
        raise ValueError("make_pairs zips equal-length lists; for the "
                         "cross/blocked product use survivor_pairs")
    return [make_pair(l, r) for l, r in zip(left_items, right_items)]


def survivor_pairs(left_items: Sequence[Any], right_items: Sequence[Any],
                   on: Optional[str]) -> List[PairItem]:
    """The blocked pair corpus over two survivor sets, in deterministic
    left-major order: every (l, r), restricted to equal `on` column
    values when the join declares a blocking column. Rows missing the
    block column never pair (SQL equi-join semantics)."""
    if on is None:
        return [make_pair(l, r) for l in left_items for r in right_items]
    by_val: Dict[Any, List[Any]] = {}
    for r in right_items:
        v = (getattr(r, "row", {}) or {}).get(on)
        if v is not None:
            by_val.setdefault(v, []).append(r)
    out: List[PairItem] = []
    for l in left_items:
        v = (getattr(l, "row", {}) or {}).get(on)
        if v is None:
            continue
        for r in by_val.get(v, ()):
            out.append(make_pair(l, r))
    return out


@dataclass
class TreeResult:
    """Result of executing a TreePlan: the three role runs plus the final
    accepted pair ids. Telemetry composes from the role runs — the
    `stage_stats` property retags each role's stages with tree-unique
    logical indices (`TreePlan.role_base`), so merged tree telemetry
    tiles exactly like single-pipeline telemetry does."""
    roles: Dict[str, RuntimeResult]       # keyed by TREE_ROLES
    pair_items: List[PairItem]            # the blocked survivor pair corpus
    pair_ids: List[Tuple[Any, Any]]       # accepted (left_id, right_id)s
    plan: TreePlan
    wall_s: float = 0.0                   # end-to-end elapsed (3 runs +
    #                                       pair construction)

    @property
    def runtime_s(self) -> float:
        return sum(r.runtime_s for r in self.roles.values())

    @property
    def n_llm_tuples(self) -> int:
        return sum(r.n_llm_tuples for r in self.roles.values())

    @property
    def stage_stats(self) -> List[StageStats]:
        out: List[StageStats] = []
        for role in TREE_ROLES:
            base = self.plan.role_base(role)
            for sg in self.roles[role].stage_stats:
                retagged = sg.copy()
                retagged.logical_idx += base
                out.append(retagged)
        return out

    @property
    def map_values(self) -> Dict[int, np.ndarray]:
        """Pair-cascade map values under tree-unique logical indices
        (aligned with `pair_items`)."""
        base = self.plan.role_base("pair")
        return {base + li: vals
                for li, vals in self.roles["pair"].map_values.items()}

    def id_set(self) -> Set[Tuple[Any, Any]]:
        return set(self.pair_ids)


def _run_roles(role_plans: Dict[str, Any], queries: Dict[str, Any],
               join, left_items: Sequence[Any], right_items: Sequence[Any],
               backend, plan: TreePlan, **exec_kwargs) -> TreeResult:
    t0 = time.perf_counter()
    backend = as_backend(backend)
    res: Dict[str, RuntimeResult] = {}
    res["left"] = run_plan(role_plans["left"], queries["left"], left_items,
                           backend, **exec_kwargs)
    res["right"] = run_plan(role_plans["right"], queries["right"],
                            right_items, backend, **exec_kwargs)
    pairs = survivor_pairs(
        [left_items[i] for i in np.flatnonzero(res["left"].accepted)],
        [right_items[j] for j in np.flatnonzero(res["right"].accepted)],
        join.on)
    res["pair"] = run_plan(role_plans["pair"], queries["pair"], pairs,
                           backend, **exec_kwargs)
    pair_ids = [pairs[t].item_id
                for t in np.flatnonzero(res["pair"].accepted)]
    return TreeResult(roles=res, pair_items=pairs, pair_ids=pair_ids,
                      plan=plan, wall_s=time.perf_counter() - t0)


def run_tree(plan: TreePlan, left_items: Sequence[Any],
             right_items: Sequence[Any], backend, *,
             partition_size: Optional[int] = None,
             coalesce: Optional[int] = None,
             dispatcher=None) -> TreeResult:
    """Execute a planned join tree: left side, right side, then the pair
    cascade over the blocked survivor pairs. Accepts the same execution
    knobs as `run_plan`; every role run uses them uniformly."""
    return _run_roles(plan.roles, plan.queries, plan.join, left_items,
                      right_items, backend, plan,
                      partition_size=partition_size, coalesce=coalesce,
                      dispatcher=dispatcher)


def run_gold_tree(plan: TreePlan, left_items: Sequence[Any],
                  right_items: Sequence[Any], backend,
                  **exec_kwargs) -> TreeResult:
    """The tree's quality reference: every role executes its gold-only
    plan (each semantic operator's gold physical implementation on every
    tuple), pairing the gold survivors. The resulting pair-id set is what
    tree recall/precision are measured against."""
    backend = as_backend(backend)
    gold_plans = {role: gold_plan_for(plan.queries[role], backend)
                  for role in TREE_ROLES}
    return _run_roles(gold_plans, plan.queries, plan.join, left_items,
                      right_items, backend, plan, **exec_kwargs)


def evaluate_pairs(result: TreeResult, gold: TreeResult
                   ) -> Dict[str, float]:
    """Pair-id-set recall / precision / F1 of a tree result against the
    gold tree reference."""
    got, want = result.id_set(), gold.id_set()
    tp = len(got & want)
    rec = tp / max(len(want), 1)
    prec = tp / max(len(got), 1)
    return {"recall": rec, "precision": prec,
            "f1": 2 * rec * prec / max(rec + prec, 1e-9),
            "n_result": len(got), "n_gold": len(want),
            "n_pairs_scored": len(result.pair_items)}
