"""Public plan/profile utilities shared by the planner and every baseline.

These used to live as underscore-private helpers inside core/planner.py;
the baselines reached in and imported them anyway, which made the planner's
internals load-bearing API by accident. They are now first-class runtime
utilities with stable names:

  gold_membership         — (N,) gold-result-set indicator from profiles
  pipelines_data          — ProfiledPipeline -> relaxation PipelineData
  estimate_selectivities  — per-selected-op inter/intra selectivities by
                            hard-simulating the chosen cascades on the
                            profiled sample (shared decision kernel)
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import relaxation as R
from repro.core.logical import Query, SemMap, SemTopK
from repro.core.physical import (PhysicalPlan, PhysicalPlanStage,
                                 ProfiledPipeline)
from repro.runtime.kernel import decide, gold_decide


def gold_plan_for(query: Query, backend) -> PhysicalPlan:
    """The reference plan: every semantic operator runs its gold physical
    implementation on every tuple (no thresholds, no cascades)."""
    from repro.runtime.backend import as_backend
    backend = as_backend(backend)
    stages = []
    for li, op in enumerate(query.semantic_ops):
        gold = backend.candidates(op)[-1]
        stages.append(PhysicalPlanStage(
            logical_idx=li, stage=0, op_name=gold.name,
            thr_hi=0.0, thr_lo=0.0, is_map=isinstance(op, SemMap),
            is_gold=True, cost=1.0,
            engine=getattr(gold, "engine_name", "")))
    return PhysicalPlan(stages=stages,
                        relational=list(query.relational_ops),
                        est_cost=0.0, recall_bound=1.0, precision_bound=1.0,
                        feasible=True)


def gold_membership(profiles: Sequence[ProfiledPipeline]) -> np.ndarray:
    """(N,) {0,1}: tuple is in the gold plan's result set (all gold filters
    accept; maps are correct vs themselves by construction)."""
    g = None
    for p in profiles:
        if p.is_map:
            continue
        acc = (p.scores[-1] > 0).astype(np.float32)
        g = acc if g is None else g * acc
    if g is None:   # map-only query: every tuple is in the gold result
        g = np.ones(profiles[0].scores.shape[1], np.float32)
    return g


def pipelines_data(profiles: Sequence[ProfiledPipeline], measured=None,
                   sem_ops: Sequence = None) -> List[R.PipelineData]:
    """Lift numpy profiling results into the relaxation's jnp PipelineData.

    Profiles carrying fitted CostCurves split cost into marginal per-tuple
    and fixed per-call components (plus the op's memory-budgeted batch
    cap), activating the batch-size-aware cost model; profiles without
    curves keep the scalar measured per-tuple cost.

    `measured` (a core.profiling.MeasuredBatchStore, optional) supplies
    each op's measured flush width from past executions: ops with a
    recorded `mean_batch` are priced at it instead of the static
    BatchHint width (unmeasured ops get NaN, the relaxation's
    fall-back-to-hint marker).

    `sem_ops` (optional, aligned with `profiles`) marks SemTopK
    pipelines as reject-only (`no_accept`): their non-gold stages may
    terminate hopeless tuples early but never admit — admission is the
    gold rank cut."""
    out = []
    for li, p in enumerate(profiles):
        no_accept = sem_ops is not None and isinstance(sem_ops[li], SemTopK)
        if p.cost_curves is not None:
            costs = jnp.asarray([c.per_tuple_s for c in p.cost_curves],
                                jnp.float32)
            fixed = jnp.asarray([c.fixed_s for c in p.cost_curves],
                                jnp.float32)
        else:
            costs = jnp.asarray(p.costs)
            fixed = None
        meas_width = None
        if measured is not None and len(measured):
            widths = [measured.mean_batch(name) for name in p.op_names]
            if any(w is not None for w in widths):
                meas_width = jnp.asarray(
                    [np.nan if w is None else w for w in widths],
                    jnp.float32)
        out.append(R.PipelineData(
            scores=jnp.asarray(p.scores),
            costs=costs,
            is_map=p.is_map,
            correct=None if p.correct is None else jnp.asarray(p.correct),
            fixed=fixed,
            batch_cap=None if p.batch_caps is None
            else jnp.asarray(p.batch_caps, jnp.float32),
            meas_width=meas_width,
            no_accept=no_accept))
    return out


def estimate_selectivities(profiles: Sequence[ProfiledPipeline], plan,
                           sem_ops: Sequence = None
                           ) -> List[Dict[int, Tuple[float, float, float]]]:
    """Hard-simulate the chosen cascades on the sample to estimate each
    selected op's inter/intra selectivity over the tuples reaching it.

    plan: an OptimizedPlan (params + selected masks per pipeline).
    Returns, per pipeline, {op_index: (sel_inter, sel_intra, reach_frac)}
    where inter = fraction not rejected, intra = fraction still unsure,
    and reach_frac = fraction of the sample the op scores at all — the
    quantity the batch-aware cost model turns into an expected flush
    batch size.
    """
    sel = []
    for li, (p, params, mask) in enumerate(
            zip(profiles, plan.params, plan.selected)):
        acc_i, rej_i, _ = decide(
            p.scores, np.asarray(params.thr_hi)[:, None],
            np.asarray(params.thr_lo)[:, None], p.is_map)
        if sem_ops is not None and isinstance(sem_ops[li], SemTopK):
            # reject-only cascade: at execution the non-gold accept
            # boundary is +inf, so a learned accept never fires
            acc_i = np.zeros_like(np.asarray(acc_i), bool)
        n_ops, N = p.scores.shape
        unsure = np.ones(N, bool)
        per_op: Dict[int, Tuple[float, float, float]] = {}
        for i in range(n_ops):
            if not mask[i]:
                continue
            if i == n_ops - 1:   # gold decides at its natural boundary
                acc, rej = gold_decide(p.scores[-1], p.is_map)
            else:
                acc, rej = acc_i[i], rej_i[i]
            reach = unsure
            n_reach = max(int(reach.sum()), 1)
            n_rej = int((reach & rej).sum())
            n_uns = int((reach & ~acc & ~rej).sum())
            per_op[i] = (1.0 - n_rej / n_reach,   # inter: not rejected
                         n_uns / n_reach,         # intra: still unsure
                         n_reach / max(N, 1))     # reach over the sample
            unsure = reach & ~acc & ~rej
        sel.append(per_op)
    return sel
