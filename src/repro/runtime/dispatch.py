"""Pluggable dispatch layer for the streaming executor's stage flushes.

The streaming executor (runtime/executor.py) turned every stage flush into
an independent batch call; this module decides *where* those calls run.
A flush becomes a `FlushTask` submitted to a `Dispatcher`:

  InlineDispatcher     — runs the operator on the calling thread and
                         completes it immediately: today's behavior, the
                         parity baseline every other dispatcher must match.
  ThreadPoolDispatcher — overlaps independent stage flushes on a thread
                         pool. Cohorts in flight are always disjoint tuple
                         sets (a tuple lives in exactly one coalescing
                         buffer or one in-flight flush), so operator calls
                         are data-independent; the executor applies
                         completions in strict submission (FIFO) order, so
                         state evolution is deterministic, and accepted /
                         map_values match the inline schedule bit-for-bit
                         as long as per-tuple scores are independent of
                         batch grouping (see run_plan's docstring for the
                         exact condition).
  ShardedDispatcher    — scatters `run_plan`'s partition loop itself:
                         contiguous corpus shards each run the full
                         streaming cascade independently (per-tuple
                         decisions are partition-invariant), and only the
                         `_CascadeState` bool arrays are merged and the
                         per-stage StageStats summed. Shards are the unit
                         that maps onto a jax mesh axis or one process per
                         host in a multi-process deployment; here they run
                         on a thread pool sharing one engine.
  MeshDispatcher       — the same partition-loop scatter over a *real*
                         jax device mesh: each corpus shard owns a slice
                         of the mesh's "data" axis (launch/mesh.py), the
                         backend's engine params are placed on that slice
                         with device_put + a NamedSharding resolved
                         through the logical-axis rules
                         (distributed/sharding.py), and every H2D copy /
                         decode the shard issues lands on its own device.
                         Same shard tiling, same merge contract, so
                         decisions stay bit-identical to inline — only
                         where the flushes run changes.

Selection: pass a Dispatcher (or spec string) to `run_plan(dispatcher=...)`
or set the ``STRETTO_DISPATCHER`` environment variable
(``inline`` | ``threads[:N]`` | ``sharded[:N]`` | ``mesh[:N]``).
"""
from __future__ import annotations

import contextlib
import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

DISPATCHER_ENV = "STRETTO_DISPATCHER"

# default coalesced flush width (tuples per stage batch): the single
# source of truth shared by the executor's streaming default, the
# benchmarks' execution config and the planner's batch-size-aware cost
# amortization (BatchHint.width), so planning prices the flush batches
# execution will actually run. Lives in this dependency-free leaf module
# so repro.core (whose planner imports it) and repro.runtime (whose
# executor imports repro.core dataclasses) can both reach it without an
# import cycle.
DEFAULT_COALESCE = 64

_DEFAULT_THREADS = 4
_DEFAULT_SHARDS = 2


@dataclass
class FlushTask:
    """One coalesced stage flush: a batch of tuples for one physical
    operator. `items` holds only the tuples the stage will actually score
    (the eligible subset of its cohort)."""
    stage_idx: int           # position in plan.stages
    sem_op: Any              # the logical (semantic) operator
    op_name: str             # physical operator name to resolve
    items: List[Any]         # batch payloads, eligible tuples only
    engine: str = ""         # owning engine of the stage's operator (""
    #                          for single-engine sessions): dispatchers
    #                          with per-engine affinity route on it, and
    #                          because the executor applies completions in
    #                          global submission (FIFO) order regardless
    #                          of which pool ran a task, per-engine
    #                          routing preserves submission-order parity


class _Immediate:
    """Resolved handle for synchronously executed tasks."""

    __slots__ = ("_value",)

    def __init__(self, value):
        self._value = value

    def result(self):
        return self._value


class InlineDispatcher:
    """Run every flush synchronously on the calling thread — the exact
    pre-dispatch execution schedule, and the parity baseline."""

    name = "inline"
    n_workers = 1
    n_shards = 1
    max_pending = 0     # executor completes each flush right after submit

    def submit(self, task: FlushTask,
               runner: Callable[[FlushTask], Any]) -> _Immediate:
        return _Immediate(runner(task))

    def close(self):
        pass


class ThreadPoolDispatcher:
    """Overlap independent stage flushes on a thread pool.

    The executor bounds in-flight flushes at `max_pending` and applies
    completions in FIFO submission order, so scheduling decisions (cohort
    composition, flush points) depend only on deterministically ordered
    state — never on thread timing. Operator calls themselves are pure
    batch -> scores functions; jax releases the GIL during device
    execution, which is where the overlap comes from.
    """

    name = "threads"
    n_shards = 1

    def __init__(self, n_workers: int = _DEFAULT_THREADS,
                 engine_workers: Optional[Dict[str, int]] = None):
        """`engine_workers` declares per-engine thread affinity: flushes
        whose FlushTask.engine appears in the mapping run on a dedicated
        pool of that size (engines stop contending for each other's
        workers); everything else shares the default pool. Completions
        are still applied by the executor in global submission order, so
        affinity never changes decisions — only where the overlap
        happens."""
        self.n_workers = max(int(n_workers), 1)
        self.engine_workers = {str(k): max(int(v), 1)
                               for k, v in (engine_workers or {}).items()}
        # in-flight window: enough tasks to keep every worker busy while
        # the main thread prepares the next cohort
        total = self.n_workers + sum(self.engine_workers.values())
        self.max_pending = 2 * total
        self._pools: Dict[str, ThreadPoolExecutor] = {}
        self._lock = threading.Lock()
        self._closed = False

    def _pool_for(self, engine: str) -> ThreadPoolExecutor:
        key = engine if engine in self.engine_workers else ""
        with self._lock:
            if self._closed:
                # without this check a submit racing close() would
                # silently respawn a fresh pool that nothing ever shuts
                # down (close already ran) — fail loudly instead
                raise RuntimeError(
                    "ThreadPoolDispatcher is closed; flushes can no "
                    "longer be submitted")
            pool = self._pools.get(key)
            if pool is None:
                workers = self.engine_workers.get(key, self.n_workers)
                pool = ThreadPoolExecutor(
                    max_workers=workers,
                    thread_name_prefix=f"stretto-flush-{key or 'shared'}")
                self._pools[key] = pool
            return pool

    def submit(self, task: FlushTask,
               runner: Callable[[FlushTask], Any]) -> Future:
        return self._pool_for(getattr(task, "engine", "") or "").submit(
            runner, task)

    def close(self):
        """Idempotent and safe under concurrent submitters: the first
        close wins (later calls return immediately), pools are shut down
        outside the lock (a shutdown waits for running flushes, which
        must not block new submitters from getting their clear
        submit-after-close error), and any submit that loses the race
        raises instead of leaking an orphan pool."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pools, self._pools = dict(self._pools), {}
        for pool in pools.values():
            pool.shutdown(wait=True)


class ShardedDispatcher:
    """Scatter the partition loop: each contiguous corpus shard streams
    through the full cascade independently; the executor merges only the
    per-shard bool decision arrays and sums StageStats."""

    name = "sharded"
    max_pending = 0

    def __init__(self, n_shards: int = _DEFAULT_SHARDS,
                 n_workers: Optional[int] = None):
        self.n_shards = max(int(n_shards), 1)
        self.n_workers = max(int(n_workers or self.n_shards), 1)
        self._closed = False

    def shard_bounds(self, n_items: int) -> List[Tuple[int, int]]:
        """Contiguous [lo, hi) shard ranges covering the corpus."""
        k = min(self.n_shards, max(n_items, 1))
        step = (n_items + k - 1) // max(k, 1)
        return [(lo, min(lo + step, n_items))
                for lo in range(0, n_items, max(step, 1))]

    def map_shards(self, fn: Callable[[int, int, int], Any],
                   bounds: Sequence[Tuple[int, int]]) -> List[Any]:
        """Run ``fn(shard_idx, lo, hi)`` for every shard; the index lets
        dispatchers with per-shard placement (MeshDispatcher) route each
        shard onto its own device slice."""
        if self._closed:
            raise RuntimeError(
                f"{type(self).__name__} is closed; shards can no longer "
                f"be scattered")
        if len(bounds) <= 1 or self.n_workers <= 1:
            return [fn(i, lo, hi) for i, (lo, hi) in enumerate(bounds)]
        with ThreadPoolExecutor(max_workers=self.n_workers,
                                thread_name_prefix="stretto-shard") as pool:
            futs = [pool.submit(fn, i, lo, hi)
                    for i, (lo, hi) in enumerate(bounds)]
            return [f.result() for f in futs]

    def close(self):
        # idempotent: per-scatter pools are context-managed inside
        # map_shards, so closing only has to fence future scatters
        self._closed = True


def backend_engines(backend) -> List[Any]:
    """Every ServingEngine a runtime backend routes flushes to: the
    engine of a KVCache/Reference backend, the union over a PoolBackend's
    members, [] for engineless (oracle/registry) backends. Used by
    dispatchers that place engine state per device."""
    eng = getattr(backend, "engine", None)
    if eng is not None:
        return [eng]
    members = getattr(backend, "members", None)
    if members:
        out: List[Any] = []
        for m in members.values():
            out.extend(backend_engines(m))
        return out
    return []


class MeshDispatcher(ShardedDispatcher):
    """ShardedDispatcher over a real jax device mesh: shard i of the
    partition-loop scatter runs with its engine params device_put onto
    data-axis slice ``i % n_data`` of the dispatch mesh (replication
    resolved through distributed.sharding's logical-axis rules), and with
    that slice as the shard thread's default jax device, so cache loads
    (H2D) and decode dispatches land per-device instead of contending for
    one. Shard tiling (`shard_bounds`) and the merge contract are
    inherited unchanged, so decisions / map values stay bit-identical to
    the inline schedule; with fewer devices than shards the shards cycle
    over the available slices (a 1-device host degenerates to
    ShardedDispatcher behavior exactly).
    """

    name = "mesh"

    def __init__(self, n_shards: Optional[int] = None,
                 n_workers: Optional[int] = None):
        import jax       # deferred: this module stays a cheap leaf import
        n = int(n_shards) if n_shards else jax.local_device_count()
        super().__init__(n, n_workers if n_workers is not None else n)
        self._lock = threading.Lock()
        self._mesh = None
        self._data_slices: List[Tuple[Any, ...]] = []

    @property
    def mesh(self):
        """The dispatch mesh (built lazily on first scatter): up to
        n_shards devices on the "data" axis — launch.mesh's local /
        production meshes finally wired into the runtime."""
        with self._lock:
            if self._mesh is None:
                from repro.launch.mesh import make_dispatch_mesh
                self._mesh = make_dispatch_mesh(self.n_shards)
                # device slices along the data axis: row i holds the
                # devices shard i runs on (model axis is 1-wide here)
                self._data_slices = [tuple(row)
                                     for row in self._mesh.devices]
            return self._mesh

    def shard_device(self, shard_idx: int):
        """The device owning shard `shard_idx` (shards cycle when the
        mesh has fewer data slices than shards)."""
        _ = self.mesh
        return self._data_slices[shard_idx % len(self._data_slices)][0]

    @contextlib.contextmanager
    def shard_context(self, shard_idx: int, backend):
        """Everything shard `shard_idx` executes runs on its own device
        slice: engine params are placed there via device_put + the
        logical-rules NamedSharding, and the slice becomes the shard
        thread's default device so batch H2D copies follow."""
        import jax
        from repro.distributed.sharding import replicated_on
        dev = self.shard_device(shard_idx)
        sharding = replicated_on(dev)
        with contextlib.ExitStack() as stack:
            for eng in backend_engines(backend):
                stack.enter_context(eng.place_on(dev, sharding))
            stack.enter_context(jax.default_device(dev))
            yield


def effective_spec(spec=None) -> str:
    """The dispatcher spec a run with this argument will actually use:
    spec strings pass through, Dispatcher instances report their name,
    and None resolves the ``STRETTO_DISPATCHER`` environment default
    (``inline``). The single source of the env-default policy — EXPLAIN
    reports through this, so it cannot drift from resolve_dispatcher."""
    if spec is None:
        spec = os.environ.get(DISPATCHER_ENV, "") or "inline"
    if isinstance(spec, str):
        return spec
    return getattr(spec, "name", str(spec))


def resolve_dispatcher(spec=None) -> Tuple[Any, bool]:
    """Resolve a dispatcher argument to (dispatcher, owned).

    `spec` may be a Dispatcher instance (passed through, owned=False — the
    caller manages its lifetime), a spec string (``inline``, ``threads``,
    ``threads:N``, ``sharded``, ``sharded:N``, ``mesh``, ``mesh:N`` —
    a bare ``mesh`` scatters over every local jax device), or None, which
    reads the ``STRETTO_DISPATCHER`` environment variable (default
    ``inline``). Owned dispatchers are closed by run_plan when the plan
    finishes.
    """
    if spec is None:
        spec = effective_spec()
    if hasattr(spec, "submit") or hasattr(spec, "map_shards"):
        return spec, False
    if not isinstance(spec, str):
        raise TypeError(f"cannot resolve {type(spec)!r} to a Dispatcher")
    kind, _, arg = spec.partition(":")
    n = int(arg) if arg else None
    if n is not None and n <= 0:
        raise ValueError(f"dispatcher spec {spec!r}: worker/shard count "
                         f"must be positive, got {n}")
    if kind == "inline":
        return InlineDispatcher(), True
    if kind == "threads":
        return ThreadPoolDispatcher(
            n if n is not None else _DEFAULT_THREADS), True
    if kind == "sharded":
        return ShardedDispatcher(
            n if n is not None else _DEFAULT_SHARDS), True
    if kind == "mesh":
        return MeshDispatcher(n), True
    raise ValueError(f"unknown dispatcher spec {spec!r} "
                     "(expected inline | threads[:N] | sharded[:N] "
                     "| mesh[:N])")
