"""Pluggable operator backends for the runtime (paper §5 execution layer).

A Backend answers one question: "score this batch of items under this
physical implementation of a semantic operator". It owns operator
resolution (which physical candidates implement a logical op, gold last)
and batched invocation (`score_filter` / `run_map`), replacing the ad-hoc
`registry(op) -> [PhysicalOperator]` callables that the planner, profiler,
executor and baselines each used to thread around and index separately.

Implementations:

  OracleBackend     — wraps any registry callable (in this repo: the
                      synthetic planted-signal registry from
                      repro.serving.operators.make_registry).
  KVCacheBackend    — the paper's contribution, first-class: operators
                      over precomputed (compressed) KV-cache profiles of a
                      ServingEngine, with KV-bytes telemetry.
  ReferenceBackend  — uncompressed gold only (largest model, ratio 0.0):
                      the quality reference every experiment compares to.
  PoolBackend       — a routing pool over *named* member backends
                      (heterogeneous engines): `candidates()` is the union
                      of every member's non-gold candidates, each tagged
                      with its owning engine (operator names become
                      ``engine/op``), sorted by (cost-scaled) static cost,
                      with exactly one gold — the designated gold engine's
                      — resolved last. score_filter / run_map and the
                      KV-bytes counter route to the owning member, so the
                      planner prices and the executor attributes every
                      stage per (engine, operator).

`as_backend` adapts legacy registry callables, so every older entry point
keeps working while routing through the single runtime execution path.
"""
from __future__ import annotations

import threading
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.core.logical import SemFilter, SemMap
from repro.core.physical import PhysicalOperator


@runtime_checkable
class Backend(Protocol):
    """Batched execution surface for physical operators."""

    name: str

    def candidates(self, op) -> List[PhysicalOperator]:
        """Physical implementations of semantic op, cost order, gold LAST."""
        ...

    def resolve(self, op, op_name: str) -> PhysicalOperator:
        """The named physical implementation of a semantic operator."""
        ...

    def score_filter(self, op: SemFilter, op_name: str,
                     items: Sequence[Any]) -> np.ndarray:
        """Log-odds scores (len(items),) for a SemFilter batch."""
        ...

    def run_map(self, op: SemMap, op_name: str, items: Sequence[Any]
                ) -> Tuple[np.ndarray, np.ndarray]:
        """(values, confidences) each (len(items),) for a SemMap batch."""
        ...

    def kv_bytes_loaded(self) -> int:
        """Monotonic counter of KV-cache bytes materialized so far *by the
        calling thread* (0 for backends that never touch a cache store).
        Thread-scoped so `run_operator`'s before/after deltas stay exact
        when independent flushes overlap on a dispatcher's thread pool —
        a process-global counter would interleave concurrent loads into
        each other's deltas and double-count."""
        ...


class RegistryBackend:
    """Shared machinery: a Backend over a `registry(op) -> [PhysicalOperator]`
    callable. Operator instances are cached per semantic op so repeated
    stages hit the same jit/profile state."""

    name = "registry"

    def __init__(self, registry: Callable):
        self._registry = registry
        self._cache: Dict[Any, List[PhysicalOperator]] = {}
        self._by_name: Dict[Any, PhysicalOperator] = {}
        # candidate/name resolution is memoized; the scheduler's query
        # drivers resolve concurrently, so the build-on-miss must be
        # serialized (RLock: a registry callable may itself resolve —
        # PoolBackend's union walks member candidates)
        self._resolve_lock = threading.RLock()

    def candidates(self, op) -> List[PhysicalOperator]:
        got = self._cache.get(op)
        if got is None:
            with self._resolve_lock:
                got = self._cache.get(op)
                if got is None:
                    got = list(self._registry(op))
                    self._cache[op] = got
        return got

    def resolve(self, op, op_name: str) -> PhysicalOperator:
        got = self._by_name.get((op, op_name))
        if got is not None:
            return got
        with self._resolve_lock:
            got = self._by_name.get((op, op_name))
            if got is not None:
                return got
            for phys in self.candidates(op):
                if phys.name == op_name:
                    self._by_name[(op, op_name)] = phys
                    return phys
        raise KeyError(f"backend {self.name!r} has no operator {op_name!r} "
                       f"for {op}")

    def score_filter(self, op: SemFilter, op_name: str,
                     items: Sequence[Any]) -> np.ndarray:
        phys = self.resolve(op, op_name)
        return np.asarray(phys.run_filter(items, op), np.float32)

    def run_map(self, op: SemMap, op_name: str, items: Sequence[Any]
                ) -> Tuple[np.ndarray, np.ndarray]:
        phys = self.resolve(op, op_name)
        vals, conf = phys.run_map(items, op)
        return np.asarray(vals), np.asarray(conf, np.float32)

    def kv_bytes_loaded(self) -> int:
        # Non-serving backends own no cache store, so they report a flat 0
        # — the StageStats kv_bytes field must not drift with whatever
        # engine-backed operators a registry callable happens to hand out.
        # Serving backends (KVCache / Reference) override this with their
        # engine's store counter.
        return 0

    def transfer_stats(self) -> Tuple[float, int]:
        """Monotonic (h2d_overlap_s, donated_bytes) counters for the
        calling thread — H2D transfer time the engine hid behind decode
        compute, and KV cache bytes donated back to XLA. Thread-scoped
        for the same reason as kv_bytes_loaded. Kept OFF the Backend
        protocol (it is optional — run_operator getattr-probes it), so
        custom backends that only implement the protocol surface keep
        satisfying the runtime_checkable isinstance check."""
        return (0.0, 0)


class OracleBackend(RegistryBackend):
    """Backend over the synthetic planted-signal registry (or any other
    registry callable): scores come from whatever operators the registry
    hands out."""

    name = "oracle"


class KVCacheBackend(RegistryBackend):
    """Backend over a ServingEngine's precomputed KV-cache profiles — the
    paper's prefill-skip operators as a first-class runtime backend."""

    name = "kvcache"

    def __init__(self, engine, *, sm: str = "sm", lg: str = "lg",
                 sm_ratios=(0.8, 0.5, 0.0), lg_ratios=(0.8, 0.5, 0.3),
                 sm_int8=(), lg_int8=(), include_cheap: bool = True):
        from repro.serving.operators import make_registry
        self.engine = engine
        super().__init__(make_registry(
            engine, sm=sm, lg=lg, sm_ratios=sm_ratios, lg_ratios=lg_ratios,
            sm_int8=sm_int8, lg_int8=lg_int8,
            include_cheap=include_cheap))

    def kv_bytes_loaded(self) -> int:
        # thread-local counter: a flush runs entirely on one dispatcher
        # thread, so per-call deltas are exact under concurrent dispatch
        return self.engine.store.bytes_loaded_local

    def transfer_stats(self) -> Tuple[float, int]:
        return self.engine.transfer_stats_local()


class ReferenceBackend(RegistryBackend):
    """Uncompressed gold only: every semantic operator maps to the single
    largest-model, ratio-0.0 operator. Executing any plan through this
    backend reproduces the reference result set."""

    name = "reference"

    def __init__(self, engine, *, lg: str = "lg"):
        from repro.core.logical import SemJoin
        from repro.serving.operators import (KVCacheLLMOperator,
                                             KVCachePairOperator)
        self.engine = engine

        def gold_registry(op):
            if isinstance(op, SemJoin):
                return [KVCachePairOperator(engine, lg, 0.0, is_gold=True)]
            return [KVCacheLLMOperator(engine, lg, 0.0, is_gold=True)]

        super().__init__(gold_registry)

    def kv_bytes_loaded(self) -> int:
        return self.engine.store.bytes_loaded_local

    def transfer_stats(self) -> Tuple[float, int]:
        return self.engine.transfer_stats_local()


class EngineTaggedOperator(PhysicalOperator):
    """A member engine's physical operator, as seen through a PoolBackend:
    the name gains an ``engine/`` prefix (so MeasuredBatchStore feedback
    and StageStats stay keyed per (engine, op) even when two engines serve
    the same model ladder), `.engine_name` names the owner (a dedicated
    attribute — serving operators already use `.engine` for the
    ServingEngine object itself), and the static cost-model estimate is
    scaled by the engine's declared `cost_scale` (candidate *ordering* —
    profiling still measures real wall time)."""

    def __init__(self, engine_name: str, inner: PhysicalOperator,
                 cost_scale: float = 1.0):
        self.engine_name = engine_name
        self.inner = inner
        self.cost_scale = float(cost_scale)
        self.name = f"{engine_name}/{inner.name}"
        self.is_gold = bool(getattr(inner, "is_gold", False))
        self.uses_llm = bool(getattr(inner, "uses_llm", True))

    def run_filter(self, items: Sequence[Any], op) -> np.ndarray:
        return self.inner.run_filter(items, op)

    def run_map(self, items: Sequence[Any], op):
        return self.inner.run_map(items, op)

    def cost_model(self) -> float:
        return self.inner.cost_model() * self.cost_scale

    def max_batch(self) -> Optional[int]:
        fn = getattr(self.inner, "max_batch", None)
        return fn() if callable(fn) else None


class PoolBackend(RegistryBackend):
    """Routing pool over named heterogeneous member backends.

    `members` is an ordered mapping (or sequence of pairs) ``name ->
    Backend``; `gold` names the member whose gold operator defines the
    reference (default: the first member — declaration order is the
    priority order). Candidates are the union of every member's non-gold
    candidates tagged ``name/op`` and sorted by cost-scaled static cost,
    plus the gold member's gold operator, last and unique — the Backend
    contract every planner/profiler path relies on. Execution and
    KV-bytes telemetry route to the owning member: a flush touches
    exactly one engine's cache store, so per-stage counters attribute to
    the right engine with no extra bookkeeping.
    """

    name = "pool"

    def __init__(self, members, *, gold: Optional[str] = None,
                 cost_scales: Optional[Dict[str, float]] = None):
        pairs = list(members.items()) if isinstance(members, dict) \
            else [(n, b) for n, b in members]
        if not pairs:
            raise ValueError("PoolBackend needs at least one member engine")
        names = [n for n, _ in pairs]
        dups = sorted({n for n in names if names.count(n) > 1})
        if dups:
            raise ValueError(f"duplicate engine name(s) in pool: {dups}")
        self.members: Dict[str, Backend] = {n: as_backend(b)
                                            for n, b in pairs}
        self.gold_engine = gold if gold is not None else names[0]
        if self.gold_engine not in self.members:
            raise ValueError(
                f"gold engine {self.gold_engine!r} is not a pool member "
                f"(engines: {sorted(self.members)})")
        self.cost_scales = {n: float((cost_scales or {}).get(n, 1.0))
                            for n in names}
        super().__init__(self._union)

    def _union(self, op) -> List[PhysicalOperator]:
        ops: List[PhysicalOperator] = []
        for name, member in self.members.items():
            for phys in member.candidates(op):
                if getattr(phys, "is_gold", False):
                    continue        # one gold only: the gold engine's
                ops.append(EngineTaggedOperator(name, phys,
                                                self.cost_scales[name]))
        # cost order (stable: declaration order breaks ties), gold LAST
        ops.sort(key=lambda t: t.cost_model())
        golds = [p for p in self.members[self.gold_engine].candidates(op)
                 if getattr(p, "is_gold", False)]
        if not golds:
            raise ValueError(f"gold engine {self.gold_engine!r} offers no "
                             f"gold operator for {op}")
        ops.append(EngineTaggedOperator(self.gold_engine, golds[-1],
                                        self.cost_scales[self.gold_engine]))
        return ops

    def resolve(self, op, op_name: str) -> PhysicalOperator:
        try:
            return super().resolve(op, op_name)
        except KeyError:
            engine, sep, _ = op_name.partition("/")
            if sep and engine not in self.members:
                # surfaced at resolve time, on the submitting thread —
                # never deep inside a dispatched flush
                raise ValueError(
                    f"operator {op_name!r} references unknown engine "
                    f"{engine!r}; pool engines: {sorted(self.members)}"
                ) from None
            raise

    def member(self, engine: str) -> Backend:
        """The named member backend."""
        try:
            return self.members[engine]
        except KeyError:
            raise ValueError(f"unknown engine {engine!r}; pool engines: "
                             f"{sorted(self.members)}") from None

    def kv_bytes_loaded(self) -> int:
        # per-thread sum over members: each member counts only its own
        # store's loads, so a flush (which touches exactly one engine)
        # contributes its delta to exactly one term
        return sum(m.kv_bytes_loaded() for m in self.members.values())

    def transfer_stats(self) -> Tuple[float, int]:
        h2d, donated = 0.0, 0
        for m in self.members.values():
            fn = getattr(m, "transfer_stats", None)
            if fn is not None:
                mh, md = fn()
                h2d += mh
                donated += md
        return (h2d, donated)


def as_backend(registry_or_backend) -> Backend:
    """Adapt a legacy registry callable to the Backend protocol; Backends
    pass through unchanged."""
    if isinstance(registry_or_backend, Backend):
        return registry_or_backend
    if callable(registry_or_backend):
        return OracleBackend(registry_or_backend)
    raise TypeError(f"cannot adapt {type(registry_or_backend)!r} "
                    "to a runtime Backend")
