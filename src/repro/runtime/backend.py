"""Pluggable operator backends for the runtime (paper §5 execution layer).

A Backend answers one question: "score this batch of items under this
physical implementation of a semantic operator". It owns operator
resolution (which physical candidates implement a logical op, gold last)
and batched invocation (`score_filter` / `run_map`), replacing the ad-hoc
`registry(op) -> [PhysicalOperator]` callables that the planner, profiler,
executor and baselines each used to thread around and index separately.

Implementations:

  OracleBackend     — wraps any registry callable (in this repo: the
                      synthetic planted-signal registry from
                      repro.serving.operators.make_registry).
  KVCacheBackend    — the paper's contribution, first-class: operators
                      over precomputed (compressed) KV-cache profiles of a
                      ServingEngine, with KV-bytes telemetry.
  ReferenceBackend  — uncompressed gold only (largest model, ratio 0.0):
                      the quality reference every experiment compares to.

`as_backend` adapts legacy registry callables, so every older entry point
keeps working while routing through the single runtime execution path.
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.core.logical import SemFilter, SemMap
from repro.core.physical import PhysicalOperator


@runtime_checkable
class Backend(Protocol):
    """Batched execution surface for physical operators."""

    name: str

    def candidates(self, op) -> List[PhysicalOperator]:
        """Physical implementations of semantic op, cost order, gold LAST."""
        ...

    def resolve(self, op, op_name: str) -> PhysicalOperator:
        """The named physical implementation of a semantic operator."""
        ...

    def score_filter(self, op: SemFilter, op_name: str,
                     items: Sequence[Any]) -> np.ndarray:
        """Log-odds scores (len(items),) for a SemFilter batch."""
        ...

    def run_map(self, op: SemMap, op_name: str, items: Sequence[Any]
                ) -> Tuple[np.ndarray, np.ndarray]:
        """(values, confidences) each (len(items),) for a SemMap batch."""
        ...

    def kv_bytes_loaded(self) -> int:
        """Monotonic counter of KV-cache bytes materialized so far *by the
        calling thread* (0 for backends that never touch a cache store).
        Thread-scoped so `run_operator`'s before/after deltas stay exact
        when independent flushes overlap on a dispatcher's thread pool —
        a process-global counter would interleave concurrent loads into
        each other's deltas and double-count."""
        ...


class RegistryBackend:
    """Shared machinery: a Backend over a `registry(op) -> [PhysicalOperator]`
    callable. Operator instances are cached per semantic op so repeated
    stages hit the same jit/profile state."""

    name = "registry"

    def __init__(self, registry: Callable):
        self._registry = registry
        self._cache: Dict[Any, List[PhysicalOperator]] = {}
        self._by_name: Dict[Any, PhysicalOperator] = {}

    def candidates(self, op) -> List[PhysicalOperator]:
        if op not in self._cache:
            self._cache[op] = list(self._registry(op))
        return self._cache[op]

    def resolve(self, op, op_name: str) -> PhysicalOperator:
        got = self._by_name.get((op, op_name))
        if got is not None:
            return got
        for phys in self.candidates(op):
            if phys.name == op_name:
                self._by_name[(op, op_name)] = phys
                return phys
        raise KeyError(f"backend {self.name!r} has no operator {op_name!r} "
                       f"for {op}")

    def score_filter(self, op: SemFilter, op_name: str,
                     items: Sequence[Any]) -> np.ndarray:
        phys = self.resolve(op, op_name)
        return np.asarray(phys.run_filter(items, op), np.float32)

    def run_map(self, op: SemMap, op_name: str, items: Sequence[Any]
                ) -> Tuple[np.ndarray, np.ndarray]:
        phys = self.resolve(op, op_name)
        vals, conf = phys.run_map(items, op)
        return np.asarray(vals), np.asarray(conf, np.float32)

    def kv_bytes_loaded(self) -> int:
        # Non-serving backends own no cache store, so they report a flat 0
        # — the StageStats kv_bytes field must not drift with whatever
        # engine-backed operators a registry callable happens to hand out.
        # Serving backends (KVCache / Reference) override this with their
        # engine's store counter.
        return 0


class OracleBackend(RegistryBackend):
    """Backend over the synthetic planted-signal registry (or any other
    registry callable): scores come from whatever operators the registry
    hands out."""

    name = "oracle"


class KVCacheBackend(RegistryBackend):
    """Backend over a ServingEngine's precomputed KV-cache profiles — the
    paper's prefill-skip operators as a first-class runtime backend."""

    name = "kvcache"

    def __init__(self, engine, *, sm: str = "sm", lg: str = "lg",
                 sm_ratios=(0.8, 0.5, 0.0), lg_ratios=(0.8, 0.5, 0.3),
                 include_cheap: bool = True):
        from repro.serving.operators import make_registry
        self.engine = engine
        super().__init__(make_registry(
            engine, sm=sm, lg=lg, sm_ratios=sm_ratios, lg_ratios=lg_ratios,
            include_cheap=include_cheap))

    def kv_bytes_loaded(self) -> int:
        # thread-local counter: a flush runs entirely on one dispatcher
        # thread, so per-call deltas are exact under concurrent dispatch
        return self.engine.store.bytes_loaded_local


class ReferenceBackend(RegistryBackend):
    """Uncompressed gold only: every semantic operator maps to the single
    largest-model, ratio-0.0 operator. Executing any plan through this
    backend reproduces the reference result set."""

    name = "reference"

    def __init__(self, engine, *, lg: str = "lg"):
        from repro.serving.operators import KVCacheLLMOperator
        self.engine = engine

        def gold_registry(op):
            return [KVCacheLLMOperator(engine, lg, 0.0, is_gold=True)]

        super().__init__(gold_registry)

    def kv_bytes_loaded(self) -> int:
        return self.engine.store.bytes_loaded_local


def as_backend(registry_or_backend) -> Backend:
    """Adapt a legacy registry callable to the Backend protocol; Backends
    pass through unchanged."""
    if isinstance(registry_or_backend, Backend):
        return registry_or_backend
    if callable(registry_or_backend):
        return OracleBackend(registry_or_backend)
    raise TypeError(f"cannot adapt {type(registry_or_backend)!r} "
                    "to a runtime Backend")
