"""Streaming cascade executor — the single plan-execution path.

Executes a PhysicalPlan over a corpus in fixed-size partitions: relational
operators first, then the DP-ordered physical stages. Each stage runs
batched on exactly the tuples that (a) survived every other logical filter
so far and (b) are still unsure for its own logical operator; accept /
reject / unsure is the shared jit kernel (runtime.kernel), gold stages
always decide.

Why streaming: the seed executor materialized every stage's batch over the
full dataset at once, so the working set scaled with the corpus. Here the
corpus flows through the cascade partition by partition — per-tuple
decisions are independent, so partitioning is result-invariant — and each
stage keeps a *coalescing buffer*: survivors from several partitions
accumulate until at least ``coalesce`` tuples are pending (or input is
exhausted), then flush as one batch. Cross-stage batch coalescing keeps
late cascade stages (which see few survivors per partition) running at
engine-friendly batch sizes instead of degenerating to tiny calls.

Stage flushes are independent batch calls, so *where* they run is
pluggable (runtime/dispatch.py): inline on the calling thread, overlapped
on a thread pool, or — at the partition-loop level — scattered across
corpus shards whose bool decision arrays merge at the end. The executor
owns all scheduling state; dispatchers only run the pure batch -> scores
operator call, and completions are applied in strict submission order, so
every dispatcher produces identical per-tuple decisions.

Every stage flush is timed and counted into per-stage StageStats — wall
time, tuple counts, LLM calls, KV-cache bytes touched — the uniform
telemetry the benchmarks record. All StageStats counters are *exact*
under every dispatcher: KV bytes come from thread-scoped counters (a
flush runs entirely on one dispatcher thread), so overlapping flushes
cannot double-count each other's loads. The final RuntimeResult reports
both ``runtime_s`` (the sum of measured operator time across all flushes
— total work) and ``wall_s`` (elapsed wall clock — what a caller actually
waited); under a parallel dispatcher wall_s < runtime_s is precisely the
overlap speedup, which a single summed number used to hide.

Two consumption modes share one implementation: ``run_plan`` returns the
final RuntimeResult, and ``iter_plan`` is a generator that additionally
yields a PartitionResult the moment every tuple of a partition has fully
cleared the cascade — decisions for a partition are final as soon as its
tuples have passed (or been skipped by) every stage, which under
coalescing can happen well before later partitions execute. That is the
incremental-delivery path the api layer's ``SemFrame.stream()`` exposes.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Deque, Dict, Generator, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.logical import Query, SemFilter, SemMap, SemTopK
from repro.core.physical import PhysicalPlan, PhysicalPlanStage
from repro.runtime.backend import Backend, as_backend
from repro.runtime.dispatch import (DEFAULT_COALESCE, FlushTask,
                                    InlineDispatcher, resolve_dispatcher)
from repro.runtime.kernel import decide, gold_decide


@dataclass
class StageStats:
    """Per-stage execution telemetry, aggregated over all partition
    flushes of that stage."""
    op_name: str
    logical_idx: int
    stage: int                 # position within its logical op's cascade
    wall_s: float = 0.0        # measured operator wall time
    n_tuples: int = 0          # tuples this stage scored
    n_llm_calls: int = 0       # tuples scored by LLM-backed operators
    kv_bytes: int = 0          # KV-cache bytes of the scored tuples'
    #                            profiles (exact + schedule-invariant:
    #                            backends count per calling thread and
    #                            per requested tuple, so neither flush
    #                            overlap nor shape-bucket padding can
    #                            distort the counter)
    n_batches: int = 0         # flushes (coalesced batches) executed
    engine: str = ""           # owning engine of the stage's physical
    #                            operator ("" for single-engine sessions);
    #                            a stage runs on exactly one engine, so
    #                            grouping stage rows by this field yields
    #                            exact per-engine cost / KV-bytes totals
    h2d_overlap_s: float = 0.0  # H2D transfer time hidden behind decode
    #                            compute by the engine's async prefetch —
    #                            time that WOULD have serialized with
    #                            wall_s but did not (counted per flush on
    #                            the dispatching thread, like kv_bytes)
    donated_bytes: int = 0     # bytes of consumed KV cache buffers the
    #                            jitted decode donated back to XLA
    #                            (donate_argnums) instead of holding live
    shared_batches: int = 0    # flushes of this stage that executed as
    #                            part of a merged cross-query engine call
    #                            (scheduler coalescing) — 0 for solo runs
    shared_width: int = 0      # total tuples of those merged calls (all
    #                            participating queries' segments), so
    #                            shared_width / shared_batches is the
    #                            mean coalesced batch this query rode in

    @property
    def mean_batch(self) -> float:
        """Mean coalesced flush size — the batch size the cost model's
        CostCurve amortizes fixed per-call overhead over."""
        return self.n_tuples / max(self.n_batches, 1)

    def add_flush(self, out: "_OperatorOutcome", n_scored: int) -> None:
        """Account one completed flush of `n_scored` tuples."""
        self.wall_s += out.wall_s
        self.n_tuples += n_scored
        self.n_batches += 1
        self.kv_bytes += out.kv_bytes
        self.h2d_overlap_s += out.h2d_overlap_s
        self.donated_bytes += out.donated_bytes
        if out.merged_queries > 1:
            self.shared_batches += 1
            self.shared_width += out.merged_width
        if out.uses_llm:
            self.n_llm_calls += n_scored

    def merge(self, other: "StageStats") -> None:
        """Fold another stats row for the same stage into this one — the
        single counter-summation used by shard merging and the stream's
        live telemetry, so a new counter field cannot be summed in one
        place and silently dropped in another."""
        self.wall_s += other.wall_s
        self.n_tuples += other.n_tuples
        self.n_llm_calls += other.n_llm_calls
        self.kv_bytes += other.kv_bytes
        self.n_batches += other.n_batches
        self.h2d_overlap_s += other.h2d_overlap_s
        self.donated_bytes += other.donated_bytes
        self.shared_batches += other.shared_batches
        self.shared_width += other.shared_width

    def copy(self) -> "StageStats":
        return StageStats(self.op_name, self.logical_idx, self.stage,
                          self.wall_s, self.n_tuples, self.n_llm_calls,
                          self.kv_bytes, self.n_batches, self.engine,
                          self.h2d_overlap_s, self.donated_bytes,
                          self.shared_batches, self.shared_width)

    def as_dict(self) -> Dict[str, Any]:
        return {"op_name": self.op_name, "logical_idx": self.logical_idx,
                "stage": self.stage, "engine": self.engine,
                "wall_s": self.wall_s,
                "n_tuples": self.n_tuples, "n_llm_calls": self.n_llm_calls,
                "kv_bytes": self.kv_bytes, "n_batches": self.n_batches,
                "h2d_overlap_s": self.h2d_overlap_s,
                "donated_bytes": self.donated_bytes,
                "shared_batches": self.shared_batches,
                "shared_width": self.shared_width,
                "mean_batch": round(self.mean_batch, 2)}


@dataclass
class RuntimeResult:
    """Result of executing a plan through the streaming runtime.

    Two time fields, deliberately distinct: ``runtime_s`` sums measured
    operator wall time over every flush (total work done — invariant
    across dispatchers up to timing noise), while ``wall_s`` is the
    elapsed wall clock of the execution itself, including scheduling.
    Time the ``iter_plan`` generator spends *suspended at a yield* (the
    consumer holding a partition) is excluded — wall_s measures the
    engine, not the caller's loop body, so ``.stream()`` and
    ``.execute()`` of the same query report comparable numbers. Under a
    parallel dispatcher ``wall_s < runtime_s``; their ratio is the
    realized overlap speedup.
    """
    accepted: np.ndarray                  # (N,) bool — in the result set
    map_values: Dict[int, np.ndarray]     # logical idx -> values (N,)
    runtime_s: float                      # sum of measured operator time
    stage_stats: List[StageStats]         # plan order, executed stages only
    n_llm_tuples: int                     # tuples processed by LLM ops
    n_partitions: int = 1
    dispatcher: str = "inline"            # dispatch layer that executed it
    n_workers: int = 1                    # its concurrency (1 = serial)
    wall_s: float = 0.0                   # elapsed wall clock, end to end
    plan: Optional[PhysicalPlan] = None   # the plan that produced this
    #                                       result — EXPLAIN ANALYZE must
    #                                       pair measured stats with the
    #                                       plan that actually executed,
    #                                       never a re-derived one
    partition_size: Optional[int] = None  # effective ingest step actually
    #                                       used (None: whole corpus)
    coalesce: Optional[int] = None        # effective flush threshold
    #                                       actually used
    # SemTopK deferred-cut export (sharded execution only): when a shard
    # runs with the rank cut deferred, it reports per-pipeline raw gold
    # ranking scores (NaN = never gold-scored) and the candidacy mask;
    # the shard merger concatenates them and applies ONE global cut, so
    # no shard ever cuts locally. None on every normally-cut result.
    topk_scores: Optional[Dict[int, np.ndarray]] = None
    topk_cand: Optional[Dict[int, np.ndarray]] = None
    # wire telemetry of the run's remote engine members (calls, retries,
    # fallbacks, rtt percentiles, bytes on wire — see
    # repro.remote.client.remote_run_info). None when the session has no
    # remote members or the run made no wire calls.
    remote: Optional[Dict[str, Any]] = None

    @property
    def stage_times(self) -> List[Tuple[str, float, int]]:
        """Seed-executor-shaped view: (op_name, seconds, n_tuples)."""
        return [(s.op_name, s.wall_s, s.n_tuples) for s in self.stage_stats]


@dataclass
class PartitionResult:
    """Finalized decisions for one contiguous corpus slice ``[lo, hi)``,
    emitted by ``iter_plan`` as soon as every tuple in the slice has
    cleared the whole cascade. Concatenating the slices of all emitted
    partitions (in order) reproduces the final RuntimeResult's
    ``accepted`` / ``map_values`` exactly.

    ``stage_stats`` carries the per-stage telemetry *delta* accounted
    since the previous partition was emitted (stages with no activity in
    the window are omitted; when several partitions settle at the same
    instant the first carries the whole window and the rest are empty).
    Summing the deltas of every emitted partition reproduces the final
    RuntimeResult.stage_stats exactly — integer counters bit-for-bit,
    float wall times up to summation order — so a streaming consumer can
    maintain live, truthful progress telemetry at zero extra cost. Under
    a sharding dispatcher each partition is one corpus shard and its
    stage_stats are that shard's full per-stage stats."""
    index: int                            # partition ordinal, corpus order
    lo: int                               # global start index (inclusive)
    hi: int                               # global stop index (exclusive)
    accepted: np.ndarray                  # (hi-lo,) bool — in the result set
    map_values: Dict[int, np.ndarray]     # logical idx -> values (hi-lo,);
    #                                       one entry per SemMap in the query
    #                                       (uncommitted tuples hold 0)
    stage_stats: List[StageStats] = field(default_factory=list)
    wall_s: float = 0.0                   # streaming dispatch: engine
    #                                       time elapsed since the
    #                                       previous emission (first:
    #                                       since start; consumer hold at
    #                                       yields excluded) — deltas sum
    #                                       to <= the run's wall_s.
    #                                       Sharding dispatch: the shard's
    #                                       own elapsed execution; shards
    #                                       overlap, so these do NOT sum
    #                                       to elapsed time (they sum to
    #                                       ~n_workers x it) — use the
    #                                       final RuntimeResult.wall_s
    #                                       for end-to-end elapsed

    def __len__(self) -> int:
        return self.hi - self.lo


@dataclass
class _OperatorOutcome:
    scores: np.ndarray
    values: Optional[np.ndarray]
    wall_s: float
    kv_bytes: int
    uses_llm: bool
    h2d_overlap_s: float = 0.0
    donated_bytes: int = 0
    # cross-query coalescing provenance (scheduler FlushHub): when this
    # outcome is one query's slice of a merged engine call, merged_width
    # is the merged call's total tuple count and merged_queries how many
    # distinct queries rode in it. Solo flushes keep (0, 1).
    merged_width: int = 0
    merged_queries: int = 1


def run_operator(backend: Backend, op, op_name: str,
                 items: Sequence[Any]) -> _OperatorOutcome:
    """Invoke one physical operator on one batch, with uniform telemetry.

    This is the only place in the tree that calls into a backend's
    score_filter / run_map — the profiler and the streaming executor both
    batch through here, so cost and KV-bytes accounting are identical in
    planning and execution.
    """
    phys = backend.resolve(op, op_name)
    kv0 = backend.kv_bytes_loaded()
    # transfer telemetry is optional on the Backend protocol: serving
    # backends expose (h2d_overlap_s, donated_bytes) per calling thread,
    # oracle/custom backends simply have no transfers to report
    xfer = getattr(backend, "transfer_stats", None)
    x0 = xfer() if xfer is not None else (0.0, 0)
    t0 = time.perf_counter()
    if isinstance(op, SemMap):
        values, scores = backend.run_map(op, op_name, items)
    else:
        # filter-like: SemFilter, SemTopK (scored like a filter, accepted
        # by rank cut) and SemJoin (pair-scoring) all return log-odds
        scores = backend.score_filter(op, op_name, items)
        values = None
    wall = time.perf_counter() - t0
    x1 = xfer() if xfer is not None else (0.0, 0)
    return _OperatorOutcome(
        scores=scores, values=values, wall_s=wall,
        kv_bytes=backend.kv_bytes_loaded() - kv0,
        uses_llm=bool(getattr(phys, "uses_llm", True)),
        h2d_overlap_s=x1[0] - x0[0], donated_bytes=x1[1] - x0[1])


class _CascadeState:
    """Per-tuple decision state over the full corpus (bool arrays only —
    O(N) bits, never item payloads, so it stays tiny even when the items
    themselves would not fit in memory)."""

    def __init__(self, n_items: int, sem_ops: Sequence[Any],
                 post_rels: Sequence[Tuple[Any, Optional[int]]] = (),
                 items: Optional[Sequence[Any]] = None):
        self.n_logical = len(sem_ops)
        self.sem_ops = sem_ops
        self.alive = np.ones(n_items, bool)
        self.accepted = {li: np.zeros(n_items, bool)
                         for li in range(self.n_logical)}
        self.rejected = {li: np.zeros(n_items, bool)
                         for li in range(self.n_logical)}
        self.unsure = {li: np.zeros(n_items, bool)
                       for li in range(self.n_logical)}
        self.map_values: Dict[int, np.ndarray] = {}
        self.n_items = n_items
        # pinned post-filters the checked pushdown could not move (see
        # PhysicalPlan.post_relational): value predicates (producer map
        # index) gate candidacy, row predicates (None) filter the result
        self.post_rels = list(post_rels)
        self.items = items
        # SemTopK: the gold stage *records* scores instead of deciding;
        # admission is the global rank cut applied at finalize (NaN =
        # never gold-scored, e.g. early-terminated by a reject stage)
        self.topk_scores: Dict[int, np.ndarray] = {
            li: np.full(n_items, np.nan)
            for li, op in enumerate(sem_ops) if isinstance(op, SemTopK)}

    def admit(self, idx: np.ndarray, alive: np.ndarray):
        """Register a partition: relational survivors become unsure
        everywhere (eligible for every cascade)."""
        self.alive[idx] = alive
        for li in range(self.n_logical):
            self.unsure[li][idx[alive]] = True

    def eligible(self, st: PhysicalPlanStage, idx: np.ndarray) -> np.ndarray:
        """Of tuples `idx`, which must stage `st` score: still unsure for
        its own logical op and not rejected by any other logical filter."""
        mask = self.unsure[st.logical_idx][idx]
        for lj in range(self.n_logical):
            if lj != st.logical_idx and not isinstance(self.sem_ops[lj],
                                                       SemMap):
                mask &= ~self.rejected[lj][idx]
        return mask

    def apply(self, st: PhysicalPlanStage, idx: np.ndarray,
              out: _OperatorOutcome):
        li = st.logical_idx
        if st.is_gold and li in self.topk_scores:
            # top-k gold: record ranking scores, settle the tuples; the
            # accept decision is the global rank cut at finalize_topk
            self.topk_scores[li][idx] = out.scores
            self.unsure[li][idx] = False
            return
        if st.is_gold:
            acc, rej = gold_decide(out.scores, st.is_map)
        else:
            acc, rej, _ = decide(out.scores, st.thr_hi, st.thr_lo, st.is_map)
        if st.is_map:
            if li not in self.map_values:
                self.map_values[li] = np.zeros(self.n_items, object)
            commit = acc | st.is_gold
            commit_idx = idx[commit]
            self.map_values[li][commit_idx] = out.values[commit]
            self.unsure[li][commit_idx] = False
        else:
            self.accepted[li][idx[acc]] = True
            self.rejected[li][idx[rej]] = True
            self.unsure[li][idx[acc]] = False
            self.unsure[li][idx[rej]] = False

    def _value_rel_mask(self, lo: int, hi: int) -> np.ndarray:
        """Pinned predicates over extracted map values, evaluated on the
        committed values of slice [lo, hi). Uncommitted tuples hold 0,
        which never matches — they are rejected elsewhere anyway."""
        m = np.ones(hi - lo, bool)
        for rel, mli in self.post_rels:
            if mli is None:
                continue
            vals = self.map_values.get(mli)
            for t in range(hi - lo):
                v = vals[lo + t] if vals is not None else 0
                if not rel.apply({rel.column: v}):
                    m[t] = False
        return m

    def _row_rel_mask(self, lo: int, hi: int) -> np.ndarray:
        """Pinned structured-row predicates (behind a SemTopK/SemAgg
        barrier): filter the *result* — after the rank cut, never before
        (filtering candidacy would be a different query)."""
        m = np.ones(hi - lo, bool)
        rels = [rel for rel, mli in self.post_rels if mli is None]
        if not rels or self.items is None:
            return m
        for t in range(hi - lo):
            row = getattr(self.items[lo + t], "row", {}) or {}
            if not all(rel.apply(row) for rel in rels):
                m[t] = False
        return m

    def topk_candidates(self, li: int) -> np.ndarray:
        """Rank-cut candidacy for SemTopK pipeline `li`: gold-scored
        (not early-terminated), admitted by every other non-top-k filter,
        and passing any pinned value predicates. Schedule-invariant:
        whether a tuple got gold-scored before or after another filter
        rejected it cannot change membership, because the other filter's
        accept is required anyway."""
        cand = self.alive & ~np.isnan(self.topk_scores[li])
        for lj, op in enumerate(self.sem_ops):
            if lj == li or isinstance(op, (SemMap, SemTopK)):
                continue
            cand &= self.accepted[lj]
        cand &= self._value_rel_mask(0, self.n_items)
        return cand

    def finalize_topk(self):
        """Apply each SemTopK's global rank cut: the k best gold scores
        among candidates, ties broken by lower corpus index (lexsort) —
        fully deterministic, so every dispatcher cuts identically."""
        for li, scores in self.topk_scores.items():
            cand = self.topk_candidates(li)
            order = np.lexsort((np.arange(self.n_items), -scores))
            chosen = order[cand[order]][:self.sem_ops[li].k]
            self.accepted[li][chosen] = True

    def result_mask(self, ignore_topk: bool = False) -> np.ndarray:
        result = self.alive.copy()
        for li, op in enumerate(self.sem_ops):
            if isinstance(op, SemMap):
                continue            # maps never reject
            if ignore_topk and isinstance(op, SemTopK):
                continue            # deferred cut (sharded merge owns it)
            result &= self.accepted[li]
        result &= self._value_rel_mask(0, self.n_items)
        result &= self._row_rel_mask(0, self.n_items)
        return result

    def partition_result(self, index: int, lo: int, hi: int
                         ) -> PartitionResult:
        """Snapshot the (final) decisions for corpus slice [lo, hi)."""
        accepted = self.alive[lo:hi].copy()
        for li, op in enumerate(self.sem_ops):
            if not isinstance(op, SemMap):
                accepted &= self.accepted[li][lo:hi]
        accepted &= self._value_rel_mask(lo, hi)
        accepted &= self._row_rel_mask(lo, hi)
        map_values = {}
        for li, op in enumerate(self.sem_ops):
            if isinstance(op, SemMap):
                vals = self.map_values.get(li)
                map_values[li] = vals[lo:hi].copy() if vals is not None \
                    else np.zeros(hi - lo, object)
        return PartitionResult(index, lo, hi, accepted, map_values)


def run_plan(plan: PhysicalPlan, query: Query, items: Sequence[Any],
             backend, *, partition_size: Optional[int] = None,
             coalesce: Optional[int] = None,
             dispatcher=None) -> RuntimeResult:
    """Execute `plan` over `items` through `backend`.

    partition_size — tuples ingested per streaming step (None: whole
        corpus at once, the non-streaming special case).
    coalesce — minimum pending tuples before a stage's buffer flushes
        mid-stream (default: DEFAULT_COALESCE, the flush width the
        planner's batch-aware cost model amortizes fixed per-call costs
        over — keep them in sync when overriding). Buffers always flush
        once ingestion finishes.
    dispatcher — where stage flushes run: a runtime.dispatch Dispatcher,
        a spec string (``inline`` | ``threads[:N]`` | ``sharded[:N]``),
        or None to read the STRETTO_DISPATCHER environment variable.
        Scheduling is deterministic under every dispatcher; accepted /
        map_values are bit-identical whenever per-tuple scores do not
        depend on batch composition (true for the oracle operators by
        construction, and for the serving engine on equal-length corpora
        where batch padding cannot shift reductions — async dispatchers
        regroup flush batches, so a backend whose scores wobble with
        padding could flip a tuple sitting within float noise of a
        threshold).
    """
    return _drain(iter_plan(plan, query, items, backend,
                            partition_size=partition_size,
                            coalesce=coalesce, dispatcher=dispatcher))


def iter_plan(plan: PhysicalPlan, query: Query, items: Sequence[Any],
              backend, *, partition_size: Optional[int] = None,
              coalesce: Optional[int] = None, dispatcher=None
              ) -> Generator[PartitionResult, None, RuntimeResult]:
    """Generator form of ``run_plan``: yields a PartitionResult per
    partition the moment all of its tuples have cleared the cascade, and
    returns the final RuntimeResult as the generator's StopIteration
    value. Execution is identical to ``run_plan`` (same schedule, same
    decisions) — the yields only observe state, never steer it.

    With a flush dispatcher (inline / threads) delivery is genuinely
    incremental: early partitions are emitted while later ones are still
    executing. A sharding dispatcher scatters the partition loop itself,
    so it emits one PartitionResult per corpus shard, after the scatter
    completes.
    """
    backend = as_backend(backend)
    disp, owned = resolve_dispatcher(dispatcher)
    try:
        # sharding dispatchers scatter the partition loop itself (a
        # 1-shard scatter degenerates to one inline streaming pass);
        # flush dispatchers plug into the streaming loop directly
        if hasattr(disp, "map_shards"):
            result = yield from _stream_sharded(plan, query, items, backend,
                                                partition_size, coalesce,
                                                disp)
        else:
            result = yield from _stream_streaming(plan, query, items,
                                                  backend, partition_size,
                                                  coalesce, disp)
        return result
    finally:
        if owned:
            disp.close()


def _drain(gen) -> RuntimeResult:
    """Exhaust an iter_plan generator, returning its RuntimeResult."""
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


def _run_streaming(plan: PhysicalPlan, query: Query, items: Sequence[Any],
                   backend: Backend, partition_size: Optional[int],
                   coalesce: Optional[int], disp,
                   topk_cut: bool = True) -> RuntimeResult:
    return _drain(_stream_streaming(plan, query, items, backend,
                                    partition_size, coalesce, disp,
                                    topk_cut=topk_cut))


def _stream_streaming(plan: PhysicalPlan, query: Query, items: Sequence[Any],
                      backend: Backend, partition_size: Optional[int],
                      coalesce: Optional[int], disp, topk_cut: bool = True
                      ) -> Generator[PartitionResult, None, RuntimeResult]:
    sem_ops = query.semantic_ops
    N = len(items)
    S = len(plan.stages)
    part = max(N, 1) if partition_size is None \
        else max(int(partition_size), 1)
    coalesce = DEFAULT_COALESCE if coalesce is None \
        else max(int(coalesce), 1)

    t_start = time.perf_counter()
    # execution-active wall clock: accumulated across segments between
    # yields, so time the consumer spends holding a partition does not
    # masquerade as engine time
    active_s = 0.0
    seg_t0 = t_start
    state = _CascadeState(N, sem_ops,
                          post_rels=getattr(plan, "post_relational", ()),
                          items=items)
    # SemTopK makes delivery blocking: a tuple's membership depends on
    # the global rank cut, which needs every candidate scored — emission
    # is held back until the drain completes and the cut is applied
    holdback = bool(state.topk_scores)

    def fresh_stats() -> List[StageStats]:
        return [StageStats(st.op_name, st.logical_idx, st.stage,
                           engine=getattr(st, "engine", ""))
                for st in plan.stages]

    stats = fresh_stats()
    # per-partition telemetry window: every completed flush is accounted
    # twice — into the run totals above and into this delta window, which
    # the next emitted partition carries away (and resets). Windows
    # therefore tile the run's stats exactly: summing the stage_stats of
    # all emitted partitions reproduces the final totals.
    window = fresh_stats()
    t_last_emit = t_start
    # incremental delivery: a tuple is *settled* once it has passed (or
    # been skipped by) every stage — no later flush can touch it, so its
    # decisions are final. Partitions are emitted in corpus order as soon
    # as every tuple in them is settled.
    settled = np.zeros(N, bool)
    bounds: List[Tuple[int, int]] = []    # partition [lo, hi) slices
    next_emit = 0

    def take_window() -> Tuple[List[StageStats], float]:
        """Hand the current telemetry window (active stages only + wall
        elapsed since the previous emission) to a settling partition and
        start a fresh one."""
        nonlocal window, t_last_emit
        taken = [sg for sg in window if sg.n_batches > 0]
        window = fresh_stats()
        now = time.perf_counter()
        elapsed, t_last_emit = now - t_last_emit, now
        return taken, elapsed

    def ready_partitions() -> List[PartitionResult]:
        nonlocal next_emit
        if holdback:
            return []
        out = []
        while next_emit < len(bounds):
            lo, hi = bounds[next_emit]
            if not settled[lo:hi].all():
                break
            pr = state.partition_result(next_emit, lo, hi)
            pr.stage_stats, pr.wall_s = take_window()
            out.append(pr)
            next_emit += 1
        return out

    def emit(parts: List[PartitionResult]):
        """Yield settled partitions with the execution clock paused — a
        consumer holding the generator between yields must not inflate
        wall_s or the next partition's telemetry window."""
        nonlocal active_s, seg_t0, t_last_emit
        if not parts:
            return
        paused = time.perf_counter()
        active_s += paused - seg_t0
        for pr in parts:
            yield pr
        resumed = time.perf_counter()
        seg_t0 = resumed
        t_last_emit += resumed - paused
    # pending[s]: global indices that stages < s have fully processed and
    # stage s has not yet looked at (its coalescing buffer). n_pending
    # counts the tuples stage s would actually SCORE — a tuple's
    # eligibility at s is fixed the moment it clears stage s-1 (its own
    # state can only change when it is processed), so counting at enqueue
    # time is safe, and low-survivor stages keep accumulating across
    # partitions instead of flushing tiny batches.
    pending: List[List[np.ndarray]] = [[] for _ in plan.stages]
    n_pending = np.zeros(S, np.int64)
    # in-flight flushes, completed strictly in submission (FIFO) order.
    # Cohorts in flight are disjoint (a tuple lives in exactly one buffer
    # or one flush), so operator calls never race on state; all state
    # mutation happens on this thread at completion.
    inflight: Deque[Tuple[int, np.ndarray, np.ndarray, Any]] = deque()

    def runner(task: FlushTask) -> _OperatorOutcome:
        return run_operator(backend, task.sem_op, task.op_name, task.items)

    def enqueue(s: int, idx: np.ndarray):
        # a cohort with nothing for stage s to score passes straight
        # through — buffering it would stall every downstream stage until
        # drain without coalescing anything
        while s < S and idx.size:
            n_eligible = int(state.eligible(plan.stages[s], idx).sum())
            if n_eligible:
                pending[s].append(idx)
                n_pending[s] += n_eligible
                return
            s += 1
        settled[idx] = True           # cleared the whole cascade: final

    def complete_oldest():
        """Apply the oldest in-flight flush: decisions, stats, downstream
        hand-off. The only place operator results touch executor state."""
        s, idx, run_idx, handle = inflight.popleft()
        out = handle.result()
        st = plan.stages[s]
        state.apply(st, run_idx, out)
        stats[s].add_flush(out, int(run_idx.size))
        window[s].add_flush(out, int(run_idx.size))
        enqueue(s + 1, idx)

    def submit_flush(s: int):
        """Dispatch stage s's buffered cohort; eligibility is settled
        because every tuple in the buffer arrived via a *completed*
        upstream flush (or pass-through over settled state)."""
        idx = np.concatenate(pending[s])
        pending[s].clear()
        n_pending[s] = 0
        st = plan.stages[s]
        mask = state.eligible(st, idx)
        run_idx = idx[mask]
        if not run_idx.size:
            enqueue(s + 1, idx)
            return
        op = sem_ops[st.logical_idx]
        backend.resolve(op, st.op_name)   # warm the op cache on this thread
        batch = [items[i] for i in run_idx]
        handle = disp.submit(
            FlushTask(s, op, st.op_name, batch,
                      engine=getattr(st, "engine", "")), runner)
        inflight.append((s, idx, run_idx, handle))
        while len(inflight) > disp.max_pending:
            complete_oldest()

    def pump():
        """Flush every stage at/above its coalesce threshold; completing a
        windowed flush may refill an earlier stage, so sweep to fixpoint
        (with an inline dispatcher one sweep reproduces the pre-dispatch
        schedule exactly and the second is a no-op)."""
        progressed = True
        while progressed:
            progressed = False
            for s in range(S):
                if n_pending[s] >= coalesce:
                    submit_flush(s)
                    progressed = True

    n_parts = 0
    for start in range(0, max(N, 1), part):
        idx = np.arange(start, min(start + part, N))
        if idx.size == 0:
            break
        n_parts += 1
        bounds.append((start, int(idx[-1]) + 1))
        alive = np.ones(idx.size, bool)
        for rel in plan.relational:
            alive &= np.array([rel.apply(getattr(items[i], "row", {}) or {})
                               for i in idx])
        state.admit(idx, alive)
        settled[idx[~alive]] = True   # relational rejects never enter
        enqueue(0, idx[alive])
        pump()
        yield from emit(ready_partitions())
    # drain: a stage's final flush runs only once nothing upstream —
    # buffered or in flight — can still feed it; otherwise settle the
    # oldest in-flight flush and re-examine
    while inflight or any(pending):
        s = next((j for j in range(S) if pending[j]), None)
        if s is not None and not any(f[0] < s for f in inflight):
            submit_flush(s)
        else:
            complete_oldest()
        yield from emit(ready_partitions())
    if holdback:
        # every tuple is settled: apply (or defer) the rank cut, then
        # release all held partitions at once
        if topk_cut:
            state.finalize_topk()
        holdback = False
    yield from emit(ready_partitions())   # all settled post-drain

    deferred = None if topk_cut or not state.topk_scores else (
        {li: s.copy() for li, s in state.topk_scores.items()},
        {li: state.topk_candidates(li) for li in state.topk_scores})
    executed = [sg for sg in stats if sg.n_batches > 0]
    return RuntimeResult(
        accepted=state.result_mask(ignore_topk=deferred is not None),
        map_values=state.map_values,
        runtime_s=sum(sg.wall_s for sg in executed),
        stage_stats=executed,
        n_llm_tuples=sum(sg.n_llm_calls for sg in executed),
        n_partitions=n_parts,
        dispatcher=disp.name, n_workers=disp.n_workers,
        wall_s=active_s + (time.perf_counter() - seg_t0), plan=plan,
        partition_size=None if partition_size is None else part,
        coalesce=coalesce,
        topk_scores=None if deferred is None else deferred[0],
        topk_cand=None if deferred is None else deferred[1])


def stage_stats_by_engine(stage_stats: Sequence[StageStats]
                          ) -> Dict[str, Dict[str, Any]]:
    """Exact per-engine execution totals: each stage runs on exactly one
    engine, so summing its counters by the engine tag partitions the
    run's totals — per-engine wall_s / n_tuples / n_llm_calls / kv_bytes
    sum back to the whole-run numbers bit-for-bit (integer counters) /
    up to summation order (floats). Single-engine runs report one ""
    bucket."""
    out: Dict[str, Dict[str, Any]] = {}
    for sg in stage_stats:
        d = out.setdefault(sg.engine, {"wall_s": 0.0, "n_tuples": 0,
                                       "n_llm_calls": 0, "kv_bytes": 0,
                                       "n_batches": 0})
        d["wall_s"] += sg.wall_s
        d["n_tuples"] += sg.n_tuples
        d["n_llm_calls"] += sg.n_llm_calls
        d["kv_bytes"] += sg.kv_bytes
        d["n_batches"] += sg.n_batches
    return out


def merge_stage_stats(per_shard: Sequence[Sequence[StageStats]],
                      plan: PhysicalPlan) -> List[StageStats]:
    """Sum per-shard StageStats keyed by (logical_idx, stage, op_name),
    returned in plan order (executed stages only)."""
    merged: Dict[Tuple[int, int, str], StageStats] = {}
    for shard_stats in per_shard:
        for sg in shard_stats:
            key = (sg.logical_idx, sg.stage, sg.op_name)
            m = merged.get(key)
            if m is None:
                merged[key] = sg.copy()
            else:
                m.merge(sg)
    out = []
    for st in plan.stages:
        key = (st.logical_idx, st.stage, st.op_name)
        if key in merged:
            out.append(merged.pop(key))
    return out


def _stream_sharded(plan: PhysicalPlan, query: Query, items: Sequence[Any],
                    backend: Backend, partition_size: Optional[int],
                    coalesce: Optional[int], disp
                    ) -> Generator[PartitionResult, None, RuntimeResult]:
    """Scatter the partition loop across contiguous corpus shards.

    Per-tuple decisions are partition-invariant (the existing streaming
    parity guarantee), so each shard can stream through the full cascade
    independently; only the per-shard bool decision arrays are merged back
    into corpus order and the StageStats summed. A shard is the natural
    unit to place on a jax mesh axis or a separate host process: shards
    fan out on a thread pool over one shared engine, and a dispatcher
    that exposes ``shard_context`` (MeshDispatcher) additionally pins
    each shard's engine state + computation onto its own device slice of
    a jax mesh for the duration of that shard's streaming pass. One
    PartitionResult is emitted per shard once the scatter
    completes (shards finish in parallel, so finer-grained emission would
    not be in corpus order anyway); each carries its shard's full
    per-stage StageStats, so the per-partition deltas still sum to the
    merged final stats exactly.

    ``runtime_s`` sums operator time over every shard (total work), while
    ``wall_s`` is the elapsed scatter wall clock — a K-worker scatter
    with balanced shards reports wall_s ~= runtime_s / K, the parallel
    speedup the summed number cannot show.
    """
    t_start = time.perf_counter()
    active_s = 0.0                # engine time only: the clock pauses
    seg_t0 = t_start              # while the consumer holds a yield
    N = len(items)
    bounds = disp.shard_bounds(N)
    inline = InlineDispatcher()
    sem_ops = query.semantic_ops
    map_lis = [li for li, op in enumerate(sem_ops)
               if isinstance(op, SemMap)]
    topk_lis = [li for li, op in enumerate(sem_ops)
                if isinstance(op, SemTopK)]

    shard_ctx = getattr(disp, "shard_context", None)

    def one_shard(i: int, lo: int, hi: int) -> RuntimeResult:
        # SemTopK: shards must never cut locally — each exports raw gold
        # ranking scores + candidacy, and ONE global cut runs at merge
        cut = not topk_lis
        if shard_ctx is None:
            return _run_streaming(plan, query, items[lo:hi], backend,
                                  partition_size, coalesce, inline,
                                  topk_cut=cut)
        with shard_ctx(i, backend):
            return _run_streaming(plan, query, items[lo:hi], backend,
                                  partition_size, coalesce, inline,
                                  topk_cut=cut)

    shards = disp.map_shards(one_shard, bounds)

    # global rank cut over the merged shards: identical candidacy and
    # deterministic tie-break (lower corpus index) reproduce the solo
    # streaming cut bit-for-bit
    chosen: Dict[int, np.ndarray] = {}
    for li in topk_lis:
        g_scores = np.full(N, np.nan)
        g_cand = np.zeros(N, bool)
        for (lo, hi), rr in zip(bounds, shards):
            g_scores[lo:hi] = rr.topk_scores[li]
            g_cand[lo:hi] = rr.topk_cand[li]
        order = np.lexsort((np.arange(N), -g_scores))
        keep = order[g_cand[order]][:sem_ops[li].k]
        mask = np.zeros(N, bool)
        mask[keep] = True
        chosen[li] = mask

    accepted = np.zeros(N, bool)
    map_values: Dict[int, np.ndarray] = {}
    for pi, ((lo, hi), rr) in enumerate(zip(bounds, shards)):
        acc = rr.accepted
        for li in topk_lis:
            acc = acc & chosen[li][lo:hi]
        accepted[lo:hi] = acc
        for li, vals in rr.map_values.items():
            if li not in map_values:
                map_values[li] = np.zeros(N, object)
            map_values[li][lo:hi] = vals
        pr = PartitionResult(
            pi, lo, hi, acc.copy(),
            {li: (rr.map_values[li].copy() if li in rr.map_values
                  else np.zeros(hi - lo, object)) for li in map_lis},
            stage_stats=rr.stage_stats, wall_s=rr.wall_s)
        active_s += time.perf_counter() - seg_t0
        yield pr
        seg_t0 = time.perf_counter()
    stats = merge_stage_stats([rr.stage_stats for rr in shards], plan)
    return RuntimeResult(
        accepted=accepted,
        map_values=map_values,
        runtime_s=sum(rr.runtime_s for rr in shards),
        stage_stats=stats,
        n_llm_tuples=sum(rr.n_llm_tuples for rr in shards),
        n_partitions=sum(rr.n_partitions for rr in shards),
        dispatcher=disp.name, n_workers=disp.n_workers,
        wall_s=active_s + (time.perf_counter() - seg_t0), plan=plan,
        partition_size=None if partition_size is None
        else max(int(partition_size), 1),
        coalesce=DEFAULT_COALESCE if coalesce is None
        else max(int(coalesce), 1))
