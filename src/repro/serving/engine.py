"""Prefill-skip batched serving engine (paper §5, Fig. 4).

Offline: `build_profiles` prefetches every corpus item through each model
once, compresses the KV cache at each ladder ratio (Expected Attention),
optionally quantizes rungs to int8, and persists the profiles in the
CacheStore.

Online: `run_filter` / `run_map` load a profile's caches for a batch of
items, pad to the max compressed length, *skip prefill entirely*, feed the
operator query tokens through the decode path, and read out answer-token
log-odds ('1' vs '0') or a greedy value token + confidence margin.

The decode path is the Pallas fast path:
  - the attention backend is selectable (`kernels` ctor arg, else the
    STRETTO_KERNELS env var: auto | pallas | interpret | ref);
  - by default the operator query is fed through ONE fused multi-token
    attention dispatch per flush (`decode_multi`) instead of a per-token
    lax.scan (`fused` ctor arg, else STRETTO_FUSED; scan remains the
    fallback for archs with recurrent state);
  - repeated flushes against the same (profile, batch) skip the
    npz-reload + re-pad + H2D copy via a device-resident LRU cache
    bounded by `memory_budget_bytes` (`device_cache` ctor arg, else
    STRETTO_DEVICE_CACHE). Device-cache hits do NOT increment the
    kv_bytes telemetry — it counts real loads only.

Transfers overlap compute (`async_h2d` ctor arg, else STRETTO_ASYNC_H2D):
a multi-batch run_filter/run_map dispatches the decode for batch i and
loads + H2D-copies batch i+1's KV caches *before* forcing batch i's
logits, so the transfer hides behind the accelerator's decode — the
hidden time is counted into the `h2d_overlap_s` telemetry. On the same
flag (and only when the device-resident LRU is off, which would need the
buffers again) the jitted decode donates the consumed cache buffers back
to XLA via donate_argnums, so the next batch's caches can reuse that HBM
instead of peaking at 2x; donated bytes are counted into
`donated_bytes`. Both counters are kept globally and per thread
(`transfer_stats_local`), so the runtime's per-flush StageStats deltas
stay exact under concurrent dispatch.

Multi-device placement: `place_on(device)` pins the calling thread's
flushes — params (device_put once per device, memoized) and decode
computation — onto one device; `default_device` (EngineSpec placement)
does the same engine-wide. The runtime's MeshDispatcher enters
`place_on` per corpus shard to scatter the cascade over a jax mesh.

Batch size is memory-bounded: higher compression -> smaller caches ->
larger batches -> fewer calls (the paper's batching speedup mechanism).
"""
from __future__ import annotations

import contextlib
import math
import os
import threading
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# CPU (and some accelerator) buffers cannot always be donated; jax warns
# per compilation. Donation here is best-effort HBM reuse — a backend
# that cannot honor it silently falls back to copying, which is exactly
# the pre-donation behavior, so the warning is noise in CPU CI runs.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from repro.cache.compression import (QueryStats, calibrate_query_stats,
                                     compress_item_cache, quantize_kv)
from repro.cache.store import CacheStore, Profile
from repro.configs.base import ModelConfig
from repro.kernels import ops as KOPS
from repro.models import (decode_multi, decode_step, init_cache, prefill,
                          supports_fused_decode)

# Engine loads pad the cache length to a multiple of the Pallas block so
# the kernel grid is always legal (S % block_s == 0), whichever backend
# ends up selected. Padded positions are masked exactly, and kv_bytes
# counts pre-padding bytes, so this changes neither results nor telemetry.
KERNEL_BLOCK_S = 128


def _env_flag(name: str, default: bool = True) -> bool:
    v = os.environ.get(name)
    if v is None or v == "":
        return default
    return v not in ("0", "false", "False", "no")


@dataclass
class EngineModel:
    cfg: ModelConfig
    params: Any
    stats: Optional[QueryStats] = None


class ServingEngine:
    """Executes semantic operators over precomputed KV-cache profiles."""

    def __init__(self, store: CacheStore,
                 memory_budget_bytes: float = 2e9,
                 max_batch: int = 128,
                 kernels: Optional[str] = None,
                 fused: Optional[bool] = None,
                 device_cache: Optional[bool] = None,
                 async_h2d: Optional[bool] = None):
        self.store = store
        self.models: Dict[str, EngineModel] = {}
        self.memory_budget = memory_budget_bytes
        self.max_batch = max_batch
        # attention backend: explicit arg > STRETTO_KERNELS env > auto.
        # Validated (and env read) at flush time, not here, so tests can
        # flip the env var between flushes.
        self.kernels = kernels
        self.fused = (_env_flag("STRETTO_FUSED") if fused is None
                      else bool(fused))
        self.device_cache = (_env_flag("STRETTO_DEVICE_CACHE")
                             if device_cache is None else bool(device_cache))
        self.async_h2d = (_env_flag("STRETTO_ASYNC_H2D")
                          if async_h2d is None else bool(async_h2d))
        self._decode_jit: Dict[Tuple[str, bool, str, bool], Any] = {}
        # engine-wide device pin (EngineSpec(device=...)); place_on()
        # overrides it per thread (MeshDispatcher shard placement)
        self.default_device: Optional[Any] = None
        self._placement_tl = threading.local()
        # params placed per device, once: (model_name, device id) ->
        # device_put params pytree
        self._placed_params: Dict[Tuple[str, Any], Any] = {}
        self._placed_lock = threading.Lock()
        # transfer telemetry: H2D time hidden behind decode + donated KV
        # bytes — global totals and per-thread counters (the runtime's
        # StageStats deltas read the thread-local pair, so overlapping
        # flushes never interleave into each other's deltas)
        self.h2d_overlap_s = 0.0
        self.donated_bytes = 0
        self._xfer_lock = threading.Lock()
        self._xfer_tl = threading.local()
        # device-resident profile cache: (profile.tag, ids, headroom) ->
        # (cache pytree on device, nbytes). One lock serializes
        # lookup-or-load so concurrent flushes of the same key load once
        # and total kv_bytes stays schedule-independent.
        self._dev_cache: "OrderedDict[Tuple, Tuple[Dict[str, Any], int]]" \
            = OrderedDict()
        self._dev_bytes = 0
        self._dev_lock = threading.Lock()
        self.dev_cache_hits = 0
        self.dev_cache_misses = 0
        # telemetry for the fused-path acceptance hook: number of
        # attention decode dispatches issued (1 per fused flush,
        # len(query) per scan flush)
        self.attn_dispatches = 0

    # ---------------- placement + transfer telemetry ----------------

    @contextlib.contextmanager
    def place_on(self, device, sharding=None):
        """Pin this thread's flushes onto `device`: params are device_put
        there (once, memoized) and the decode computation runs there.
        `sharding` optionally carries a NamedSharding for the params
        (resolved through the logical-axis rules); default is plain
        single-device placement. Nests/restores like a context var."""
        tl = self._placement_tl
        prev = getattr(tl, "placement", None)
        tl.placement = (device, sharding)
        try:
            yield
        finally:
            tl.placement = prev

    def _placement(self) -> Optional[Tuple[Any, Any]]:
        got = getattr(self._placement_tl, "placement", None)
        if got is not None:
            return got
        if self.default_device is not None:
            return (self.default_device, None)
        return None

    def _device_ctx(self, placement):
        return (contextlib.nullcontext() if placement is None
                else jax.default_device(placement[0]))

    def _params_for(self, em: EngineModel, model_name: str, placement):
        """The model params on the placement's device (device_put once
        per (model, device); unplaced engines use the params as-is)."""
        if placement is None:
            return em.params
        dev, sharding = placement
        key = (model_name, getattr(dev, "id", dev))
        with self._placed_lock:
            got = self._placed_params.get(key)
            if got is None:
                got = jax.device_put(
                    em.params, sharding if sharding is not None else dev)
                self._placed_params[key] = got
            return got

    def _count_xfer(self, h2d_s: float = 0.0, donated: int = 0):
        tl = self._xfer_tl
        tl.h2d_s = getattr(tl, "h2d_s", 0.0) + h2d_s
        tl.donated = getattr(tl, "donated", 0) + donated
        with self._xfer_lock:
            self.h2d_overlap_s += h2d_s
            self.donated_bytes += donated

    def transfer_stats_local(self) -> Tuple[float, int]:
        """Monotonic (h2d_overlap_s, donated_bytes) for the calling
        thread — the runtime's run_operator takes before/after deltas."""
        tl = self._xfer_tl
        return (getattr(tl, "h2d_s", 0.0), getattr(tl, "donated", 0))

    # ---------------- offline phase ----------------

    def register_model(self, name: str, cfg: ModelConfig, params):
        self.models[name] = EngineModel(cfg, params)

    def build_profiles(self, model_name: str, items: Sequence[Any],
                       ratios: Sequence[float], prefill_batch: int = 16,
                       quant_ratios: Sequence[float] = ()):
        """Prefill every item once, compress at every ratio, persist.

        `quant_ratios` adds int8 rungs: the cache is compressed at the
        given ratio and then quantized to int8 with per-token scales
        (halved HBM traffic at decode time), stored under a distinct
        quant profile tag.
        """
        em = self.models[model_name]
        cfg = em.cfg
        has_cache = cfg.attn_kind != "rwkv6"
        if quant_ratios and cfg.attn_kind not in ("gqa", "hymba"):
            raise ValueError(
                f"int8 KV profiles require a k/v cache; "
                f"attn_kind={cfg.attn_kind!r} has none")
        # calibration on the first few items
        if has_cache and em.stats is None:
            calib = _pad_tokens([it.tokens for it in items[:8]])
            em.stats = calibrate_query_stats(em.params, cfg, tokens=calib)
        for start in range(0, len(items), prefill_batch):
            chunk = items[start:start + prefill_batch]
            toks = _pad_tokens([it.tokens for it in chunk])
            lengths = jnp.asarray([len(it.tokens) for it in chunk],
                                  jnp.int32)
            _, cache = prefill(em.params, cfg, tokens=toks,
                               max_len=toks.shape[1], lengths=lengths)
            for bi, it in enumerate(chunk):
                item_cache = jax.tree.map(_take_item(bi), cache)
                n = int(lengths[bi])
                for ratio in ratios:
                    if not has_cache and ratio > 0:
                        continue     # rwkv6: no ladder (DESIGN.md)
                    if has_cache:
                        arrays, new_len = compress_item_cache(
                            cfg, item_cache, em.stats, ratio, n)
                    else:
                        arrays = {k: np.asarray(v[:, 0]) for k, v in
                                  item_cache.items() if k != "lengths"}
                        new_len = n
                    self.store.save(Profile(model_name, ratio), it.item_id,
                                    arrays, new_len)
                for ratio in quant_ratios:
                    arrays, new_len = compress_item_cache(
                        cfg, item_cache, em.stats, ratio, n)
                    self.store.save(Profile(model_name, ratio, quant=True),
                                    it.item_id, quantize_kv(arrays),
                                    new_len)

    # ---------------- online phase ----------------

    def max_batch_for(self, model_name: str, ratio: float,
                      item_id: Optional[int] = None,
                      quant: bool = False) -> int:
        """Memory-bounded max decode batch for a (model, ratio) profile.

        Higher compression -> smaller per-item caches -> larger batches ->
        fewer calls: the paper's batching speedup mechanism (§5), exposed
        so the planner's batch-size-aware cost model can exploit the
        compression -> batch-size link. Per-item bytes come from the
        store's profile metadata (recorded at save time — no shard read
        on the flush path); never exceeds `max_batch`. Falls back to
        `max_batch` when the profile has no stored shards yet.
        """
        profile = Profile(model_name, ratio, quant)
        per_item = self.store.item_nbytes(profile, item_id)
        if per_item is None:
            return self.max_batch
        b = max(1, int(self.memory_budget / max(per_item, 1)))
        return min(b, self.max_batch)

    def _batch_size(self, profile: Profile, item_ids) -> int:
        b = self.max_batch_for(profile.model_name, profile.ratio,
                               item_ids[0], quant=profile.quant)
        return min(b, len(item_ids))

    def _decode_fn(self, model_name: str, fused: bool, backend: str,
                   donate: bool = False):
        key = (model_name, fused, backend, donate)
        if key not in self._decode_jit:
            em = self.models[model_name]

            if fused:
                def run_tokens(params, cache, tokens):
                    """All query tokens in ONE fused attention dispatch."""
                    return decode_multi(params, em.cfg, cache,
                                        tokens=tokens, kernels=backend)
            else:
                def run_tokens(params, cache, tokens):
                    """Feed tokens (B, L) sequentially; return final
                    logits."""
                    def step(cache, tok):
                        logits, cache = decode_step(params, em.cfg, cache,
                                                    tokens=tok[:, None],
                                                    kernels=backend)
                        return cache, logits
                    cache, logits_seq = jax.lax.scan(
                        step, cache, jnp.moveaxis(tokens, 1, 0))
                    return logits_seq[-1], cache

            # donate the consumed cache buffers (arg 1) so XLA reuses
            # their HBM for the next batch instead of peaking at 2x —
            # only ever requested when nothing else holds the buffers
            # (device-resident LRU off, prefetched caches used once)
            self._decode_jit[key] = jax.jit(
                run_tokens, donate_argnums=(1,) if donate else ())
        return self._decode_jit[key]

    def device_cache_clear(self):
        with self._dev_lock:
            self._dev_cache.clear()
            self._dev_bytes = 0

    def warm(self, model_name: str, ratio: float, item_ids: Sequence[int],
             query_len: int = 1, quant: bool = False) -> int:
        """Pre-stage a profile's flush batches in the device-resident LRU
        (scheduler keep_warm tenants): loads each memory-bounded batch of
        `item_ids` through the same `_load_for` path a flush would take,
        so subsequent flushes over the same id runs hit the LRU instead
        of reloading + H2D-copying. `query_len` must match the operator
        query length the flushes will use (semantic filter/map operators
        send a single query token). Returns the number of batches staged;
        a no-op (0) when the device cache is off, the model is unknown,
        or the profile has no stored shards yet — warming is best-effort
        and never a correctness dependency."""
        if not self.device_cache or model_name not in self.models \
                or not item_ids:
            return 0
        em = self.models[model_name]
        profile = Profile(model_name, ratio, quant)
        # a cold-started engine (e.g. a remote worker warmed before its
        # first corpus sync) may hold none — or only some — of the ids
        # for this rung: stage what exists, skip the rest. Probing only
        # the first id would crash the load below whenever the rung is
        # partially built.
        ids = [int(i) for i in item_ids if self.store.has(profile, i)]
        if not ids:
            return 0                     # rung not built (yet): no-op
        bs = self._batch_size(profile, ids)
        query_tokens = [0] * max(int(query_len), 1)
        n = 0
        for s in range(0, len(ids), bs):
            with self._device_ctx(self._placement()):
                self._load_for(em, profile, ids[s:s + bs], query_tokens, bs)
            n += 1
        return n

    def evict(self, model_name: Optional[str] = None,
              ratio: Optional[float] = None,
              quant: bool = False) -> int:
        """Drop device-LRU entries for a profile (scheduler cold-tier
        release): `model_name=None` clears everything, `ratio=None`
        drops every rung of the model, otherwise exactly the
        (model, ratio, quant) profile. Returns entries dropped. Only the
        device-resident copies go — the on-disk profiles are untouched,
        so the next flush simply reloads."""
        with self._dev_lock:
            if model_name is None:
                n = len(self._dev_cache)
                self._dev_cache.clear()
                self._dev_bytes = 0
                return n
            if ratio is None:
                prefix = f"{model_name}__r"
                keys = [k for k in self._dev_cache
                        if k[0].startswith(prefix)]
            else:
                tag = Profile(model_name, ratio, quant).tag
                keys = [k for k in self._dev_cache if k[0] == tag]
            for k in keys:
                _, nbytes = self._dev_cache.pop(k)
                self._dev_bytes -= nbytes
            return len(keys)

    def _load_cached(self, em: EngineModel, profile: Profile,
                     ids: Sequence[int], headroom: int, n_real: int):
        """load_batch through the device-resident LRU (kv_bytes counts
        real loads only — a hit skips the npz-reload + re-pad + H2D copy
        entirely)."""
        if not self.device_cache:
            cache, _ = self.store.load_batch(
                em.cfg, profile, ids, pad_to_multiple=KERNEL_BLOCK_S,
                headroom=headroom, n_real=n_real)
            return cache
        key = (profile.tag, tuple(ids), headroom)
        with self._dev_lock:
            hit = self._dev_cache.get(key)
            if hit is not None:
                self._dev_cache.move_to_end(key)
                self.dev_cache_hits += 1
                return hit[0]
            self.dev_cache_misses += 1
            cache, _ = self.store.load_batch(
                em.cfg, profile, ids, pad_to_multiple=KERNEL_BLOCK_S,
                headroom=headroom, n_real=n_real)
            nbytes = sum(np.asarray(v).nbytes if not hasattr(v, "nbytes")
                         else v.nbytes for v in cache.values())
            self._dev_cache[key] = (cache, nbytes)
            self._dev_bytes += nbytes
            while self._dev_bytes > self.memory_budget \
                    and len(self._dev_cache) > 1:
                _, (_, old_bytes) = self._dev_cache.popitem(last=False)
                self._dev_bytes -= old_bytes
            return cache

    def _load_for(self, em: EngineModel, profile: Profile, ids: List[int],
                  query_tokens: Sequence[int], bs: int):
        """Load (or device-cache-hit) one flush batch's caches — the same
        padded shape `_flush` would load, so a prefetched cache slots in
        as `preloaded` bit-for-bit."""
        # shape-bucketed batches, capped so padding never exceeds the
        # memory-bounded batch size
        pad = max(0, min(_bucket(len(ids)), bs) - len(ids))
        return self._load_cached(em, profile, ids + ids[:1] * pad,
                                 headroom=len(query_tokens) + 2,
                                 n_real=len(ids))

    def _flush(self, em: EngineModel, profile: Profile, ids: List[int],
               query_tokens: Sequence[int], bs: int, preloaded=None):
        """One decode flush: load (or device-cache-hit, or take the
        prefetched) caches, run the query, return logits (len(ids) rows,
        NOT yet forced to host — callers np.asarray when they consume,
        which is what lets the next batch's H2D hide behind the decode)."""
        pad = max(0, min(_bucket(len(ids)), bs) - len(ids))
        fused = self.fused and supports_fused_decode(em.cfg)
        backend = KOPS.resolve_backend(self.kernels)
        # donation needs exclusive ownership of the cache buffers: the
        # device-resident LRU would hand the same buffers to the next hit
        donate = self.async_h2d and not self.device_cache
        fn = self._decode_fn(profile.model_name, fused, backend, donate)
        placement = self._placement()
        with self._device_ctx(placement):
            cache = preloaded if preloaded is not None else \
                self._load_for(em, profile, ids, query_tokens, bs)
            donated = sum(v.nbytes for v in cache.values()
                          if hasattr(v, "nbytes")) if donate else 0
            params = self._params_for(em, profile.model_name, placement)
            q = jnp.asarray([list(query_tokens)] * (len(ids) + pad),
                            jnp.int32)
            logits, _ = fn(params, cache, q)
        if donated:
            self._count_xfer(donated=donated)
        self.attn_dispatches += 1 if fused else len(query_tokens)
        return logits[:len(ids)]

    def _iter_flushes(self, em: EngineModel, profile: Profile,
                      item_ids: Sequence[int], query_tokens: Sequence[int],
                      bs: int):
        """Yield (start, ids, logits) per flush batch. With `async_h2d`
        and more than one batch, batch i+1's caches are loaded (npz read
        + pad + H2D copy) right after batch i's decode is *dispatched*
        and before its logits are forced — the consumer's np.asarray
        blocks on the decode while the transfer proceeds, so the load
        time counted into h2d_overlap_s is hidden from wall_s."""
        batches = [(s, list(item_ids[s:s + bs]))
                   for s in range(0, len(item_ids), bs)]
        prefetch = self.async_h2d and len(batches) > 1
        pre = None
        for bi, (s, ids) in enumerate(batches):
            logits = self._flush(em, profile, ids, query_tokens, bs,
                                 preloaded=pre)
            pre = None
            if prefetch and bi + 1 < len(batches):
                nxt = batches[bi + 1][1]
                t0 = time.perf_counter()
                with self._device_ctx(self._placement()):
                    pre = self._load_for(em, profile, nxt, query_tokens, bs)
                self._count_xfer(h2d_s=time.perf_counter() - t0)
            yield s, ids, logits

    def run_filter(self, model_name: str, profile_ratio: float,
                   item_ids: Sequence[int], query_tokens: Sequence[int],
                   yes_token: int, no_token: int,
                   quant: bool = False) -> np.ndarray:
        """Log-odds per item: logit(yes) - logit(no), prefill skipped."""
        em = self.models[model_name]
        profile = Profile(model_name, profile_ratio, quant)
        out = np.zeros(len(item_ids), np.float32)
        bs = self._batch_size(profile, item_ids)
        for s, ids, logits in self._iter_flushes(em, profile, item_ids,
                                                 query_tokens, bs):
            lo = np.asarray(logits[:, yes_token] - logits[:, no_token],
                            np.float32)
            out[s:s + len(ids)] = lo
        return out

    def run_map(self, model_name: str, profile_ratio: float,
                item_ids: Sequence[int], query_tokens: Sequence[int],
                value_tokens: Sequence[int], quant: bool = False
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Greedy value among `value_tokens` + confidence (logit margin)."""
        em = self.models[model_name]
        profile = Profile(model_name, profile_ratio, quant)
        vals = np.zeros(len(item_ids), np.int64)
        confs = np.zeros(len(item_ids), np.float32)
        bs = self._batch_size(profile, item_ids)
        vt = jnp.asarray(list(value_tokens))
        for s, ids, logits in self._iter_flushes(em, profile, item_ids,
                                                 query_tokens, bs):
            vlogits = logits[:, vt]                        # (B, n_vals)
            top2 = jax.lax.top_k(vlogits, 2)[0]
            vals[s:s + len(ids)] = np.asarray(vt[jnp.argmax(vlogits, -1)])
            confs[s:s + len(ids)] = np.asarray(top2[:, 0] - top2[:, 1])
        return vals, confs


def _bucket(n: int) -> int:
    """Round batch size up to a power of two: bounded jit-shape diversity
    across cascade stages (dispatch overhead, not semantics). Callers cap
    the result at the memory-bounded batch size (see _flush)."""
    b = 1
    while b < n:
        b *= 2
    return b


def _take_item(bi: int):
    def f(leaf):
        if leaf.ndim == 1:           # lengths
            return leaf[bi:bi + 1]
        return leaf[:, bi:bi + 1]    # (L, B, ...)
    return f


def _pad_tokens(token_lists: Sequence[Sequence[int]],
                multiple: int = 16) -> jnp.ndarray:
    n = max(len(t) for t in token_lists)
    n = (n + multiple - 1) // multiple * multiple
    out = np.zeros((len(token_lists), n), np.int32)
    for i, t in enumerate(token_lists):
        out[i, :len(t)] = t
    return jnp.asarray(out)
