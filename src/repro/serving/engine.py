"""Prefill-skip batched serving engine (paper §5, Fig. 4).

Offline: `build_profiles` prefetches every corpus item through each model
once, compresses the KV cache at each ladder ratio (Expected Attention),
and persists the profiles in the CacheStore.

Online: `run_filter` / `run_map` load a profile's caches for a batch of
items, pad to the max compressed length, *skip prefill entirely*, feed the
operator query tokens through decode steps, and read out answer-token
log-odds ('1' vs '0') or a greedy value token + confidence margin.

Batch size is memory-bounded: higher compression -> smaller caches ->
larger batches -> fewer calls (the paper's batching speedup mechanism).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.compression import (QueryStats, calibrate_query_stats,
                                     compress_item_cache)
from repro.cache.store import CacheStore, Profile
from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill


@dataclass
class EngineModel:
    cfg: ModelConfig
    params: Any
    stats: Optional[QueryStats] = None


class ServingEngine:
    """Executes semantic operators over precomputed KV-cache profiles."""

    def __init__(self, store: CacheStore,
                 memory_budget_bytes: float = 2e9,
                 max_batch: int = 128):
        self.store = store
        self.models: Dict[str, EngineModel] = {}
        self.memory_budget = memory_budget_bytes
        self.max_batch = max_batch
        self._decode_jit: Dict[str, Any] = {}

    # ---------------- offline phase ----------------

    def register_model(self, name: str, cfg: ModelConfig, params):
        self.models[name] = EngineModel(cfg, params)

    def build_profiles(self, model_name: str, items: Sequence[Any],
                       ratios: Sequence[float], prefill_batch: int = 16):
        """Prefill every item once, compress at every ratio, persist."""
        em = self.models[model_name]
        cfg = em.cfg
        has_cache = cfg.attn_kind != "rwkv6"
        # calibration on the first few items
        if has_cache and em.stats is None:
            calib = _pad_tokens([it.tokens for it in items[:8]])
            em.stats = calibrate_query_stats(em.params, cfg, tokens=calib)
        for start in range(0, len(items), prefill_batch):
            chunk = items[start:start + prefill_batch]
            toks = _pad_tokens([it.tokens for it in chunk])
            lengths = jnp.asarray([len(it.tokens) for it in chunk],
                                  jnp.int32)
            _, cache = prefill(em.params, cfg, tokens=toks,
                               max_len=toks.shape[1], lengths=lengths)
            for bi, it in enumerate(chunk):
                item_cache = jax.tree.map(_take_item(bi), cache)
                n = int(lengths[bi])
                for ratio in ratios:
                    if not has_cache and ratio > 0:
                        continue     # rwkv6: no ladder (DESIGN.md)
                    if has_cache:
                        arrays, new_len = compress_item_cache(
                            cfg, item_cache, em.stats, ratio, n)
                    else:
                        arrays = {k: np.asarray(v[:, 0]) for k, v in
                                  item_cache.items() if k != "lengths"}
                        new_len = n
                    self.store.save(Profile(model_name, ratio), it.item_id,
                                    arrays, new_len)

    # ---------------- online phase ----------------

    def max_batch_for(self, model_name: str, ratio: float,
                      item_id: Optional[int] = None) -> int:
        """Memory-bounded max decode batch for a (model, ratio) profile.

        Higher compression -> smaller per-item caches -> larger batches ->
        fewer calls: the paper's batching speedup mechanism (§5), exposed
        so the planner's batch-size-aware cost model can exploit the
        compression -> batch-size link. Measures per-item bytes from a
        stored shard (any shard if `item_id` is None); never exceeds
        `max_batch`. Falls back to `max_batch` when the profile has no
        stored shards yet.
        """
        profile = Profile(model_name, ratio)
        if item_id is None:
            item_id = self.store.any_item_id(profile)
            if item_id is None:
                return self.max_batch
        shard = self.store.load(profile, item_id)
        per_item = sum(a.nbytes for k, a in shard.items()
                       if k != "__length__")
        b = max(1, int(self.memory_budget / max(per_item, 1)))
        return min(b, self.max_batch)

    def _batch_size(self, profile: Profile, item_ids) -> int:
        b = self.max_batch_for(profile.model_name, profile.ratio,
                               item_ids[0])
        return min(b, len(item_ids))

    def _decode_fn(self, model_name: str):
        if model_name not in self._decode_jit:
            em = self.models[model_name]

            def run_tokens(params, cache, tokens):
                """Feed tokens (B, L) sequentially; return final logits."""
                def step(cache, tok):
                    logits, cache = decode_step(params, em.cfg, cache,
                                                tokens=tok[:, None])
                    return cache, logits
                cache, logits_seq = jax.lax.scan(
                    step, cache, jnp.moveaxis(tokens, 1, 0))
                return logits_seq[-1], cache

            self._decode_jit[model_name] = jax.jit(run_tokens)
        return self._decode_jit[model_name]

    def run_filter(self, model_name: str, profile_ratio: float,
                   item_ids: Sequence[int], query_tokens: Sequence[int],
                   yes_token: int, no_token: int) -> np.ndarray:
        """Log-odds per item: logit(yes) - logit(no), prefill skipped."""
        em = self.models[model_name]
        profile = Profile(model_name, profile_ratio)
        out = np.zeros(len(item_ids), np.float32)
        bs = self._batch_size(profile, item_ids)
        fn = self._decode_fn(model_name)
        for s in range(0, len(item_ids), bs):
            ids = list(item_ids[s:s + bs])
            pad = _bucket(len(ids)) - len(ids)     # shape-bucketed batches
            cache, _ = self.store.load_batch(
                em.cfg, profile, ids + ids[:1] * pad,
                headroom=len(query_tokens) + 2, n_real=len(ids))
            q = jnp.asarray([list(query_tokens)] * (len(ids) + pad),
                            jnp.int32)
            logits, _ = fn(em.params, cache, q)
            lo = np.asarray(logits[:, yes_token] - logits[:, no_token],
                            np.float32)
            out[s:s + len(ids)] = lo[:len(ids)]
        return out

    def run_map(self, model_name: str, profile_ratio: float,
                item_ids: Sequence[int], query_tokens: Sequence[int],
                value_tokens: Sequence[int]
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Greedy value among `value_tokens` + confidence (logit margin)."""
        em = self.models[model_name]
        profile = Profile(model_name, profile_ratio)
        vals = np.zeros(len(item_ids), np.int64)
        confs = np.zeros(len(item_ids), np.float32)
        bs = self._batch_size(profile, item_ids)
        fn = self._decode_fn(model_name)
        vt = jnp.asarray(list(value_tokens))
        for s in range(0, len(item_ids), bs):
            ids = list(item_ids[s:s + bs])
            pad = _bucket(len(ids)) - len(ids)
            cache, _ = self.store.load_batch(
                em.cfg, profile, ids + ids[:1] * pad,
                headroom=len(query_tokens) + 2, n_real=len(ids))
            q = jnp.asarray([list(query_tokens)] * (len(ids) + pad),
                            jnp.int32)
            logits, _ = fn(em.params, cache, q)
            vlogits = logits[:, vt]                        # (B, n_vals)
            top2 = jax.lax.top_k(vlogits, 2)[0]
            vals[s:s + len(ids)] = np.asarray(
                vt[jnp.argmax(vlogits, -1)])[:len(ids)]
            confs[s:s + len(ids)] = np.asarray(
                top2[:, 0] - top2[:, 1])[:len(ids)]
        return vals, confs


def _bucket(n: int) -> int:
    """Round batch size up to a power of two: bounded jit-shape diversity
    across cascade stages (dispatch overhead, not semantics)."""
    b = 1
    while b < n:
        b *= 2
    return b


def _take_item(bi: int):
    def f(leaf):
        if leaf.ndim == 1:           # lengths
            return leaf[bi:bi + 1]
        return leaf[:, bi:bi + 1]    # (L, B, ...)
    return f


def _pad_tokens(token_lists: Sequence[Sequence[int]],
                multiple: int = 16) -> jnp.ndarray:
    n = max(len(t) for t in token_lists)
    n = (n + multiple - 1) // multiple * multiple
    out = np.zeros((len(token_lists), n), np.int32)
    for i, t in enumerate(token_lists):
        out[i, :len(t)] = t
    return jnp.asarray(out)
