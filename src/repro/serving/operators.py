"""Physical operator implementations against the serving engine.

The registry produced by `make_registry` is what the planner/profiler
consume: for every semantic operator it returns the cascade candidates in
cost order, gold last:

  filters: [embedding filter, sm @ high-comp ... lg @ comp ..., lg @ 0 = gold]
  maps:    [python extractor, sm ladder ..., lg ladder ..., lg @ 0 = gold]
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.logical import SemFilter, SemJoin, SemMap
from repro.core.physical import PhysicalOperator
from repro.data.synthetic import (N_VALUES, TOK_NO, TOK_YES, Item,
                                  filter_query_token, filter_signal_token,
                                  map_query_token, map_signal_token,
                                  value_token)
from repro.serving.engine import ServingEngine


class KVCacheLLMOperator(PhysicalOperator):
    """The paper's contribution: LLM operator over a precomputed
    (compressed) KV-cache profile — prefill skipped."""

    uses_llm = True

    def __init__(self, engine: ServingEngine, model_name: str, ratio: float,
                 is_gold: bool = False, quant: bool = False):
        self.engine = engine
        self.model_name = model_name
        self.ratio = ratio
        self.is_gold = is_gold
        self.quant = quant
        self.name = (f"{model_name}-kv{int(round(ratio * 100)):02d}"
                     + ("i8" if quant else ""))

    def run_filter(self, items: Sequence[Item], op: SemFilter) -> np.ndarray:
        ids = [it.item_id for it in items]
        return self.engine.run_filter(
            self.model_name, self.ratio, ids,
            [filter_query_token(op.task_id)], TOK_YES, TOK_NO,
            quant=self.quant)

    def run_map(self, items: Sequence[Item], op: SemMap):
        ids = [it.item_id for it in items]
        vals, conf = self.engine.run_map(
            self.model_name, self.ratio, ids, [map_query_token(op.task_id)],
            [value_token(v) for v in range(N_VALUES)], quant=self.quant)
        return vals, conf

    def cost_model(self) -> float:
        d = self.engine.models[self.model_name].cfg.d_model
        cost = d ** 2 * (1.0 - 0.6 * self.ratio)
        if self.quant:
            # int8 KV streams ~half the HBM bytes of the bf16/f32 cache;
            # the planner prices the memory-bound decode accordingly
            cost *= 0.55
        return cost

    def max_batch(self):
        """Memory-budgeted batch cap for this profile: the compression ->
        batch-size link the batch-aware cost model feeds to the planner."""
        return self.engine.max_batch_for(self.model_name, self.ratio,
                                         quant=self.quant)


class EmbeddingFilterOperator(PhysicalOperator):
    """BLIP-style embedding similarity filter: cosine between the item's
    mean token embedding and the task's signal direction. No LLM call."""

    uses_llm = False
    is_gold = False

    def __init__(self, engine: ServingEngine, model_name: str):
        self.engine = engine
        self.model_name = model_name
        self.name = f"emb-{model_name}"

    def run_filter(self, items: Sequence[Item], op: SemFilter) -> np.ndarray:
        E = np.asarray(self.engine.models[self.model_name].params["embed"])
        # probe direction: mean difference of the task's yes/no signal
        # token embeddings (a calibrated contrastive probe)
        yes = np.mean([E[filter_signal_token(op.task_id, 1, i)]
                       for i in range(4)], axis=0)
        no = np.mean([E[filter_signal_token(op.task_id, 0, i)]
                      for i in range(4)], axis=0)
        probe = yes - no
        probe /= np.linalg.norm(probe) + 1e-9
        out = np.zeros(len(items), np.float32)
        for i, it in enumerate(items):
            v = E[np.asarray(it.tokens)].mean(0)
            out[i] = 8.0 * float(v @ probe / (np.linalg.norm(v) + 1e-9))
        return out

    def cost_model(self) -> float:
        return 1.0


class PythonMapOperator(PhysicalOperator):
    """Generated-code extractor: counts value-token occurrences. Only knows
    the corpus conventions partially (it cannot see attention-composed
    evidence), so it is decisive on easy items and unsure otherwise."""

    uses_llm = False
    is_gold = False

    def __init__(self):
        self.name = "python-map"

    def run_filter(self, items, op):
        raise NotImplementedError

    def run_map(self, items: Sequence[Item], op: SemMap):
        vals = np.zeros(len(items), np.int64)
        conf = np.zeros(len(items), np.float32)
        for i, it in enumerate(items):
            counts = np.zeros(N_VALUES)
            for t in it.tokens:
                for v in range(N_VALUES):
                    if t == map_signal_token(op.task_id, v):
                        counts[v] += 1
            order = np.argsort(counts)[::-1]
            vals[i] = value_token(int(order[0]))
            conf[i] = float(counts[order[0]] - counts[order[1]])
        return vals, conf

    def cost_model(self) -> float:
        return 0.5


class KVCachePairOperator(PhysicalOperator):
    """Pair-scoring operator for SemJoin: runs the join's extraction task
    over both sides' precomputed KV-cache profiles and scores agreement —
    positive log-odds when both sides express the same latent value, with
    magnitude the mean extraction confidence. Two engine calls per batch
    (left ids, right ids); KV-bytes telemetry counts both sides' cache
    loads, exactly what the pair cascade really streams."""

    uses_llm = True

    def __init__(self, engine: ServingEngine, model_name: str, ratio: float,
                 is_gold: bool = False, quant: bool = False):
        self.engine = engine
        self.model_name = model_name
        self.ratio = ratio
        self.is_gold = is_gold
        self.quant = quant
        self.name = (f"{model_name}-pair{int(round(ratio * 100)):02d}"
                     + ("i8" if quant else ""))

    def _side(self, ids: Sequence[int], op: SemJoin):
        return self.engine.run_map(
            self.model_name, self.ratio, ids, [map_query_token(op.task_id)],
            [value_token(v) for v in range(N_VALUES)], quant=self.quant)

    def run_filter(self, pairs: Sequence[Any], op: SemJoin) -> np.ndarray:
        vl, cl = self._side([p.left.item_id for p in pairs], op)
        vr, cr = self._side([p.right.item_id for p in pairs], op)
        # agreement log-odds: sign from value match, magnitude from the
        # mean margin (floored so the gold boundary at 0 stays two-sided)
        margin = np.maximum(0.5 * (np.asarray(cl, np.float32)
                                   + np.asarray(cr, np.float32)), 1e-3)
        return np.where(np.asarray(vl) == np.asarray(vr),
                        margin, -margin).astype(np.float32)

    def cost_model(self) -> float:
        d = self.engine.models[self.model_name].cfg.d_model
        cost = 2.0 * d ** 2 * (1.0 - 0.6 * self.ratio)   # two side calls
        if self.quant:
            cost *= 0.55
        return cost

    def max_batch(self):
        return self.engine.max_batch_for(self.model_name, self.ratio,
                                         quant=self.quant)


class PythonPairOperator(PhysicalOperator):
    """Generated-code pair matcher: the PythonMapOperator heuristic run on
    both sides, agreement of the top value-token counts. Decisive only on
    easy pairs — the cheap front of the pairing cascade."""

    uses_llm = False
    is_gold = False

    def __init__(self):
        self.name = "python-pair"

    @staticmethod
    def _top(tokens, task_id: int) -> Tuple[int, float]:
        counts = np.zeros(N_VALUES)
        for t in tokens:
            for v in range(N_VALUES):
                if t == map_signal_token(task_id, v):
                    counts[v] += 1
        order = np.argsort(counts)[::-1]
        return int(order[0]), float(counts[order[0]] - counts[order[1]])

    def run_filter(self, pairs: Sequence[Any], op: SemJoin) -> np.ndarray:
        out = np.zeros(len(pairs), np.float32)
        for i, p in enumerate(pairs):
            vl, ml = self._top(p.left.tokens, op.task_id)
            vr, mr = self._top(p.right.tokens, op.task_id)
            margin = 0.5 * (ml + mr)
            out[i] = margin if vl == vr else -margin
        return out

    def cost_model(self) -> float:
        return 1.0


def make_registry(engine: ServingEngine, *, sm: str = "sm", lg: str = "lg",
                  sm_ratios=(0.8, 0.5, 0.0), lg_ratios=(0.8, 0.5, 0.3),
                  sm_int8=(), lg_int8=(),
                  include_cheap: bool = True):
    """Build the semantic-op -> cascade-candidates registry (gold last).

    `sm_int8` / `lg_int8` list compression ratios whose int8-quantized
    profiles exist in the store; each becomes a distinct cascade
    candidate (suffix `i8`) the planner prices at the halved HBM traffic.
    """

    def registry(op) -> List[PhysicalOperator]:
        if isinstance(op, SemJoin):
            pair_ops: List[PhysicalOperator] = []
            if include_cheap:
                pair_ops.append(PythonPairOperator())
            for r in sm_ratios:
                pair_ops.append(KVCachePairOperator(engine, sm, r))
            for r in lg_ratios:
                pair_ops.append(KVCachePairOperator(engine, lg, r))
            pair_ops.append(KVCachePairOperator(engine, lg, 0.0,
                                                is_gold=True))
            return pair_ops
        ops: List[PhysicalOperator] = []
        if isinstance(op, SemFilter):
            if include_cheap:
                ops.append(EmbeddingFilterOperator(engine, sm))
        else:
            if include_cheap:
                ops.append(PythonMapOperator())
        for r in sm_int8:
            ops.append(KVCacheLLMOperator(engine, sm, r, quant=True))
        for r in sm_ratios:
            ops.append(KVCacheLLMOperator(engine, sm, r))
        for r in lg_int8:
            ops.append(KVCacheLLMOperator(engine, lg, r, quant=True))
        for r in lg_ratios:
            ops.append(KVCacheLLMOperator(engine, lg, r))
        ops.append(KVCacheLLMOperator(engine, lg, 0.0, is_gold=True))
        return ops

    return registry
