"""Wire protocol for remote engine members.

Frames are length-prefixed binary messages over a stream socket:

    magic "SW" (2B) | version (1B) | flags (1B) | payload length (4B, BE)

followed by the payload: a msgpack- or JSON-encoded dict (flag bit 1),
optionally zlib-compressed (flag bit 0) when the raw payload crosses
`COMPRESS_MIN` bytes. JSON is the floor every peer must speak — msgpack
is used only when both sides import it (negotiated by the `hello`
handshake), never required, so the protocol works on a bare stdlib.

Numeric fidelity: scores are float32 on both ends. Python's float repr
round-trips exactly through JSON (and msgpack carries IEEE doubles), and
float32 -> float64 -> float32 is lossless, so a remote member's scores
are bit-identical to scoring locally — the parity guarantee the whole
subsystem is pinned on.

Message verbs (all dicts with a "verb" key; responses carry "ok"):

  hello        — protocol/version + encoding negotiation
  sync         — corpus sync: (item_id, tokens) pairs + corpus hash; the
                 worker builds its profiles lazily on the first sync and
                 echoes the hash back (the data handshake)
  catalog      — the worker's operator ladder for one op kind
  score_filter — batched filter scoring by item ids (or pair ids)
  run_map      — batched map extraction by item ids
  warm / evict — device-LRU staging, forwarded to the worker's engine
  health       — liveness + uptime + synced corpus hash
  stats        — the worker's request counters

Scoring responses return the member's telemetry deltas (kv_bytes,
attn_dispatches, h2d_overlap_s, donated_bytes, server_wall_s) so the
client can keep per-engine StageStats exact end to end.
"""
from __future__ import annotations

import hashlib
import json
import struct
import zlib
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.logical import (SemAgg, SemFilter, SemJoin, SemMap, SemTopK)

try:                                    # optional — JSON is the floor
    import msgpack                      # type: ignore
    HAVE_MSGPACK = True
except ImportError:                     # pragma: no cover - env dependent
    msgpack = None
    HAVE_MSGPACK = False

PROTOCOL_VERSION = 1
MAGIC = b"SW"
FLAG_ZLIB = 0x01
FLAG_MSGPACK = 0x02
HEADER = struct.Struct(">2sBBI")
COMPRESS_MIN = 8192                     # compress payloads past this size
MAX_FRAME = 512 * 1024 * 1024           # hard cap against garbage lengths


class ProtocolError(RuntimeError):
    """Malformed frame, version mismatch, or truncated stream."""


# ---------------- frame codec ----------------

def encode_frame(obj: Dict[str, Any], *, encoding: str = "json") -> bytes:
    """One wire frame for `obj`. `encoding` is "json" or "msgpack" (the
    latter requires the msgpack import — negotiate via `hello` first)."""
    flags = 0
    if encoding == "msgpack":
        if not HAVE_MSGPACK:
            raise ProtocolError("msgpack encoding requested but msgpack "
                                "is not installed")
        payload = msgpack.packb(obj, use_bin_type=True)
        flags |= FLAG_MSGPACK
    elif encoding == "json":
        payload = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    else:
        raise ProtocolError(f"unknown frame encoding {encoding!r}")
    if len(payload) >= COMPRESS_MIN:
        packed = zlib.compress(payload, 1)
        if len(packed) < len(payload):
            payload = packed
            flags |= FLAG_ZLIB
    return HEADER.pack(MAGIC, PROTOCOL_VERSION, flags, len(payload)) \
        + payload


def decode_frame(header: bytes, payload: bytes
                 ) -> Tuple[Dict[str, Any], str]:
    """Decode one frame; returns (message, encoding-name)."""
    magic, version, flags, _ = HEADER.unpack(header)
    if magic != MAGIC:
        raise ProtocolError(f"bad frame magic {magic!r}")
    if version != PROTOCOL_VERSION:
        raise ProtocolError(
            f"protocol version mismatch: peer speaks {version}, "
            f"this side speaks {PROTOCOL_VERSION}")
    if flags & FLAG_ZLIB:
        payload = zlib.decompress(payload)
    if flags & FLAG_MSGPACK:
        if not HAVE_MSGPACK:
            raise ProtocolError("received a msgpack frame but msgpack is "
                                "not installed on this side")
        return msgpack.unpackb(payload, raw=False), "msgpack"
    return json.loads(payload.decode("utf-8")), "json"


def _recv_exact(sock, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ProtocolError(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf.extend(chunk)
    return bytes(buf)


def send_msg(sock, obj: Dict[str, Any], *, encoding: str = "json") -> int:
    """Send one frame; returns bytes put on the wire."""
    frame = encode_frame(obj, encoding=encoding)
    sock.sendall(frame)
    return len(frame)


def recv_msg(sock) -> Tuple[Optional[Dict[str, Any]], str, int]:
    """Receive one frame: (message, encoding, wire bytes). Returns
    (None, "", 0) on a clean EOF at a frame boundary."""
    try:
        first = sock.recv(1)
    except ConnectionResetError:
        return None, "", 0
    if not first:
        return None, "", 0
    header = first + _recv_exact(sock, HEADER.size - 1)
    length = HEADER.unpack(header)[3]
    if length > MAX_FRAME:
        raise ProtocolError(f"frame length {length} exceeds cap "
                            f"{MAX_FRAME}")
    payload = _recv_exact(sock, length) if length else b""
    msg, encoding = decode_frame(header, payload)
    return msg, encoding, HEADER.size + length


# ---------------- semantic-operator codec ----------------

def sem_to_wire(op) -> Dict[str, Any]:
    """Serialize a frozen semantic-operator dataclass by kind + fields.
    Subclass checks come first: SemTopK is a SemFilter, SemAgg a SemMap."""
    if isinstance(op, SemTopK):
        return {"kind": "topk", "text": op.text, "task_id": op.task_id,
                "modality": op.modality, "k": op.k}
    if isinstance(op, SemAgg):
        return {"kind": "agg", "text": op.text, "task_id": op.task_id,
                "out_column": op.out_column, "modality": op.modality,
                "group_by": op.group_by, "how": op.how}
    if isinstance(op, SemJoin):
        return {"kind": "join", "text": op.text, "task_id": op.task_id,
                "on": op.on, "modality": op.modality}
    if isinstance(op, SemMap):
        return {"kind": "map", "text": op.text, "task_id": op.task_id,
                "out_column": op.out_column, "modality": op.modality}
    if isinstance(op, SemFilter):
        return {"kind": "filter", "text": op.text, "task_id": op.task_id,
                "modality": op.modality}
    raise ProtocolError(f"cannot serialize semantic op {op!r}")


def sem_from_wire(d: Dict[str, Any]):
    kind = d.get("kind")
    if kind == "topk":
        return SemTopK(d["text"], d["task_id"], modality=d["modality"],
                       k=d["k"])
    if kind == "agg":
        return SemAgg(d["text"], d["task_id"], out_column=d["out_column"],
                      modality=d["modality"], group_by=d["group_by"],
                      how=d["how"])
    if kind == "join":
        return SemJoin(d["text"], d["task_id"], on=d["on"],
                       modality=d["modality"])
    if kind == "map":
        return SemMap(d["text"], d["task_id"], out_column=d["out_column"],
                      modality=d["modality"])
    if kind == "filter":
        return SemFilter(d["text"], d["task_id"], modality=d["modality"])
    raise ProtocolError(f"unknown semantic op kind {kind!r}")


# ---------------- corpus hash (the data handshake) ----------------

def corpus_hash(pairs: Iterable[Tuple[int, Sequence[int]]]) -> str:
    """Order-independent fingerprint of a corpus as (item_id, tokens)
    pairs — platform-stable (fixed-width big-endian packing), so a
    client and a worker on different hosts agree on the data."""
    h = hashlib.sha1()
    for item_id, tokens in sorted((int(i), tuple(int(t) for t in ts))
                                  for i, ts in pairs):
        h.update(struct.pack(">qI", item_id, len(tokens)))
        h.update(struct.pack(f">{len(tokens)}q", *tokens))
    return h.hexdigest()


def items_to_wire(items: Sequence[Any]) -> List[List[Any]]:
    """Corpus items as [item_id, [tokens...]] pairs (the only fields
    operators consume on the worker side)."""
    out = []
    for it in items:
        item_id = getattr(it, "item_id", None)
        tokens = getattr(it, "tokens", None)
        if item_id is None or tokens is None:
            raise ProtocolError(
                "remote corpus sync needs items with `item_id` and "
                f"`tokens`; got {type(it).__name__}")
        out.append([int(item_id), [int(t) for t in tokens]])
    return out
