"""RemoteEngineMember: a PoolBackend member whose operators go over the
wire.

The member satisfies exactly the surface PoolBackend expects of a local
member backend — `candidates` (from the worker's catalog, cost numbers
included, so pool ordering matches the all-local pool bit for bit),
`score_filter` / `run_map` (one wire call per flush), thread-scoped
`kv_bytes_loaded` / `transfer_stats` counters (fed from the worker's
per-call stat deltas, so per-engine StageStats tile exactly) — plus
`warm` / `evict` via a `_RemoteEngineHandle` so scheduler keep-warm
tenants reach across the network too.

Failure handling, layered:

  timeout   — every call carries a deadline (`timeout_s`; corpus sync
              gets `sync_timeout_s`, profile builds are slow).
  retries   — transport-level failures (refused / reset / timeout /
              protocol error) on idempotent calls retry with exponential
              backoff. Scoring is idempotent: the worker holds no
              per-call state.
  breaker   — after `breaker_threshold` consecutive transport failures
              the circuit opens and calls fail fast (no connect attempt)
              until `breaker_reset_s` passes, then one probe call
              half-opens it.
  policy    — `on_unavailable="fallback"` re-routes a failed flush to
              the pool's gold/local member mid-run (gold scores are
              always semantically safe) and records it in telemetry;
              `"fail"` raises RemoteEngineError. Application-level
              errors from the worker (unknown operator, no synced
              corpus) are never retried or masked by fallback — a
              misconfiguration must surface, not degrade.
"""
from __future__ import annotations

import socket
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.logical import SemFilter, SemJoin, SemMap
from repro.remote.protocol import (HAVE_MSGPACK, PROTOCOL_VERSION,
                                   ProtocolError, corpus_hash, items_to_wire,
                                   recv_msg, send_msg, sem_to_wire)
from repro.runtime.backend import RegistryBackend

_TRANSPORT_ERRORS = (OSError, ProtocolError, socket.timeout)


class RemoteEngineError(RuntimeError):
    """A remote engine call failed. `transport` distinguishes network
    unavailability (eligible for fallback) from an application error the
    worker reported (never masked)."""

    def __init__(self, message: str, *, engine: str = "", verb: str = "",
                 transport: bool = False):
        super().__init__(message)
        self.engine = engine
        self.verb = verb
        self.transport = transport


class _RemoteOperator:
    """One catalog entry as a physical operator: runs through the owning
    member's wire calls. Carries the serving attributes (`model_name`,
    `ratio`, `quant`, `.engine`) the scheduler's keep-warm path reads."""

    def __init__(self, member: "RemoteEngineMember", desc: Dict[str, Any]):
        self._member = member
        self.name = desc["name"]
        self.is_gold = bool(desc["is_gold"])
        self.uses_llm = bool(desc["uses_llm"])
        self._cost = float(desc["cost"])
        self._max_batch = desc.get("max_batch")
        self.model_name = desc.get("model")
        self.engine = member.engine_handle
        if desc.get("ratio") is not None:
            self.ratio = float(desc["ratio"])
        self.quant = bool(desc.get("quant", False))

    def run_filter(self, items: Sequence[Any], op) -> np.ndarray:
        return self._member._wire_filter(op, self.name, items)

    def run_map(self, items: Sequence[Any], op):
        return self._member._wire_map(op, self.name, items)

    def cost_model(self) -> float:
        return self._cost

    def max_batch(self) -> Optional[int]:
        return self._max_batch


class _RemoteEngineHandle:
    """The `.engine` surface remote operators expose to the scheduler's
    keep-warm tenant path: warm/evict forwarded over the wire,
    best-effort (a dead worker warms nothing; the query still runs)."""

    def __init__(self, member: "RemoteEngineMember"):
        self._member = member

    def warm(self, model_name: str, ratio: float,
             item_ids: Sequence[int], query_len: int = 1,
             quant: bool = False) -> int:
        resp = self._member._call(
            {"verb": "warm", "model": model_name, "ratio": float(ratio),
             "item_ids": [int(i) for i in item_ids],
             "query_len": int(query_len), "quant": bool(quant)})
        return int(resp.get("batches", 0))

    def evict(self, model_name: Optional[str] = None,
              ratio: Optional[float] = None, quant: bool = False) -> int:
        resp = self._member._call(
            {"verb": "evict", "model": model_name,
             "ratio": float(ratio) if ratio is not None else None,
             "quant": bool(quant)})
        return int(resp.get("dropped", 0))


class RemoteEngineMember(RegistryBackend):
    """A pool member backend served by a RemoteWorker at `address`."""

    def __init__(self, engine_name: str, address: str, *,
                 timeout_s: float = 30.0, sync_timeout_s: float = 600.0,
                 retries: int = 2, backoff_s: float = 0.05,
                 breaker_threshold: int = 3, breaker_reset_s: float = 5.0,
                 on_unavailable: str = "fallback"):
        if on_unavailable not in ("fallback", "fail"):
            raise ValueError(
                f"on_unavailable must be 'fallback' or 'fail', "
                f"got {on_unavailable!r}")
        host, _, port = address.partition(":")
        if not host or not port.isdigit():
            raise ValueError(
                f"remote engine {engine_name!r}: address must be "
                f"'host:port', got {address!r}")
        self.engine_name = engine_name
        self.name = f"remote:{engine_name}"
        self.address = (host, int(port))
        self.timeout_s = float(timeout_s)
        self.sync_timeout_s = float(sync_timeout_s)
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.on_unavailable = on_unavailable
        self.engine_handle = _RemoteEngineHandle(self)
        self._fallback = None          # a local Backend (the gold member)
        self._synced_hash: Optional[str] = None

        self._sock_tl = threading.local()
        # per-flush telemetry, thread-scoped like a local engine's store
        # counters (run_operator deltas them before/after each flush)
        self._flush_tl = threading.local()

        # circuit breaker + global counters
        self._breaker_threshold = int(breaker_threshold)
        self._breaker_reset_s = float(breaker_reset_s)
        self._consecutive_failures = 0
        self._breaker_open_until = 0.0
        self._stats_lock = threading.Lock()
        self._calls = 0
        self._retries = 0
        self._fallbacks = 0
        self._errors = 0
        self._bytes_sent = 0
        self._bytes_recv = 0
        self._rtt_count = 0
        self._rtt_total_s = 0.0
        self._rtt_recent: "deque[float]" = deque(maxlen=8192)
        super().__init__(self._remote_registry)

    # ---------------- catalog -> candidates ----------------

    def _remote_registry(self, op) -> List[_RemoteOperator]:
        if isinstance(op, SemJoin):
            kind = "join"
        elif isinstance(op, SemMap):
            kind = "map"
        elif isinstance(op, SemFilter):
            kind = "filter"
        else:
            raise RemoteEngineError(
                f"remote engine {self.engine_name!r} cannot serve "
                f"{type(op).__name__}", engine=self.engine_name,
                verb="catalog")
        resp = self._call({"verb": "catalog", "kind": kind})
        return [_RemoteOperator(self, d) for d in resp["ops"]]

    # ---------------- transport ----------------

    def _socket(self):
        tl = self._sock_tl
        sock = getattr(tl, "sock", None)
        if sock is not None:
            return sock, tl.encoding
        sock = socket.create_connection(self.address,
                                        timeout=self.timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            send_msg(sock, {"verb": "hello", "version": PROTOCOL_VERSION,
                            "msgpack": HAVE_MSGPACK})
            resp, _, _ = recv_msg(sock)
        except _TRANSPORT_ERRORS:
            sock.close()
            raise
        if resp is None:
            sock.close()
            raise ProtocolError("worker closed the connection during "
                                "the hello handshake")
        if not resp.get("ok"):
            sock.close()
            raise RemoteEngineError(
                f"remote engine {self.engine_name!r} rejected the "
                f"handshake: {resp.get('error')}",
                engine=self.engine_name, verb="hello")
        tl.sock = sock
        tl.encoding = "msgpack" if (HAVE_MSGPACK and resp.get("msgpack")) \
            else "json"
        return sock, tl.encoding

    def _drop_socket(self):
        tl = self._sock_tl
        sock = getattr(tl, "sock", None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass
            tl.sock = None

    def _breaker_check(self):
        now = time.monotonic()
        if self._consecutive_failures >= self._breaker_threshold \
                and now < self._breaker_open_until:
            raise RemoteEngineError(
                f"remote engine {self.engine_name!r}: circuit open after "
                f"{self._consecutive_failures} consecutive failures "
                f"(retries in "
                f"{self._breaker_open_until - now:.1f}s)",
                engine=self.engine_name, verb="breaker", transport=True)

    def _breaker_record(self, ok: bool):
        with self._stats_lock:
            if ok:
                self._consecutive_failures = 0
            else:
                self._consecutive_failures += 1
                if self._consecutive_failures >= self._breaker_threshold:
                    self._breaker_open_until = \
                        time.monotonic() + self._breaker_reset_s

    def _call(self, msg: Dict[str, Any], *, timeout: Optional[float] = None,
              idempotent: bool = True) -> Dict[str, Any]:
        """One request/response round trip with retries + breaker.
        Transport failures raise RemoteEngineError(transport=True);
        worker-reported errors raise transport=False (never retried)."""
        self._breaker_check()
        attempts = (self.retries + 1) if idempotent else 1
        last: Optional[Exception] = None
        for attempt in range(attempts):
            if attempt:
                with self._stats_lock:
                    self._retries += 1
                time.sleep(self.backoff_s * (2 ** (attempt - 1)))
            try:
                sock, encoding = self._socket()
                if timeout is not None:
                    sock.settimeout(timeout)
                t0 = time.perf_counter()
                try:
                    sent = send_msg(sock, msg, encoding=encoding)
                    resp, _, recvd = recv_msg(sock)
                finally:
                    if timeout is not None:
                        sock.settimeout(self.timeout_s)
                if resp is None:
                    raise ProtocolError("worker closed the connection "
                                        "mid-call")
                wall = time.perf_counter() - t0
            except _TRANSPORT_ERRORS as exc:
                self._drop_socket()
                self._breaker_record(ok=False)
                last = exc
                continue
            self._breaker_record(ok=True)
            server_wall = float(
                (resp.get("stats") or {}).get("server_wall_s", 0.0))
            with self._stats_lock:
                self._calls += 1
                self._bytes_sent += sent
                self._bytes_recv += recvd
                self._rtt_count += 1
                rtt = max(wall - server_wall, 0.0)
                self._rtt_total_s += rtt
                self._rtt_recent.append(rtt)
            if not resp.get("ok"):
                with self._stats_lock:
                    self._errors += 1
                raise RemoteEngineError(
                    f"remote engine {self.engine_name!r} "
                    f"{msg.get('verb')} failed: "
                    f"[{resp.get('etype')}] {resp.get('error')}",
                    engine=self.engine_name, verb=str(msg.get("verb")),
                    transport=False)
            return resp
        with self._stats_lock:
            self._errors += 1
        raise RemoteEngineError(
            f"remote engine {self.engine_name!r} unreachable at "
            f"{self.address[0]}:{self.address[1]} "
            f"({attempts} attempt(s)): {last}",
            engine=self.engine_name, verb=str(msg.get("verb")),
            transport=True)

    def close(self):
        self._drop_socket()

    # ---------------- corpus sync ----------------

    def sync(self, items: Sequence[Any]) -> str:
        """Ship the corpus and (lazily, worker-side) build profiles.
        Idempotent by corpus hash — re-syncing the same corpus is one
        cheap round trip."""
        wire = items_to_wire(items)
        want = corpus_hash((i, t) for i, t in wire)
        resp = self._call({"verb": "sync", "items": wire, "hash": want},
                          timeout=self.sync_timeout_s)
        self._synced_hash = resp["hash"]
        return self._synced_hash

    # ---------------- fallback wiring ----------------

    def set_fallback(self, backend) -> None:
        """The local backend (the pool's gold member) that serves a flush
        when this member is unreachable under on_unavailable='fallback'."""
        self._fallback = backend

    def _fallback_scores(self, op, items, exc: RemoteEngineError,
                         mapper: bool):
        if self.on_unavailable != "fallback" or self._fallback is None:
            raise exc
        gold = self._fallback.candidates(op)[-1]
        with self._stats_lock:
            self._fallbacks += 1
        if mapper:
            vals, conf = gold.run_map(items, op)
            return np.asarray(vals), np.asarray(conf, np.float32)
        return np.asarray(gold.run_filter(items, op), np.float32)

    # ---------------- scoring (the member surface) ----------------

    def _batch_msg(self, verb: str, op, op_name: str,
                   items: Sequence[Any]) -> Dict[str, Any]:
        msg: Dict[str, Any] = {"verb": verb, "sem": sem_to_wire(op),
                               "op_name": op_name}
        if items and hasattr(items[0], "left"):     # join pairs
            msg["pair_ids"] = [[int(p.left.item_id), int(p.right.item_id)]
                               for p in items]
        else:
            msg["item_ids"] = [int(it.item_id) for it in items]
        return msg

    def _apply_stats(self, stats: Dict[str, Any]):
        tl = self._flush_tl
        tl.kv_bytes = getattr(tl, "kv_bytes", 0) \
            + int(stats.get("kv_bytes", 0))
        tl.h2d_s = getattr(tl, "h2d_s", 0.0) \
            + float(stats.get("h2d_overlap_s", 0.0))
        tl.donated = getattr(tl, "donated", 0) \
            + int(stats.get("donated_bytes", 0))

    def _wire_filter(self, op, op_name: str,
                     items: Sequence[Any]) -> np.ndarray:
        try:
            resp = self._call(self._batch_msg("score_filter", op, op_name,
                                              items))
        except RemoteEngineError as exc:
            if not exc.transport:
                raise
            return self._fallback_scores(op, items, exc, mapper=False)
        self._apply_stats(resp["stats"])
        return np.asarray(resp["scores"], np.float32)

    def _wire_map(self, op, op_name: str, items: Sequence[Any]):
        try:
            resp = self._call(self._batch_msg("run_map", op, op_name,
                                              items))
        except RemoteEngineError as exc:
            if not exc.transport:
                raise
            return self._fallback_scores(op, items, exc, mapper=True)
        self._apply_stats(resp["stats"])
        return (np.asarray(resp["values"], np.int64),
                np.asarray(resp["confs"], np.float32))

    # score_filter / run_map come from RegistryBackend: resolve the
    # catalog operator by name, which routes back through _wire_*.

    # ---------------- telemetry ----------------

    def kv_bytes_loaded(self) -> int:
        # thread-scoped, like a local engine's store counter: the
        # worker's per-call kv delta is applied on the calling thread,
        # so run_operator's before/after deltas stay exact
        return getattr(self._flush_tl, "kv_bytes", 0)

    def transfer_stats(self) -> Tuple[float, int]:
        tl = self._flush_tl
        return (getattr(tl, "h2d_s", 0.0), getattr(tl, "donated", 0))

    def snapshot(self) -> Dict[str, Any]:
        """Cumulative wire counters (monotonic; diff two snapshots for a
        per-run delta — see remote_run_info)."""
        with self._stats_lock:
            return {
                "engine": self.engine_name,
                "calls": self._calls,
                "retries": self._retries,
                "fallbacks": self._fallbacks,
                "errors": self._errors,
                "bytes_sent": self._bytes_sent,
                "bytes_recv": self._bytes_recv,
                "rtt_count": self._rtt_count,
                "rtt_total_s": self._rtt_total_s,
                "rtt_recent": list(self._rtt_recent),
            }

    def health(self) -> Dict[str, Any]:
        return self._call({"verb": "health"})

    def worker_stats(self) -> Dict[str, Any]:
        return self._call({"verb": "stats"})


# ---------------- module helpers (Session/EXPLAIN integration) --------

def remote_members(backend) -> List[RemoteEngineMember]:
    """Every RemoteEngineMember reachable from `backend` (itself, or a
    pool's members, recursively)."""
    out: List[RemoteEngineMember] = []
    seen = set()

    def walk(b):
        if id(b) in seen:
            return
        seen.add(id(b))
        if isinstance(b, RemoteEngineMember):
            out.append(b)
            return
        members = getattr(b, "members", None)
        if isinstance(members, dict):
            for m in members.values():
                walk(m)

    walk(backend)
    return out


def _percentile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(int(round(q * (len(sorted_vals) - 1))),
              len(sorted_vals) - 1)
    return sorted_vals[idx]


def remote_run_info(before: Dict[str, Dict[str, Any]],
                    after: Dict[str, Dict[str, Any]]
                    ) -> Optional[Dict[str, Any]]:
    """Per-run remote telemetry from two snapshot maps (engine name ->
    RemoteEngineMember.snapshot()). None when no wire activity happened
    during the run."""
    engines: Dict[str, Dict[str, Any]] = {}
    rtts: List[float] = []
    totals = {"calls": 0, "retries": 0, "fallbacks": 0, "errors": 0,
              "bytes": 0}
    for name, a in after.items():
        b = before.get(name, {})
        calls = a["calls"] - b.get("calls", 0)
        retries = a["retries"] - b.get("retries", 0)
        fallbacks = a["fallbacks"] - b.get("fallbacks", 0)
        errors = a["errors"] - b.get("errors", 0)
        nbytes = (a["bytes_sent"] + a["bytes_recv"]
                  - b.get("bytes_sent", 0) - b.get("bytes_recv", 0))
        if not (calls or retries or fallbacks or errors):
            continue
        n_new = a["rtt_count"] - b.get("rtt_count", 0)
        new_rtts = a["rtt_recent"][-n_new:] if n_new > 0 else []
        rtts.extend(new_rtts)
        engines[name] = {"calls": calls, "retries": retries,
                         "fallbacks": fallbacks, "errors": errors,
                         "wire_kb": round(nbytes / 1024.0, 2)}
        totals["calls"] += calls
        totals["retries"] += retries
        totals["fallbacks"] += fallbacks
        totals["errors"] += errors
        totals["bytes"] += nbytes
    if not engines:
        return None
    rtts.sort()
    return {
        "calls": totals["calls"],
        "retries": totals["retries"],
        "fallbacks": totals["fallbacks"],
        "errors": totals["errors"],
        "wire_kb": round(totals["bytes"] / 1024.0, 2),
        "rtt_ms_p50": round(1e3 * _percentile(rtts, 0.50), 3),
        "rtt_ms_p95": round(1e3 * _percentile(rtts, 0.95), 3),
        "engines": engines,
    }
