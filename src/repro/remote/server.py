"""Remote worker: one local ServingEngine behind the wire protocol.

A `RemoteWorker` owns exactly what a session-built engine slot owns — a
CacheStore, a ServingEngine, planted models, and a KVCacheBackend over
them — but serves it to `RemoteEngineMember` clients over a threaded
socket server instead of in-process calls.

Profiles are built lazily on the first corpus `sync`: the client ships
(item_id, tokens) pairs plus a corpus hash, the worker builds its ladder
(exactly the rungs a local engine with the same spec would build, in the
same item order, so calibration and therefore scores match the local
engine bit for bit) and echoes the hash back. A re-sync with the same
hash is a no-op, so reconnects and multiple clients are cheap.

Scoring requests execute under one lock so the telemetry deltas
(thread-local kv-bytes / transfer counters on the handler thread, the
global attn-dispatch counter) attribute to exactly one request — the
client folds them into its own per-flush StageStats, keeping per-engine
telemetry exact across the network boundary.
"""
from __future__ import annotations

import socketserver
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.remote.protocol import (HAVE_MSGPACK, PROTOCOL_VERSION,
                                   ProtocolError, corpus_hash, recv_msg,
                                   send_msg, sem_from_wire)


class _WirePair:
    """A join pair reconstructed from synced corpus items by id — the
    only surface pair operators touch (.left / .right with item_id and
    tokens)."""

    __slots__ = ("left", "right")

    def __init__(self, left, right):
        self.left = left
        self.right = right


class RemoteWorker:
    """One serving engine + backend, exposed verb by verb.

    The constructor mirrors the EngineSpec fields that define an engine's
    identity (model zoo, ladder, limits, seed) — a worker launched with
    the same values as a local spec serves bit-identical scores.
    """

    def __init__(self, name: str = "remote", *,
                 models: Sequence[str] = ("sm", "lg"),
                 sm_ratios: Sequence[float] = (0.8, 0.5, 0.0),
                 lg_ratios: Sequence[float] = (0.8, 0.5, 0.3),
                 include_cheap: bool = True,
                 sm_int8: Sequence[float] = (),
                 lg_int8: Sequence[float] = (),
                 prefill_batch: int = 16,
                 memory_budget_bytes: float = 2e9,
                 max_batch: int = 128,
                 model_seed: int = 1,
                 cache_dir: Optional[str] = None,
                 kernels: Optional[str] = None,
                 verbose: bool = False):
        from repro.cache.store import CacheStore
        from repro.data.synthetic import make_planted_params, planted_config
        from repro.runtime.backend import KVCacheBackend
        from repro.serving.engine import ServingEngine

        self.name = name
        self.models = tuple(models)
        self.sm_ratios = tuple(sm_ratios)
        self.lg_ratios = tuple(lg_ratios)
        self.include_cheap = bool(include_cheap)
        self.sm_int8 = tuple(sm_int8)
        self.lg_int8 = tuple(lg_int8)
        self.prefill_batch = int(prefill_batch)
        self.verbose = bool(verbose)
        self._t0 = time.monotonic()

        if cache_dir is None:
            cache_dir = tempfile.mkdtemp(prefix=f"stretto_remote_{name}_")
        self.engine = ServingEngine(
            CacheStore(cache_dir), memory_budget_bytes=memory_budget_bytes,
            max_batch=max_batch, kernels=kernels)
        for m in self.models:
            mcfg = planted_config(m)
            self.engine.register_model(
                m, mcfg, make_planted_params(mcfg, seed=model_seed))
        self.backend = KVCacheBackend(
            self.engine, sm=self.models[0], lg=self.models[-1],
            sm_ratios=self.sm_ratios, lg_ratios=self.lg_ratios,
            sm_int8=self.sm_int8, lg_int8=self.lg_int8,
            include_cheap=self.include_cheap)

        # synced corpus state (guarded by _sync_lock)
        self._items: Dict[int, Any] = {}
        self._corpus_hash: Optional[str] = None
        self._sync_lock = threading.Lock()
        # scoring runs one request at a time so the engine's counters
        # delta cleanly per request
        self._exec_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.n_requests = 0
        self.n_scores = 0
        self.n_syncs = 0

    # ---------------- verb handlers ----------------

    def handle(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        verb = msg.get("verb")
        with self._stats_lock:
            self.n_requests += 1
        fn = getattr(self, f"_do_{verb}", None)
        if fn is None:
            return {"ok": False, "etype": "ProtocolError",
                    "error": f"unknown verb {verb!r}"}
        try:
            return fn(msg)
        except Exception as exc:                  # -> typed client error
            return {"ok": False, "etype": type(exc).__name__,
                    "error": str(exc)}

    def _do_hello(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        peer = int(msg.get("version", -1))
        if peer != PROTOCOL_VERSION:
            return {"ok": False, "etype": "ProtocolError",
                    "error": f"protocol version mismatch: client speaks "
                             f"{peer}, worker speaks {PROTOCOL_VERSION}"}
        return {"ok": True, "version": PROTOCOL_VERSION, "name": self.name,
                "models": list(self.models),
                "msgpack": HAVE_MSGPACK and bool(msg.get("msgpack")),
                "corpus_hash": self._corpus_hash}

    def _ladder(self) -> List[float]:
        return sorted({0.0, *self.sm_ratios, *self.lg_ratios})

    def _do_sync(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        from repro.data.synthetic import Item
        pairs = msg["items"]
        want = msg.get("hash")
        with self._sync_lock:
            if want is not None and want == self._corpus_hash:
                return {"ok": True, "hash": self._corpus_hash,
                        "built": False, "n_items": len(self._items)}
            items = [Item(int(i), [int(t) for t in toks], {}, {}, {})
                     for i, toks in pairs]
            got = corpus_hash((it.item_id, it.tokens) for it in items)
            if want is not None and got != want:
                return {"ok": False, "etype": "ProtocolError",
                        "error": f"corpus hash mismatch after decode: "
                                 f"client {want}, worker {got}"}
            ladder = self._ladder()
            for m in self.models:
                quant: set = set()
                if m == self.models[0]:
                    quant |= set(self.sm_int8)
                if m == self.models[-1]:
                    quant |= set(self.lg_int8)
                self.engine.build_profiles(
                    m, items, ratios=ladder,
                    prefill_batch=self.prefill_batch,
                    quant_ratios=sorted(quant))
            self._items = {it.item_id: it for it in items}
            self._corpus_hash = got
            with self._stats_lock:
                self.n_syncs += 1
            if self.verbose:
                print(f"[{self.name}] synced {len(items)} items, "
                      f"ladder {ladder}", flush=True)
            return {"ok": True, "hash": got, "built": True,
                    "n_items": len(items)}

    def _do_catalog(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        from repro.core.logical import SemFilter, SemJoin, SemMap
        kind = msg.get("kind")
        rep = {"filter": SemFilter("", 0), "map": SemMap("", 0),
               "join": SemJoin("", 0)}.get(kind)
        if rep is None:
            raise ProtocolError(f"unknown catalog kind {kind!r}")
        descs = []
        for phys in self.backend.candidates(rep):
            mb = getattr(phys, "max_batch", None)
            descs.append({
                "name": phys.name,
                "is_gold": bool(getattr(phys, "is_gold", False)),
                "uses_llm": bool(getattr(phys, "uses_llm", True)),
                "cost": float(phys.cost_model()),
                "max_batch": mb() if callable(mb) else None,
                "model": getattr(phys, "model_name", None),
                "ratio": getattr(phys, "ratio", None),
                "quant": bool(getattr(phys, "quant", False)),
            })
        return {"ok": True, "ops": descs}

    def _materialize(self, msg: Dict[str, Any]) -> List[Any]:
        """The request's item batch from the synced corpus (single ids or
        [left, right] pair ids)."""
        if not self._items:
            raise RuntimeError(
                f"worker {self.name!r} has no synced corpus — "
                f"send `sync` before scoring")
        if msg.get("pair_ids") is not None:
            out: List[Any] = []
            for li, ri in msg["pair_ids"]:
                out.append(_WirePair(self._items[int(li)],
                                     self._items[int(ri)]))
            return out
        return [self._items[int(i)] for i in msg["item_ids"]]

    def _score(self, msg: Dict[str, Any], runner) -> Dict[str, Any]:
        sem = sem_from_wire(msg["sem"])
        items = self._materialize(msg)
        eng = self.engine
        with self._exec_lock:
            kv0 = eng.store.bytes_loaded_local
            h2d0, don0 = eng.transfer_stats_local()
            attn0 = eng.attn_dispatches
            t0 = time.perf_counter()
            payload = runner(sem, msg["op_name"], items)
            wall = time.perf_counter() - t0
            h2d1, don1 = eng.transfer_stats_local()
            stats = {"kv_bytes": eng.store.bytes_loaded_local - kv0,
                     "attn_dispatches": eng.attn_dispatches - attn0,
                     "h2d_overlap_s": h2d1 - h2d0,
                     "donated_bytes": don1 - don0,
                     "server_wall_s": wall}
        with self._stats_lock:
            self.n_scores += 1
        payload.update(ok=True, stats=stats)
        return payload

    def _do_score_filter(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        def run(sem, op_name, items):
            scores = self.backend.score_filter(sem, op_name, items)
            return {"scores": np.asarray(scores, np.float32).tolist()}
        return self._score(msg, run)

    def _do_run_map(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        def run(sem, op_name, items):
            vals, conf = self.backend.run_map(sem, op_name, items)
            return {"values": np.asarray(vals).tolist(),
                    "confs": np.asarray(conf, np.float32).tolist()}
        return self._score(msg, run)

    def _do_warm(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        ids = msg.get("item_ids")
        if ids is None:
            ids = sorted(self._items)
        with self._exec_lock:
            n = self.engine.warm(
                msg["model"], float(msg["ratio"]), [int(i) for i in ids],
                query_len=int(msg.get("query_len", 1)),
                quant=bool(msg.get("quant", False)))
        return {"ok": True, "batches": n}

    def _do_evict(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        ratio = msg.get("ratio")
        with self._exec_lock:
            n = self.engine.evict(
                msg.get("model"),
                float(ratio) if ratio is not None else None,
                quant=bool(msg.get("quant", False)))
        return {"ok": True, "dropped": n}

    def _do_health(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        return {"ok": True, "name": self.name,
                "uptime_s": time.monotonic() - self._t0,
                "corpus_hash": self._corpus_hash,
                "n_items": len(self._items)}

    def _do_stats(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        with self._stats_lock:
            return {"ok": True, "n_requests": self.n_requests,
                    "n_scores": self.n_scores, "n_syncs": self.n_syncs,
                    "attn_dispatches": self.engine.attn_dispatches}


class _Handler(socketserver.BaseRequestHandler):
    """Persistent per-connection frame loop: each request frame gets one
    response frame in the request's encoding; a clean EOF ends the
    connection."""

    def handle(self):
        worker: RemoteWorker = self.server.worker     # type: ignore
        while True:
            try:
                msg, encoding, _ = recv_msg(self.request)
            except (ProtocolError, OSError):
                return
            if msg is None:
                return
            reply = worker.handle(msg)
            try:
                send_msg(self.request, reply, encoding=encoding)
            except OSError:
                return


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


def start_server(worker: RemoteWorker, host: str = "127.0.0.1",
                 port: int = 0) -> Tuple[_Server, threading.Thread, str]:
    """Serve `worker` on (host, port) in a daemon thread; port 0 picks a
    free one. Returns (server, thread, "host:port") — call
    `server.shutdown()` to stop."""
    server = _Server((host, port), _Handler)
    server.worker = worker                            # type: ignore
    bound = server.server_address
    thread = threading.Thread(
        target=server.serve_forever, name=f"remote-{worker.name}",
        daemon=True)
    thread.start()
    return server, thread, f"{bound[0]}:{bound[1]}"
