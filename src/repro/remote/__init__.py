"""Remote engine members: any EngineSpec behind a wire protocol.

The subsystem has three layers plus an integration seam:

  protocol — versioned, length-prefixed msgpack-or-JSON frames for
      score_filter / run_map / warm / evict / health / stats, carrying
      operator identity, a compression tag, item batches, and the
      member's per-call telemetry deltas (kv_bytes, attn_dispatches,
      h2d_overlap_s, donated_bytes) so per-engine StageStats stay exact
      end to end.
  server — a threaded socket server (RemoteWorker) wrapping one local
      ServingEngine + KVCacheBackend, building profiles lazily on the
      first corpus sync, with a corpus-hash handshake so client and
      worker agree on data. `launch/remote_worker.py` is the CLI.
  client — RemoteEngineMember, a pool member whose score_filter /
      run_map go over the wire: per-call timeouts, exponential-backoff
      retries on idempotent calls, a circuit breaker after K consecutive
      failures, and a degradation policy (`on_unavailable="fallback"`
      re-routes failed calls to the gold/local engine mid-run and
      records it; `"fail"` raises RemoteEngineError).

Declared as ``EngineSpec(address="host:port")`` in a SessionConfig, a
remote member routes through PoolBackend transparently, FlushHub merges
cross-query flushes destined for it into one wire call, the planner
prices its operators with the measured per-call RTT folded into
CostCurve.fixed_s at profile time, and EXPLAIN ANALYZE renders a
"remote:" footer (calls, retries, fallbacks, rtt_ms p50/p95, wire
bytes).
"""
from repro.remote.client import (RemoteEngineError, RemoteEngineMember,
                                 remote_members, remote_run_info)
from repro.remote.protocol import PROTOCOL_VERSION, ProtocolError
from repro.remote.server import RemoteWorker, start_server

__all__ = [
    "PROTOCOL_VERSION",
    "ProtocolError",
    "RemoteEngineError",
    "RemoteEngineMember",
    "RemoteWorker",
    "remote_members",
    "remote_run_info",
    "start_server",
]
