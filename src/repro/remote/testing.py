"""Test/CI helpers: spawn a remote worker as a real subprocess.

In-process workers (`start_server` on a thread) cover protocol and
parity tests; the subprocess spawner exists for the robustness tests
that SIGKILL a worker mid-run — an in-process server cannot die without
taking the test down with it.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading
from typing import List, Optional, Sequence, Tuple

import repro


def worker_argv(*, host: str = "127.0.0.1", port: int = 0,
                name: str = "remote", models: Sequence[str] = ("sm", "lg"),
                sm_ratios: Sequence[float] = (0.8, 0.5, 0.0),
                lg_ratios: Sequence[float] = (0.8, 0.5, 0.3),
                include_cheap: bool = True, model_seed: int = 1,
                extra: Sequence[str] = ()) -> List[str]:
    argv = [sys.executable, "-m", "repro.launch.remote_worker",
            "--host", host, "--port", str(port), "--name", name,
            "--models", ",".join(models),
            "--sm-ratios", ",".join(str(r) for r in sm_ratios),
            "--lg-ratios", ",".join(str(r) for r in lg_ratios),
            "--model-seed", str(model_seed)]
    if not include_cheap:
        argv.append("--no-cheap")
    argv.extend(extra)
    return argv


def spawn_worker(timeout_s: float = 120.0, **kwargs
                 ) -> Tuple[subprocess.Popen, str]:
    """Start a worker subprocess and wait for its LISTENING line.
    Returns (proc, "host:port"); kill the proc yourself (it is a real
    process — SIGKILL it to simulate a worker crash)."""
    env = dict(os.environ)
    src_root = os.path.dirname(os.path.dirname(repro.__file__))
    env["PYTHONPATH"] = src_root + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        worker_argv(**kwargs), env=env, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)

    address: Optional[str] = None
    deadline_lines: List[str] = []

    def _fail(reason: str):
        proc.kill()
        raise RuntimeError(
            f"remote worker failed to start ({reason}); output:\n"
            + "".join(deadline_lines))

    timer = threading.Timer(timeout_s, proc.kill)
    timer.start()
    try:
        assert proc.stdout is not None
        for line in proc.stdout:
            deadline_lines.append(line)
            if line.startswith("LISTENING "):
                address = line.split(None, 1)[1].strip()
                break
        if address is None:
            _fail("no LISTENING line before exit/timeout")
    finally:
        timer.cancel()

    # drain the rest of stdout so the worker never blocks on a full pipe
    def _drain(stream):
        try:
            for _ in stream:
                pass
        except ValueError:
            pass

    threading.Thread(target=_drain, args=(proc.stdout,),
                     daemon=True).start()
    return proc, address
