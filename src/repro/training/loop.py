"""Fault-tolerant training loop with straggler mitigation.

Designed for 1000+ node fleets; exercised at reduced scale on CPU:
  - resume-from-latest on start (elastic: any mesh)
  - periodic atomic checkpoints
  - per-step watchdog: a step slower than `straggler_factor` x the EMA step
    time is recorded as a straggler event (on real fleets this triggers
    re-dispatch to a hot spare; here we surface the signal + count)
  - transient-failure retry: a step that raises is retried from the last
    good state up to `max_retries` times (covers preemptions / flaky ICI)
  - optional failure injection for tests
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.training import checkpoint as CKPT


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    keep_last: int = 3
    straggler_factor: float = 3.0
    ema_decay: float = 0.9
    max_retries: int = 2


@dataclass
class LoopReport:
    steps_run: int = 0
    resumed_from: Optional[int] = None
    losses: List[float] = field(default_factory=list)
    straggler_events: int = 0
    retries: int = 0
    ckpts: List[str] = field(default_factory=list)


def run_training(step_fn: Callable, params, opt_state, batches,
                 cfg: LoopConfig,
                 failure_injector: Optional[Callable[[int], None]] = None
                 ) -> tuple:
    """batches: iterable of batch pytrees (len >= total_steps).

    Returns (params, opt_state, LoopReport).
    """
    report = LoopReport()
    start = 0
    if cfg.ckpt_dir:
        latest = CKPT.latest_step(cfg.ckpt_dir)
        if latest is not None:
            (params, opt_state), start = CKPT.restore_checkpoint(
                cfg.ckpt_dir, (params, opt_state))
            report.resumed_from = start

    ema = None
    it = iter(batches)
    # fast-forward the data stream on resume (deterministic pipelines)
    for _ in range(start):
        next(it)

    for step in range(start, cfg.total_steps):
        batch = next(it)
        for attempt in range(cfg.max_retries + 1):
            t0 = time.perf_counter()
            try:
                if failure_injector is not None:
                    failure_injector(step)
                new_params, new_opt, loss = step_fn(params, opt_state, batch)
                jax.block_until_ready(loss)
                break
            except Exception:
                report.retries += 1
                if attempt == cfg.max_retries:
                    raise
        dt = time.perf_counter() - t0
        if ema is not None and dt > cfg.straggler_factor * ema:
            report.straggler_events += 1
        ema = dt if ema is None else cfg.ema_decay * ema + (
            1 - cfg.ema_decay) * dt
        params, opt_state = new_params, new_opt
        report.losses.append(float(loss))
        report.steps_run += 1
        if cfg.ckpt_dir and (step + 1) % cfg.ckpt_every == 0:
            report.ckpts.append(CKPT.save_checkpoint(
                cfg.ckpt_dir, step + 1, (params, opt_state),
                cfg.keep_last))
    return params, opt_state, report
