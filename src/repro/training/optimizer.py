"""Pure-JAX AdamW with optional error-feedback int8 gradient compression.

Optimizer moments are kept in f32 regardless of param dtype; under the
production mesh they are additionally sharded over the `data` axis
(ZeRO-1) — see `opt_state_axes`.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: PyTree                # f32, like params
    v: PyTree                # f32, like params


def adamw_init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 ) -> Tuple[PyTree, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)


def opt_state_axes(params_axes: PyTree) -> "AdamWState":
    """Logical axes for AdamWState (ZeRO-1): the moments replace the
    weights' 'fsdp' logical axis with 'opt_fsdp', so optimizer state can be
    sharded over the data axis even when the weights themselves are
    replicated across it (classic ZeRO-1: no per-layer weight gathers in
    fwd/bwd, sharded Adam update, one params all-gather per step)."""
    def swap(axes):
        return tuple("opt_fsdp" if a == "fsdp" else a for a in axes)

    mapped = jax.tree.map(
        swap, params_axes,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(x, (str, type(None))) for x in v))
    return AdamWState(step=(), m=mapped, v=mapped)


# ---------------------------------------------------------------------------
# error-feedback int8 gradient compression (distributed-optimization trick)
# ---------------------------------------------------------------------------

def compress_grads(grads: PyTree, residual: Optional[PyTree]):
    """Quantize grads to int8 with per-tensor scale + error feedback.

    Returns (q_grads, scales, new_residual). The all-reduce then moves 4x
    fewer bytes; the residual keeps the quantization error for the next step
    (Seide et al. 1-bit SGD generalization).
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads)

    def q(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        qg = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_r = g - qg.astype(jnp.float32) * scale
        return qg, scale, new_r

    flat, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [q(g, r) for g, r in zip(flat, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]),
            treedef.unflatten([o[2] for o in out]))


def decompress_grads(q_grads: PyTree, scales: PyTree) -> PyTree:
    return jax.tree.map(
        lambda qg, s: qg.astype(jnp.float32) * s, q_grads, scales)
