"""Fault-tolerant, mesh-agnostic checkpointing.

- Atomic: write to a temp dir, fsync, rename. A crash mid-write never
  corrupts the latest checkpoint.
- Mesh-agnostic / elastic: arrays are saved as full (unsharded) numpy
  buffers with a manifest (tree structure + shapes + dtypes + step +
  content hashes). Restore takes *any* mesh/sharding: the loader reshards
  on device_put, so a job checkpointed on 256 chips resumes on 512 (or 8).
- Self-validating: manifest carries per-leaf SHA1 prefixes; restore
  verifies before handing the tree back.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

PyTree = Any


def _to_numpy_savable(arr: np.ndarray) -> np.ndarray:
    """bf16 & friends are ml_dtypes, not native numpy: store as raw u8."""
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(np.uint8)
    return arr


def _from_numpy_savable(arr: np.ndarray, dtype_name: str,
                        shape) -> np.ndarray:
    if arr.dtype == np.uint8 and dtype_name not in ("uint8",):
        dt = np.dtype(getattr(ml_dtypes, dtype_name, dtype_name))
        return arr.view(dt).reshape(shape)
    return arr.reshape(shape)


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(root: str, step: int, tree: PyTree,
                    keep_last: int = 3) -> str:
    """Atomically persist `tree` under root/step_<n>. Returns the path."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_ckpt_")
    manifest = {"step": step, "leaves": {}}
    for key, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
        np.save(os.path.join(tmp, fname), _to_numpy_savable(arr))
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape),
            "dtype": arr.dtype.name,
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest()[:16],
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic on POSIX
    _gc(root, keep_last)
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(root: str, like: PyTree, step: Optional[int] = None,
                       shardings: Optional[PyTree] = None,
                       validate: bool = True) -> Tuple[PyTree, int]:
    """Restore into the structure of `like`, optionally placing each leaf
    with the given shardings (elastic resharding happens here)."""
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (treedef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(flat_like))
    leaves = []
    for (path, leaf), shard in zip(flat_like, shard_flat):
        key = "/".join(_path_str(p) for p in path)
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(d, meta["file"]))
        arr = _from_numpy_savable(arr, meta["dtype"], tuple(meta["shape"]))
        if validate:
            h = hashlib.sha1(arr.tobytes()).hexdigest()[:16]
            if h != meta["sha1"]:
                raise IOError(f"checkpoint leaf {key} failed hash check")
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves), step


def _gc(root: str, keep_last: int):
    steps = sorted([d for d in os.listdir(root) if d.startswith("step_")])
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)
