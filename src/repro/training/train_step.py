"""Causal-LM training step (loss, grads, AdamW update).

Used by the multi-pod dry-run (train_4k shapes) and by the runnable
examples (reduced configs on CPU).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.training.optimizer import AdamWState, adamw_update

PyTree = Any


def lm_loss(params, cfg: ModelConfig, tokens=None, embeds=None,
            labels=None, remat: bool = True,
            remat_policy: str = "none") -> jax.Array:
    """Next-token cross-entropy. For token inputs, labels default to the
    shifted input. For embeds inputs (vlm/audio stubs), labels are given."""
    logits, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                        remat=remat, remat_policy=remat_policy)
    if labels is None:
        assert tokens is not None
        logits = logits[:, :-1]
        labels = tokens[:, 1:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def train_step(params, opt_state: AdamWState, batch, cfg: ModelConfig, *,
               lr: float = 3e-4, remat: bool = True, microbatches: int = 1,
               remat_policy: str = "none"
               ) -> Tuple[PyTree, AdamWState, jax.Array]:
    """One optimization step. batch: dict with 'tokens' or 'embeds'(+labels).

    With microbatches > 1, the global batch is split along dim 0 and
    gradients are accumulated in a scan (bounds activation memory — the
    production default for the 1M-token train_4k shape).

    Returns (new_params, new_opt_state, loss).
    """
    def loss_fn(p, b):
        return lm_loss(p, cfg, tokens=b.get("tokens"),
                       embeds=b.get("embeds"),
                       labels=b.get("labels"), remat=remat,
                       remat_policy=remat_policy)

    if microbatches <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    else:
        B = next(iter(batch.values())).shape[0]
        assert B % microbatches == 0, (B, microbatches)
        mb = {k: v.reshape((microbatches, B // microbatches) + v.shape[1:])
              for k, v in batch.items()}

        def acc_step(carry, b):
            loss_sum, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, b)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), g_acc, g)
            return (loss_sum + loss, g_acc), ()

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(acc_step, (0.0, g0), mb)
        loss = loss_sum / microbatches
        grads = jax.tree.map(lambda g: g / microbatches, grads)

    new_params, new_opt = adamw_update(grads, opt_state, params, lr=lr)
    return new_params, new_opt, loss


def make_train_step(cfg: ModelConfig, lr: float = 3e-4, remat: bool = True,
                    microbatches: int = 1, remat_policy: str = "none"):
    """Closure suitable for jax.jit(in_shardings=..., out_shardings=...)."""
    def step(params, opt_state, batch):
        return train_step(params, opt_state, batch, cfg, lr=lr, remat=remat,
                          microbatches=microbatches,
                          remat_policy=remat_policy)
    return step
