"""Tiered tenants for the concurrent query scheduler.

A TenantSpec names one workload class sharing a Session's engine pool
and declares how the scheduler treats its queries: `weight` sets the
weighted-fair admission share (a tenant's virtual time advances at
tuples/weight, so a heavy tenant with twice the weight gets twice the
throughput before a light tenant's queries jump the queue), `tier`
selects the cache policy — premium tenants keep their profile ladders
device-resident (the engine's device LRU is pre-warmed on their first
query per corpus and never evicted by the scheduler), standard tenants
share the LRU opportunistically, and cold tenants build lazily and have
their rungs evicted from the device LRU when each query finishes, so a
rarely-seen workload cannot squat on HBM a premium tenant paid for.

Declared on SessionConfig(tenants=...) or passed straight to
QueryScheduler(tenants=...); queries are submitted under a tenant name
(default: the implicit "default" standard tenant).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

# tier -> (default fair-share weight, default keep_warm)
TIERS = {
    "premium": (4.0, True),
    "standard": (1.0, False),
    "cold": (0.25, False),
}


@dataclass(frozen=True)
class TenantSpec:
    """One tenant sharing a scheduled Session.

      name      — unique tenant name queries are submitted under
      tier      — "premium" | "standard" | "cold" (cache policy + the
                  default weight)
      weight    — weighted-fair admission share (None: the tier default;
                  premium 4.0, standard 1.0, cold 0.25). Charged in
                  tuples/weight of virtual time per coalesced flush.
      keep_warm — pre-stage this tenant's profile ladder in the engines'
                  device-resident LRU on its first query per corpus
                  (None: the tier default; True only for premium).
                  A no-op on engines with the device cache off.
    """
    name: str
    tier: str = "standard"
    weight: Optional[float] = None
    keep_warm: Optional[bool] = None

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            raise ValueError("TenantSpec.name must be a non-empty string")
        if self.tier not in TIERS:
            raise ValueError(
                f"tenant {self.name!r}: tier {self.tier!r} is not one of "
                f"{sorted(TIERS)}")
        if self.weight is not None and self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be "
                             f"positive, got {self.weight}")

    @property
    def fair_weight(self) -> float:
        """The effective fair-share weight (tier default unless set)."""
        return float(self.weight) if self.weight is not None \
            else TIERS[self.tier][0]

    @property
    def warms(self) -> bool:
        """Whether this tenant's first query per corpus pre-warms the
        device LRU (tier default unless keep_warm set)."""
        return bool(self.keep_warm) if self.keep_warm is not None \
            else TIERS[self.tier][1]

    @property
    def evicts(self) -> bool:
        """Cold tenants release their device-LRU rungs after each
        query."""
        return self.tier == "cold"


def validate_tenants(tenants) -> Tuple[TenantSpec, ...]:
    """Normalize + validate a tenants declaration (tuple of TenantSpec,
    unique names)."""
    specs = tuple(tenants)
    for t in specs:
        if not isinstance(t, TenantSpec):
            raise TypeError(f"tenants must be TenantSpec instances, "
                            f"got {type(t)!r}")
    names = [t.name for t in specs]
    dups = sorted({n for n in names if names.count(n) > 1})
    if dups:
        raise ValueError(f"duplicate tenant name(s): {dups}")
    return specs
