"""Concurrent query scheduler: admit many SemFrame queries onto one
Session/engine pool with cross-query flush coalescing and tiered
tenants. See scheduler.py (admission + fairness + tiers), hub.py
(coalescing seam), tenants.py (TenantSpec tiers).

Lazy exports (PEP 562): repro.api.session imports tenants from here for
SessionConfig validation; importing scheduler.py eagerly would close an
import cycle back through repro.api.
"""
from typing import TYPE_CHECKING

_EXPORTS = {
    "QueryScheduler": "repro.scheduler.scheduler",
    "QueryHandle": "repro.scheduler.scheduler",
    "QueryTelemetry": "repro.scheduler.scheduler",
    "SchedulerSaturated": "repro.scheduler.scheduler",
    "FlushHub": "repro.scheduler.hub",
    "QueryDispatcher": "repro.scheduler.hub",
    "split_ints": "repro.scheduler.hub",
    "TenantSpec": "repro.scheduler.tenants",
    "TIERS": "repro.scheduler.tenants",
    "validate_tenants": "repro.scheduler.tenants",
}

__all__ = sorted(_EXPORTS)

if TYPE_CHECKING:    # static importers see the real names
    from repro.scheduler.hub import (FlushHub, QueryDispatcher,  # noqa
                                     split_ints)
    from repro.scheduler.scheduler import (QueryHandle,  # noqa
                                           QueryScheduler,
                                           QueryTelemetry,
                                           SchedulerSaturated)
    from repro.scheduler.tenants import (TIERS, TenantSpec,  # noqa
                                         validate_tenants)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return __all__
