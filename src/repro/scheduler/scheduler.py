"""QueryScheduler: concurrent admission of SemFrame queries onto one
Session's engine pool.

The scheduler owns three concerns the single-query Session API does not:

  admission — a bounded run queue in front of `max_concurrent` driver
      slots. submit() returns a QueryHandle immediately; when the queue
      is full it raises SchedulerSaturated instead of buffering
      unboundedly. Admission order is weighted-fair: each tenant carries
      a virtual time that advances at tuples/weight as its flushes fire,
      and the pending query belonging to the lowest-vtime tenant is
      admitted first (arrival order breaks ties), so a heavy premium
      tenant gets its weight share without starving cold tenants.

  coalescing — every admitted query executes the ordinary streaming
      cascade on its own driver thread, but flushes route through the
      shared FlushHub (see hub.py): concurrent queries' flushes for the
      same (engine, operator) fire as ONE merged engine call, and the
      per-query decisions stay bit-identical to solo execution.

  tiers — premium tenants (`TenantSpec.warms`) get their profile ladder
      pre-staged into the engines' device-resident LRU on their first
      query per corpus; cold tenants (`TenantSpec.evicts`) have their
      rungs evicted when each query finishes.

Per-query telemetry (queue wait, slot occupancy, shared-batch counters)
is attached to the QueryResult as `.sched` and rendered by EXPLAIN
ANALYZE's "scheduler:" footer; per-tenant aggregates and the hub's
merge counters come back from `stats()`.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from repro.scheduler.hub import FlushHub
from repro.scheduler.tenants import TenantSpec, validate_tenants


class SchedulerSaturated(RuntimeError):
    """submit() refused: the run queue is at max_queue."""


@dataclass
class QueryTelemetry:
    """Per-query scheduler telemetry, attached to QueryResult.sched."""
    query_id: int
    tenant: str
    tier: str
    weight: float
    queue_wait_s: float = 0.0     # submit -> admission
    run_wall_s: float = 0.0       # admission -> completion
    slots: int = 1                # concurrent flush slots the query held
    shared_batches: int = 0       # this query's flushes that rode a
    shared_width: int = 0         # merged call, and their summed width
    n_batches: int = 0            # total flushes this query executed

    @property
    def mean_shared_width(self) -> float:
        return self.shared_width / max(self.shared_batches, 1)

    def as_dict(self) -> Dict[str, Any]:
        return {"query_id": self.query_id, "tenant": self.tenant,
                "tier": self.tier, "weight": self.weight,
                "queue_wait_s": self.queue_wait_s,
                "run_wall_s": self.run_wall_s, "slots": self.slots,
                "shared_batches": self.shared_batches,
                "shared_width": self.shared_width,
                "n_batches": self.n_batches}


@dataclass
class _TenantState:
    """Scheduler-internal per-tenant accounting."""
    spec: TenantSpec
    vtime: float = 0.0            # virtual time, tuples/weight
    n_queries: int = 0
    n_tuples: int = 0
    queue_wait_s: float = 0.0
    run_wall_s: float = 0.0
    warmed: Set[Any] = field(default_factory=set)   # corpus keys staged
    warm_batches: int = 0
    evictions: int = 0


class QueryHandle:
    """Future-like handle for one submitted query."""

    def __init__(self, scheduler: "QueryScheduler", query_id: int,
                 tenant: str, query, items: Sequence[Any], plan):
        self._scheduler = scheduler
        self.query_id = query_id
        self.tenant = tenant
        self.query = query
        self.items = items
        self.plan = plan
        self.submit_t = time.monotonic()
        self.admit_t: Optional[float] = None
        self.queue_wait_s = 0.0
        self.run_wall_s = 0.0
        self._done = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block until the query completes; returns its QueryResult
        (with `.sched` telemetry attached) or re-raises its error."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"query {self.query_id} (tenant {self.tenant!r}) not done "
                f"within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def _finish(self, result, error: Optional[BaseException]):
        self._result = result
        self._error = error
        self._done.set()

    def __repr__(self) -> str:
        state = "done" if self.done() else (
            "running" if self.admit_t is not None else "queued")
        return (f"QueryHandle(id={self.query_id}, tenant={self.tenant!r}, "
                f"{state})")


class QueryScheduler:
    """Admit many concurrent queries onto one Session.

      max_concurrent — driver slots (queries executing at once)
      max_queue      — bound on queued-but-unadmitted queries; submit()
                       raises SchedulerSaturated beyond it
      slots_per_query — concurrent unfinished flushes each query may
                       hold in the hub (1 = inline lockstep schedule,
                       the bit-identical default)
      execute        — where merged engine calls run: "inline" or
                       "threads[:N]" (see FlushHub)
      patience_s / fire_width — hub firing policy knobs
      tenants        — TenantSpec declarations (default: the session
                       config's `tenants`; an implicit "default"
                       standard tenant always exists)
      paused         — start paused (queries queue but none admit);
                       useful for deterministic overlap in tests
    """

    def __init__(self, session, *, max_concurrent: int = 4,
                 max_queue: int = 64, slots_per_query: int = 1,
                 execute: str = "inline", patience_s: float = 0.05,
                 fire_width: Optional[int] = None,
                 tenants: Optional[Sequence[TenantSpec]] = None,
                 paused: bool = False):
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.session = session
        self.max_concurrent = int(max_concurrent)
        self.max_queue = int(max_queue)
        self.slots_per_query = max(int(slots_per_query), 1)
        declared = tenants if tenants is not None else \
            (session.config.tenants or ())
        specs = list(validate_tenants(declared))
        if not any(t.name == "default" for t in specs):
            specs.append(TenantSpec("default"))
        self._lock = threading.Lock()
        self._tenants: Dict[str, _TenantState] = {
            t.name: _TenantState(t) for t in specs}
        self._queue: List[QueryHandle] = []
        self._running: Set[QueryHandle] = set()
        self._seq = itertools.count()
        self._paused = bool(paused)
        self._closed = False
        self._idle = threading.Condition(self._lock)
        self._hub = FlushHub(session.backend, execute=execute,
                             patience_s=patience_s, fire_width=fire_width,
                             charge=self._charge, priority=self._priority)

    # ---------------- submission ----------------

    def submit(self, frame=None, *, query=None, items=None,
               tenant: str = "default", plan=None) -> QueryHandle:
        """Enqueue one query. Pass a SemFrame, or (query=, items=)
        explicitly; `plan` short-circuits planning with a prebuilt
        PhysicalPlan. Returns a QueryHandle immediately."""
        if frame is not None:
            if getattr(frame, "_session", None) is not self.session:
                raise ValueError("frame belongs to a different Session "
                                 "than this scheduler")
            query = frame.to_query()
            items = frame.items
        if query is None or items is None:
            raise ValueError("submit() needs a SemFrame or query= and "
                             "items=")
        with self._lock:
            if self._closed:
                raise RuntimeError("QueryScheduler is closed")
            st = self._tenants.get(tenant)
            if st is None:
                raise ValueError(
                    f"unknown tenant {tenant!r}: declared tenants are "
                    f"{sorted(self._tenants)}")
            can_start = (not self._paused
                         and len(self._running) < self.max_concurrent)
            if not can_start and len(self._queue) >= self.max_queue:
                raise SchedulerSaturated(
                    f"run queue full ({self.max_queue} queries waiting); "
                    f"tenant {tenant!r} must back off")
            h = QueryHandle(self, next(self._seq), tenant, query, items,
                            plan)
            self._queue.append(h)
        self._maybe_admit()
        return h

    def _maybe_admit(self):
        while True:
            with self._lock:
                if (self._paused or self._closed or not self._queue
                        or len(self._running) >= self.max_concurrent):
                    return
                h = min(self._queue,
                        key=lambda q: (self._tenants[q.tenant].vtime,
                                       q.query_id))
                self._queue.remove(h)
                self._running.add(h)
                h.admit_t = time.monotonic()
                h.queue_wait_s = h.admit_t - h.submit_t
            # register with the hub HERE, before the driver thread even
            # starts: the hub's quiescence count then covers every
            # admitted query, so an early driver's first flush waits for
            # its co-admitted peers instead of firing solo (outside the
            # scheduler lock — the hub's cv may call back into
            # _priority, which takes it)
            self._hub.register()
            t = threading.Thread(target=self._drive, args=(h,),
                                 name=f"stretto-query-{h.query_id}",
                                 daemon=True)
            t.start()

    # ---------------- hub callbacks (fairness) ----------------

    # Lock ordering: the hub calls these while holding nothing (charge)
    # or its own cv (priority); this lock never calls back into the hub,
    # so hub-cv -> scheduler-lock is the only ordering and cannot cycle.

    def _charge(self, ticket: QueryHandle, n_tuples: int):
        with self._lock:
            st = self._tenants[ticket.tenant]
            st.vtime += n_tuples / st.spec.fair_weight
            st.n_tuples += n_tuples

    def _priority(self, ticket: QueryHandle) -> float:
        with self._lock:
            return self._tenants[ticket.tenant].vtime

    # ---------------- execution ----------------

    def _drive(self, h: QueryHandle):
        # NOTE: the matching hub.register() already ran in _maybe_admit
        from repro.api.result import QueryResult
        try:
            spec = self._tenants[h.tenant].spec
            plan = h.plan if h.plan is not None \
                else self.session.plan(h.query, h.items)
            if spec.warms:
                self._warm(h, plan)
            t0 = time.monotonic()
            try:
                disp = self._hub.dispatcher(h, self.slots_per_query)
                gen = self.session.iter_run(plan, h.query, h.items,
                                            dispatcher=disp)
                while True:
                    try:
                        next(gen)
                    except StopIteration as stop:
                        raw = stop.value
                        break
            finally:
                h.run_wall_s = time.monotonic() - t0
            if spec.evicts:
                self._evict(plan, h)
            qr = QueryResult(self.session, h.query, h.items, raw)
            qr.sched = self._telemetry(h, raw)
            h._finish(qr, None)
        except BaseException as e:
            h._finish(None, e)
        finally:
            self._hub.unregister()
            with self._lock:
                self._running.discard(h)
                st = self._tenants[h.tenant]
                st.n_queries += 1
                st.queue_wait_s += h.queue_wait_s
                st.run_wall_s += h.run_wall_s
                self._idle.notify_all()
            self._maybe_admit()

    def _telemetry(self, h: QueryHandle, raw) -> QueryTelemetry:
        spec = self._tenants[h.tenant].spec
        return QueryTelemetry(
            query_id=h.query_id, tenant=h.tenant, tier=spec.tier,
            weight=spec.fair_weight, queue_wait_s=h.queue_wait_s,
            run_wall_s=h.run_wall_s, slots=self.slots_per_query,
            shared_batches=sum(getattr(sg, "shared_batches", 0)
                               for sg in raw.stage_stats),
            shared_width=sum(getattr(sg, "shared_width", 0)
                             for sg in raw.stage_stats),
            n_batches=sum(sg.n_batches for sg in raw.stage_stats))

    # ---------------- tier cache policy ----------------

    def _stage_engines(self, plan, query) -> List[Tuple[Any, str, float,
                                                        bool]]:
        """(engine, model_name, ratio, quant) per distinct KV-cache rung
        the plan touches — derived by resolving each stage to its
        physical operator and reading the serving attributes off it
        (pooled stages unwrap their EngineTaggedOperator)."""
        sem_ops = query.semantic_ops
        seen: Set[Tuple[int, str, float, bool]] = set()
        out: List[Tuple[Any, str, float, bool]] = []
        for st in plan.stages:
            try:
                phys = self.session.backend.resolve(
                    sem_ops[st.logical_idx], st.op_name)
            except Exception:
                continue
            inner = getattr(phys, "inner", phys)
            eng = getattr(inner, "engine", None)
            model = getattr(inner, "model_name", None)
            if eng is None or model is None or not hasattr(eng, "warm"):
                continue
            ratio = float(getattr(inner, "ratio", 1.0))
            quant = bool(getattr(inner, "quant", False))
            key = (id(eng), model, ratio, quant)
            if key in seen:
                continue
            seen.add(key)
            out.append((eng, model, ratio, quant))
        return out

    def _warm(self, h: QueryHandle, plan):
        """Premium pre-staging: push the plan's profile rungs into the
        engines' device LRU, once per (tenant, corpus)."""
        st = self._tenants[h.tenant]
        ckey = self.session.corpus_key(h.items)
        with self._lock:
            if ckey in st.warmed:
                return
            st.warmed.add(ckey)
        ids = [getattr(it, "item_id", None) for it in h.items]
        if any(i is None for i in ids):
            return
        batches = 0
        for eng, model, ratio, quant in self._stage_engines(plan, h.query):
            try:
                batches += eng.warm(model, ratio, ids, quant=quant)
            except Exception:
                continue      # warm is best-effort; the query still runs
        with self._lock:
            st.warm_batches += batches

    def _evict(self, plan, h: QueryHandle):
        """Cold-tier cleanup: drop this query's rungs from the device
        LRU so a rarely-seen workload cannot squat on HBM."""
        st = self._tenants[h.tenant]
        n = 0
        for eng, model, ratio, quant in self._stage_engines(plan, h.query):
            try:
                n += eng.evict(model, ratio, quant=quant)
            except Exception:
                continue
        with self._lock:
            st.evictions += n

    # ---------------- control / telemetry / lifecycle ----------------

    def pause(self):
        """Stop admitting queries (running ones finish; submits queue)."""
        with self._lock:
            self._paused = True

    def resume(self):
        with self._lock:
            self._paused = False
        self._maybe_admit()

    @property
    def n_running(self) -> int:
        with self._lock:
            return len(self._running)

    @property
    def n_queued(self) -> int:
        with self._lock:
            return len(self._queue)

    def stats(self) -> Dict[str, Any]:
        """Per-tenant aggregates plus the hub's merge counters."""
        with self._lock:
            tenants = {
                name: {"tier": st.spec.tier,
                       "weight": st.spec.fair_weight,
                       "vtime": st.vtime,
                       "n_queries": st.n_queries,
                       "n_tuples": st.n_tuples,
                       "queue_wait_s": st.queue_wait_s,
                       "run_wall_s": st.run_wall_s,
                       "warm_batches": st.warm_batches,
                       "evictions": st.evictions}
                for name, st in self._tenants.items()}
            queued, running = len(self._queue), len(self._running)
        out = {"tenants": tenants, "queued": queued, "running": running}
        out.update(self._hub.snapshot())
        return out

    def drain(self, timeout: Optional[float] = None):
        """Block until every submitted query has completed."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while self._queue or self._running:
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"scheduler not drained: {len(self._queue)} "
                        f"queued, {len(self._running)} running")
                self._idle.wait(left)

    def close(self, timeout: Optional[float] = None):
        """Drain outstanding queries, then shut the hub down.
        Idempotent; submits after close raise RuntimeError."""
        with self._lock:
            if self._closed:
                self._hub.close()
                return
        self.drain(timeout)
        with self._lock:
            self._closed = True
        self._hub.close()

    def __enter__(self) -> "QueryScheduler":
        return self

    def __exit__(self, *exc):
        self.close()
        return False
