"""FlushHub: the cross-query flush-coalescing seam.

Each admitted query runs the ordinary streaming executor on its own
driver thread, but with a per-query proxy dispatcher (`QueryDispatcher`)
instead of inline/threads: every FlushTask the executor submits is parked
in the hub, grouped by ``(engine, op_name, semantic op)``, and the
driver blocks on the task's handle exactly where an InlineDispatcher
would have executed it. When every live driver is blocked on an unfired
flush (quiescence — nobody can contribute more work to the current
round), the hub fires all pending groups: each group becomes ONE
`run_operator` call over the concatenation of its members' batches, and
the scores/values are sliced back per member.

Why decisions stay bit-identical to solo execution: per-query *schedule*
is untouched (the proxy's default max_pending=0 reproduces the inline
lockstep flush order, and completions apply in the executor's FIFO
order), and per-tuple scores are independent of batch composition under
the same documented condition the threads dispatcher already relies on
(run_plan's docstring) — merging only regroups batches, exactly like
coalescing across partitions does. Telemetry splits exactly: integer
counters (kv_bytes, donated_bytes) are apportioned by segment size with
the remainder on the leading segments so per-query stats tile the merged
totals bit-for-bat even though a merged load cannot be re-measured per
query; wall_s is apportioned proportionally (each query reports its
share of the merged call's wall time).

Deadlock-freedom: quiescence is detected as ``blocked >= active`` with
no fired group still executing; a driver doing long non-flush work
(planning, decision kernels) delays firing at most `patience_s`, after
which pending groups fire without it. The same patience window bounds
how long a fired-but-slow group (a remote member on a bad link) can
hold back unrelated parked groups. A pump-thread failure fails every
parked flush instead of hanging its drivers.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.runtime.backend import Backend
from repro.runtime.dispatch import FlushTask
from repro.runtime.executor import _OperatorOutcome, run_operator


def split_ints(total: int, sizes: List[int]) -> List[int]:
    """Apportion an integer total over segments proportionally to their
    sizes, remainder (< len(sizes)) on the leading segments — the splits
    always sum back to the total exactly."""
    n = sum(sizes)
    if n <= 0:
        return [0] * len(sizes)
    out = [total * s // n for s in sizes]
    rem = total - sum(out)
    for i in range(rem):
        out[i] += 1
    return out


class _PendingFlush:
    """One parked FlushTask awaiting a merged fire."""

    __slots__ = ("ticket", "task", "done", "outcome", "error", "fired")

    def __init__(self, ticket, task: FlushTask):
        self.ticket = ticket
        self.task = task
        self.done = threading.Event()
        self.outcome: Optional[_OperatorOutcome] = None
        self.error: Optional[BaseException] = None
        self.fired = False


class _HubHandle:
    """The handle the executor blocks on (its `.result()` is where an
    inline flush would have run)."""

    __slots__ = ("_hub", "_flush")

    def __init__(self, hub: "FlushHub", flush: _PendingFlush):
        self._hub = hub
        self._flush = flush

    def result(self):
        return self._hub._wait(self._flush)


class QueryDispatcher:
    """Per-query proxy dispatcher: satisfies the executor's dispatcher
    surface (submit/close/max_pending) but parks every flush in the
    shared FlushHub instead of executing it. With the default
    ``slots=1`` the executor completes each flush right after submitting
    it — the exact inline lockstep schedule, which is what keeps
    per-query decisions bit-identical to solo execution."""

    name = "scheduler"
    n_shards = 1

    def __init__(self, hub: "FlushHub", ticket, slots: int = 1):
        self._hub = hub
        self._ticket = ticket
        self.max_pending = max(int(slots), 1) - 1
        self.n_workers = hub.n_workers

    def submit(self, task: FlushTask,
               runner: Callable[[FlushTask], Any]) -> _HubHandle:
        # the runner is ignored on purpose: the hub executes merged
        # groups through run_operator itself, one call per group
        return self._hub.submit(self._ticket, task)

    def close(self):
        pass


class FlushHub:
    """Shared coalescing hub over one Session backend.

    execute — where merged calls run: "inline" (the pump thread,
        serially, in fair order) or "threads[:N]" (a pool; groups for
        different engines overlap, as ThreadPoolDispatcher would).
    patience_s — max time the pump waits for stragglers once at least
        one flush is parked and nothing is executing; bounds added
        latency when a driver is busy with non-flush work.
    fire_width — fire a group immediately once its concatenated batch
        reaches this many tuples, without waiting for quiescence
        (None: always wait — maximal merging).
    charge / priority — scheduler callbacks: ``charge(ticket, n)``
        advances the ticket's tenant virtual time when its flush fires;
        ``priority(ticket)`` orders groups at fire time (lower first).
    """

    def __init__(self, backend: Backend, *, execute: str = "inline",
                 patience_s: float = 0.05,
                 fire_width: Optional[int] = None,
                 charge: Optional[Callable[[Any, int], None]] = None,
                 priority: Optional[Callable[[Any], float]] = None):
        self._backend = backend
        self._patience = max(float(patience_s), 1e-4)
        self._fire_width = fire_width
        self._charge = charge
        self._priority = priority
        kind, _, arg = str(execute).partition(":")
        if kind not in ("inline", "threads"):
            raise ValueError(f"FlushHub execute={execute!r}: expected "
                             f"'inline' or 'threads[:N]'")
        self.n_workers = int(arg) if (kind == "threads" and arg) else \
            (4 if kind == "threads" else 1)
        if self.n_workers <= 0:
            raise ValueError(f"FlushHub execute={execute!r}: worker count "
                             f"must be positive")
        self._pool = None
        if kind == "threads":
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=self.n_workers,
                thread_name_prefix="stretto-hub")
        self._cv = threading.Condition()
        # key -> (arrival seq, [parked flushes]); keys are hashable by
        # construction (engine tag, op name, frozen-dataclass sem op)
        self._groups: "OrderedDict[Tuple, Tuple[int, List[_PendingFlush]]]" \
            = OrderedDict()
        self._seq = 0
        self._active = 0          # registered driver threads
        self._blocked = 0         # drivers inside _wait
        self._in_service = 0      # fired groups still executing
        self._closed = False
        self._last_change = time.monotonic()
        # telemetry (read via snapshot())
        self.n_calls = 0          # merged engine calls issued
        self.n_flushes = 0        # member flushes folded into them
        self.n_merged_calls = 0   # calls that merged >1 query
        self.merged_width = 0     # tuples in those merged calls
        self._thread = threading.Thread(target=self._pump_loop,
                                        name="stretto-hub-pump",
                                        daemon=True)
        self._thread.start()

    # ---------------- driver surface ----------------

    def register(self):
        with self._cv:
            if self._closed:
                raise RuntimeError("FlushHub is closed")
            self._active += 1
            self._touch()

    def unregister(self):
        with self._cv:
            self._active -= 1
            self._touch()
            self._cv.notify_all()

    def dispatcher(self, ticket, slots: int = 1) -> QueryDispatcher:
        return QueryDispatcher(self, ticket, slots)

    def submit(self, ticket, task: FlushTask) -> _HubHandle:
        f = _PendingFlush(ticket, task)
        key = (task.engine, task.op_name, task.sem_op)
        with self._cv:
            if self._closed:
                raise RuntimeError("FlushHub is closed")
            got = self._groups.get(key)
            if got is None:
                self._groups[key] = (self._seq, [f])
                self._seq += 1
            else:
                got[1].append(f)
            self._touch()
            self._cv.notify_all()
        return _HubHandle(self, f)

    def _wait(self, f: _PendingFlush) -> _OperatorOutcome:
        with self._cv:
            self._blocked += 1
            self._touch()
            self._cv.notify_all()
        try:
            f.done.wait()
        finally:
            with self._cv:
                self._blocked -= 1
                self._touch()
        if f.error is not None:
            raise f.error
        return f.outcome

    # ---------------- firing policy ----------------

    def _touch(self):
        self._last_change = time.monotonic()

    def _width(self, members: List[_PendingFlush]) -> int:
        return sum(len(f.task.items) for f in members)

    def _fire_ready(self) -> bool:
        """Under self._cv: should the pump fire the pending groups now?"""
        if not self._groups:
            return False
        if self._closed:
            return True
        if self._fire_width is not None and any(
                self._width(m) >= self._fire_width
                for _, m in self._groups.values()):
            return True
        if self._in_service:
            # a completing group normally wakes the next round (maximal
            # merging) — but a SLOW member (a remote engine on a bad
            # link, say) must not stall unrelated parked groups past the
            # patience window: after patience_s they fire anyway (under
            # "threads" execution they overlap the straggler; decisions
            # are unchanged — merging only regroups batches)
            return (time.monotonic() - self._last_change) >= self._patience
        # quiescence: every live driver is blocked on an unfired flush —
        # nobody can add to this round, so merging is maximal
        if self._blocked >= self._active:
            return True
        return (time.monotonic() - self._last_change) >= self._patience

    def _wait_timeout(self) -> Optional[float]:
        # the patience timer is armed whenever anything is parked — also
        # while a fired group is still executing, else a straggling
        # member leaves parked groups waiting on its completion forever
        if self._groups:
            left = self._patience - (time.monotonic() - self._last_change)
            return max(left, 1e-3)
        return None

    def _take_all(self) -> List[Tuple[Tuple, List[_PendingFlush]]]:
        """Under self._cv: claim every pending group, fair order (lowest
        member priority first, arrival order breaking ties)."""
        taken = [(key, seq, members)
                 for key, (seq, members) in self._groups.items()]
        self._groups.clear()
        if self._priority is not None:
            taken.sort(key=lambda g: (min(self._priority(f.ticket)
                                          for f in g[2]), g[1]))
        else:
            taken.sort(key=lambda g: g[1])
        for _, _, members in taken:
            for f in members:
                f.fired = True
        return [(key, members) for key, _, members in taken]

    def _pump_loop(self):
        try:
            while True:
                with self._cv:
                    while not self._fire_ready():
                        if self._closed and not self._groups:
                            return
                        self._cv.wait(self._wait_timeout())
                    groups = self._take_all()
                    self._in_service += len(groups)
                    self._touch()
                for key, members in groups:
                    if self._charge is not None:
                        for f in members:
                            self._charge(f.ticket, len(f.task.items))
                    if self._pool is not None:
                        self._pool.submit(self._run_group, key, members)
                    else:
                        self._run_group(key, members)
        except BaseException as e:       # pump must never die silently:
            self._fail_all(e)            # fail parked flushes, not hang
            raise

    def _fail_all(self, err: BaseException):
        with self._cv:
            groups = [m for _, (_, m) in self._groups.items()]
            self._groups.clear()
            self._closed = True
            self._cv.notify_all()
        for members in groups:
            for f in members:
                f.error = err
                f.done.set()

    # ---------------- merged execution ----------------

    def _run_group(self, key: Tuple, members: List[_PendingFlush]):
        engine, op_name, sem_op = key
        try:
            items: List[Any] = []
            segs: List[Tuple[_PendingFlush, int, int]] = []
            for f in members:
                lo = len(items)
                items.extend(f.task.items)
                segs.append((f, lo, len(items)))
            out = run_operator(self._backend, sem_op, op_name, items)
            n_total = len(items)
            sizes = [hi - lo for _, lo, hi in segs]
            n_queries = len({id(f.ticket) for f in members})
            kv = split_ints(out.kv_bytes, sizes)
            donated = split_ints(out.donated_bytes, sizes)
            for i, (f, lo, hi) in enumerate(segs):
                frac = sizes[i] / max(n_total, 1)
                f.outcome = _OperatorOutcome(
                    scores=out.scores[lo:hi],
                    values=None if out.values is None
                    else out.values[lo:hi],
                    wall_s=out.wall_s * frac,
                    kv_bytes=kv[i],
                    uses_llm=out.uses_llm,
                    h2d_overlap_s=out.h2d_overlap_s * frac,
                    donated_bytes=donated[i],
                    merged_width=n_total if n_queries > 1 else 0,
                    merged_queries=n_queries)
        except BaseException as e:
            for f in members:
                f.error = e
        finally:
            with self._cv:
                self._in_service -= 1
                self.n_calls += 1
                self.n_flushes += len(members)
                if len({id(f.ticket) for f in members}) > 1:
                    self.n_merged_calls += 1
                    self.merged_width += sum(len(f.task.items)
                                             for f in members)
                self._touch()
                self._cv.notify_all()
            for f in members:
                f.done.set()

    # ---------------- lifecycle / telemetry ----------------

    def snapshot(self) -> Dict[str, Any]:
        with self._cv:
            return {"n_calls": self.n_calls,
                    "n_flushes": self.n_flushes,
                    "n_merged_calls": self.n_merged_calls,
                    "merged_width": self.merged_width,
                    "saved_calls": self.n_flushes - self.n_calls}

    def close(self):
        with self._cv:
            if self._closed and not self._thread.is_alive():
                return
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout=30)
        if self._pool is not None:
            self._pool.shutdown(wait=True)
