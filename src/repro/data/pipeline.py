"""Deterministic sharded token data pipeline.

Host-side: each data-parallel host reads its shard of a deterministic
token stream (synthetic LM corpus here; swap `source_tokens` for a real
reader on a fleet). Determinism makes resume-from-checkpoint exact: the
loop fast-forwards the stream by the restored step count.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


def source_tokens(vocab: int, seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def lm_batches(vocab: int, global_batch: int, seq_len: int, *,
               host_id: int = 0, n_hosts: int = 1, seed: int = 1234,
               embeds_dim: Optional[int] = None
               ) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {'tokens': (B_host, S)} (or embeds+labels for stub-frontend
    archs). Each host yields its slice of the global batch."""
    assert global_batch % n_hosts == 0
    b = global_batch // n_hosts
    rng = np.random.default_rng(seed + 17 * host_id)
    # Zipfian unigram distribution: uniform tokens carry no learnable
    # signal (loss is already ln V); real corpora are heavy-tailed
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        tokens = rng.choice(vocab, size=(b, seq_len),
                            p=probs).astype(np.int32)
        if embeds_dim is None:
            yield {"tokens": tokens}
        else:
            yield {"embeds": rng.normal(size=(b, seq_len, embeds_dim)
                                        ).astype(np.float32),
                   "labels": tokens}
