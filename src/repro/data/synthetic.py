"""Planted-signal synthetic corpora + constructed model weights.

CPU cannot run 8B/70B models, so the executed experiments use reduced
same-family models whose weights are *constructed* (not trained) such that:

  - each corpus item plants label-bearing signal tokens for each task,
    scattered among distractors;
  - the model's attention pathway really retrieves them: the task's query
    token attends to that task's signal tokens (aligned key directions) and
    the answer head reads the label direction out of the attended value mix;
  - KV-cache compression *really* drops tokens (by Expected-Attention
    score), so the accuracy-vs-ratio ladder EMERGES from the mechanism the
    paper describes, rather than being simulated;
  - the larger model has more embedding dimensions -> less cross-task
    interference -> cleaner decisions: the model-size quality ladder also
    emerges.

Vocabulary layout (vocab = 256):
  0 pad | 1 no-answer | 2 yes-answer | 3-7 punctuation-distractors
  16+k   filter-task-k query token
  32+k   map-task-k query token
  8+v    value-answer tokens (8 values)
  64 + 8k + 4y + i   filter signal token (task k<16, label y, variant i<4)
  192 + 8k + v       map signal token (task k<8, value v<8)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import init_params

VOCAB = 256
TOK_NO, TOK_YES = 1, 2
N_VALUES = 8


def filter_query_token(k): return 16 + k
def map_query_token(k): return 32 + k
def value_token(v): return 8 + v
def filter_signal_token(k, y, i): return 64 + 8 * k + 4 * y + i
def map_signal_token(k, v): return 192 + 8 * k + v


@dataclass
class Item:
    item_id: int
    tokens: List[int]
    row: Dict[str, Any]
    labels: Dict[int, bool]          # filter task -> latent label
    map_vals: Dict[int, int]         # map task -> latent value
    modality: str = "text"


@dataclass
class Dataset:
    name: str
    items: List[Item]
    n_filter_tasks: int
    n_map_tasks: int
    modality: str = "text"


CATEGORIES = ("news", "sport", "science", "art")


def make_dataset(name: str, n_items: int, n_filter_tasks: int = 10,
                 n_map_tasks: int = 8, seq_len: int = 160,
                 n_signal: int = 5, modality: str = "text",
                 seed: int = 0) -> Dataset:
    assert n_filter_tasks <= 16 and n_map_tasks <= 8
    rng = np.random.default_rng(seed)
    items: List[Item] = []
    for i in range(n_items):
        labels = {k: bool(rng.random() < 0.45)
                  for k in range(n_filter_tasks)}
        map_vals = {k: int(rng.integers(N_VALUES))
                    for k in range(n_map_tasks)}
        toks = list(rng.integers(3, 8, size=seq_len))
        # non-overlapping planting slots so signals don't overwrite each
        # other; remaining positions stay distractors
        free = list(rng.permutation(seq_len))

        def take(n):
            out, rest = free[:n], free[n:]
            free[:] = rest
            return out

        for k in range(n_filter_tasks):
            if labels[k] or rng.random() < 0.5:
                y = int(labels[k])
                for p in take(n_signal):
                    toks[p] = filter_signal_token(k, y, int(rng.integers(4)))
        for k in range(n_map_tasks):
            for p in take(n_signal):
                toks[p] = map_signal_token(k, map_vals[k])
        row = {"year": int(rng.integers(1990, 2025)),
               "category": CATEGORIES[int(rng.integers(len(CATEGORIES)))],
               "length": seq_len}
        items.append(Item(i, [int(t) for t in toks], row, labels, map_vals,
                          modality))
    return Dataset(name, items, n_filter_tasks, n_map_tasks, modality)


def make_join_corpora(n_left: int = 120, n_right: int = 120, seed: int = 0,
                      id_offset: int = 1_000_000
                      ) -> Tuple[Dataset, Dataset]:
    """Two independently planted corpora for `sem_join` experiments.

    Both carry the full task layout (a join on map task k matches pairs
    whose latent `map_vals[k]` agree — ~1/8 of pairs) and the shared
    structured `category` column for equi-join blocking. Right-corpus
    item ids are offset into a disjoint id space: serving profiles are
    keyed by item id, so the two corpora can share one engine/cache
    store without collisions."""
    left = make_dataset("join-left", n_left, seed=seed)
    right = make_dataset("join-right", n_right, seed=seed + 101)
    for it in right.items:
        it.item_id += id_offset
    return left, right


def paper_datasets(scale: float = 1.0) -> Dict[str, Dataset]:
    """The five evaluation corpora (sizes from the paper)."""
    spec = [("artwork", 1000, "image", 11), ("rotowire", 728, "text", 13),
            ("email", 1001, "text", 17), ("movies", 1000, "text", 19),
            ("ecommerce", 1000, "image", 23)]
    out = {}
    for name, n, modality, seed in spec:
        out[name] = make_dataset(name, max(8, int(n * scale)),
                                 modality=modality, seed=seed)
    return out


# ---------------------------------------------------------------------------
# constructed ("planted") model weights
# ---------------------------------------------------------------------------

def planted_config(size: str) -> ModelConfig:
    """Reduced same-family model configs. 'sm' ~ the paper's 8B analogue,
    'lg' ~ the 70B analogue (gold)."""
    if size == "sm":
        return ModelConfig(
            name="planted-sm", family="dense", n_layers=2, d_model=32,
            n_heads=2, n_kv_heads=2, d_head=16, d_ff=64, vocab_size=VOCAB,
            attn_kind="gqa", rope_theta=1e8, dtype="float32")
    if size == "lg":
        return ModelConfig(
            name="planted-lg", family="dense", n_layers=2, d_model=96,
            n_heads=4, n_kv_heads=4, d_head=24, d_ff=128, vocab_size=VOCAB,
            attn_kind="gqa", rope_theta=1e8, dtype="float32")
    raise ValueError(size)


def make_planted_params(cfg: ModelConfig, seed: int = 0, beta: float = 2.0):
    """Construct weights so the attention pathway decodes planted signals.

    Geometry: each task has a *content* direction c_k (what its query token
    embeds), a *signal* direction u_k (what its signal tokens' keys carry)
    and a *label* direction r_k (what their values carry). The query
    projection is the rotation  wq = beta * sum_k c_k u_k^T, so the query
    attends to signal keys (q ~ beta*u_k) with ZERO self-attention score
    (c_k ⟂ u_k). The answer head reads sum_k r_k. Distractor embeddings are
    sampled in the orthogonal complement of all task directions — in the
    large model that complement exists and distractor keys score ~0; in the
    small model the directions can't all be orthogonal, so crosstalk makes
    it genuinely noisier. The quality ladders over model size AND cache
    compression therefore *emerge* from the mechanism.
    """
    D = cfg.d_model
    rng = np.random.default_rng(seed)
    n_dirs = 16 * 3 + 8 * 3     # u,r,c per filter task; m,w,cm per map task

    # as-orthogonal-as-possible direction bank
    raw = rng.normal(size=(max(n_dirs, D), D))
    qmat, _ = np.linalg.qr(raw.T)           # (D, D) orthonormal columns
    basis = qmat.T                          # D orthonormal rows
    dirs = np.empty((n_dirs, D))
    for i in range(n_dirs):
        if i < D:
            dirs[i] = basis[i]
        else:  # more directions than dimensions: random unit (crosstalk)
            v = rng.normal(size=D)
            dirs[i] = v / np.linalg.norm(v)
    u, r, c = dirs[0:16], dirs[16:32], dirs[32:48]
    m, w, cm = dirs[48:56], dirs[56:64], dirs[64:72]

    used = dirs[:min(n_dirs, D)]
    proj = np.eye(D) - used.T @ np.linalg.pinv(used.T)   # complement proj

    def distract():
        v = proj @ rng.normal(size=D)
        n = np.linalg.norm(v)
        if n < 1e-6:                      # sm model: complement is empty
            v = rng.normal(size=D)
            n = np.linalg.norm(v)
        return v / n

    E = np.stack([distract() for _ in range(VOCAB)]) * 0.5
    for k in range(16):
        E[filter_query_token(k)] = c[k]
        for y in (0, 1):
            s = 1.0 if y else -1.0
            for i in range(4):
                E[filter_signal_token(k, y, i)] = (
                    u[k] + s * r[k] + 0.25 * rng.normal(size=D) / np.sqrt(D))
    for k in range(8):
        E[map_query_token(k)] = cm[k]
        for v in range(8):
            E[map_signal_token(k, v)] = (
                m[k] + w[v] + 0.25 * rng.normal(size=D) / np.sqrt(D))

    head = 0.02 * rng.normal(size=(D, cfg.vocab_padded))
    r_sum = r.sum(0)
    head[:, TOK_YES] = +r_sum / np.sqrt(16)
    head[:, TOK_NO] = -r_sum / np.sqrt(16)
    for v in range(8):
        head[:, value_token(v)] = w[v]

    # query rotation: content dirs -> signal dirs
    wq_rot = beta * (np.einsum("kd,ke->de", c, u)
                     + np.einsum("kd,ke->de", cm, m))

    params = init_params(cfg, jax.random.PRNGKey(seed))
    params = jax.tree.map(np.asarray, params)
    eye = np.eye(D, dtype=np.float32)
    L_ = cfg.n_layers

    def stack(a):
        return np.broadcast_to(a, (L_,) + a.shape).copy()

    params["embed"] = E.astype(np.float32)
    params["head"] = head.astype(np.float32)
    params["final_norm"] = np.zeros(D, np.float32)
    la = params["layers"]
    la["norm_attn"] = np.zeros((L_, D), np.float32)
    la["norm_mlp"] = np.zeros((L_, D), np.float32)
    la["attn"]["wq"] = stack(wq_rot.astype(np.float32))
    la["attn"]["wk"] = stack(eye)
    la["attn"]["wv"] = stack(eye)
    # o-proj: only the LAST layer writes attention output into the residual
    # (keeps token identity intact in every layer's cache; the last layer
    # is the retrieval layer)
    wo = np.zeros((L_, D, D), np.float32)
    wo[-1] = 0.7 * eye
    la["attn"]["wo"] = wo
    la["mlp"]["w_gate"] = np.zeros_like(la["mlp"]["w_gate"])
    la["mlp"]["w_up"] = np.zeros_like(la["mlp"]["w_up"])
    la["mlp"]["w_down"] = np.zeros_like(la["mlp"]["w_down"])
    return jax.tree.map(jnp.asarray, params)
