"""Config-driven decoder LM: params, forward, prefill, decode.

A single ``lax.scan`` over the layer stack (stacked params) covers every
assigned architecture; per-layer structure differences (gemma3 local:global,
hymba SWA/global) are carried as *data* — an int32 window per layer — so the
scanned body is uniform and the HLO stays small enough to compile 512-way.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import sc
from repro.models import layers as L

PyTree = Any


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axes, parallel to shape
    init: str = "normal"              # normal | zeros | ones | alog


# ---------------------------------------------------------------------------
# parameter templates
# ---------------------------------------------------------------------------

def _attn_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "wq": ParamSpec((d, H * dh), ("fsdp", "heads")),
        "wk": ParamSpec((d, KV * dh), ("fsdp", "heads")),
        "wv": ParamSpec((d, KV * dh), ("fsdp", "heads")),
        "wo": ParamSpec((H * dh, d), ("heads", "fsdp")),
    }


def _mla_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, H, m = cfg.d_model, cfg.n_heads, cfg.mla
    qdim = H * (m.qk_nope_dim + m.qk_rope_dim)
    t: Dict[str, ParamSpec] = {}
    if m.q_lora_rank:
        t["wq_a"] = ParamSpec((d, m.q_lora_rank), ("fsdp", None))
        t["wq_b"] = ParamSpec((m.q_lora_rank, qdim), (None, "heads"))
    else:
        t["wq"] = ParamSpec((d, qdim), ("fsdp", "heads"))
    t["w_kv_a"] = ParamSpec((d, m.kv_lora_rank + m.qk_rope_dim),
                            ("fsdp", None))
    t["kv_norm"] = ParamSpec((m.kv_lora_rank,), (None,), "zeros")
    t["w_kv_b"] = ParamSpec(
        (m.kv_lora_rank, H * (m.qk_nope_dim + m.v_head_dim)),
        (None, "heads"))
    t["wo"] = ParamSpec((H * m.v_head_dim, d), ("heads", "fsdp"))
    return t


def _mamba_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, s = cfg.d_model, cfg.ssm
    di = s.expand * d
    rank = max(16, d // 32)
    return {
        "w_in": ParamSpec((d, 2 * di), ("fsdp", "ff")),
        "conv_w": ParamSpec((di, s.d_conv), ("ff", None)),
        "conv_b": ParamSpec((di,), ("ff",), "zeros"),
        "w_dt_a": ParamSpec((di, rank), ("ff", None)),
        "w_dt_b": ParamSpec((rank, di), (None, "ff")),
        "dt_bias": ParamSpec((di,), ("ff",), "zeros"),
        "w_B": ParamSpec((di, s.d_state), ("ff", None)),
        "w_C": ParamSpec((di, s.d_state), ("ff", None)),
        "A_log": ParamSpec((di, s.d_state), ("ff", None), "alog"),
        "D": ParamSpec((di,), ("ff",), "ones"),
        "w_out": ParamSpec((di, d), ("ff", "fsdp")),
    }


def _rwkv_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_size
    dec_rank = 64
    mix = {
        **{f"mu_{n}": ParamSpec((d,), (None,), "zeros")
           for n in "rkvwg"},
        "w_r": ParamSpec((d, d), ("fsdp", "heads")),
        "w_k": ParamSpec((d, d), ("fsdp", "heads")),
        "w_v": ParamSpec((d, d), ("fsdp", "heads")),
        "w_g": ParamSpec((d, d), ("fsdp", "heads")),
        "w_o": ParamSpec((d, d), ("heads", "fsdp")),
        "w_dec_a": ParamSpec((d, dec_rank), ("fsdp", None)),
        "w_dec_b": ParamSpec((dec_rank, d), (None, "heads"), "zeros"),
        "w0": ParamSpec((d,), ("heads",), "ones"),
        "u": ParamSpec((d,), ("heads",), "zeros"),
        "ln_w": ParamSpec((H, hd), ("heads", None), "ones"),
        "ln_b": ParamSpec((H, hd), ("heads", None), "zeros"),
    }
    cmix = {
        "mu_k": ParamSpec((d,), (None,), "zeros"),
        "mu_r": ParamSpec((d,), (None,), "zeros"),
        "w_k": ParamSpec((d, ff), ("fsdp", "ff")),
        "w_v": ParamSpec((ff, d), ("ff", "fsdp")),
        "w_r": ParamSpec((d, d), ("fsdp", None)),
    }
    return {"attn": mix, "mlp": cmix}


def _mlp_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, ff), ("fsdp", "ff")),
        "w_up": ParamSpec((d, ff), ("fsdp", "ff")),
        "w_down": ParamSpec((ff, d), ("ff", "fsdp")),
    }


def _moe_template(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, e = cfg.d_model, cfg.moe
    ffe = e.d_ff_expert
    t = {
        "router": ParamSpec((d, e.n_experts), (None, None)),
        "experts": {
            "w_gate": ParamSpec((e.n_experts, d, ffe),
                                ("expert", "fsdp", "ffe")),
            "w_up": ParamSpec((e.n_experts, d, ffe),
                              ("expert", "fsdp", "ffe")),
            "w_down": ParamSpec((e.n_experts, ffe, d),
                                ("expert", "ffe", "fsdp")),
        },
    }
    if e.n_shared_experts:
        ffs = e.n_shared_experts * ffe
        t["shared"] = {
            "w_gate": ParamSpec((d, ffs), ("fsdp", "ff")),
            "w_up": ParamSpec((d, ffs), ("fsdp", "ff")),
            "w_down": ParamSpec((ffs, d), ("ff", "fsdp")),
        }
    return t


def layer_template(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    if cfg.attn_kind == "rwkv6":
        t = _rwkv_template(cfg)
    else:
        if cfg.attn_kind == "gqa":
            attn = _attn_template(cfg)
        elif cfg.attn_kind == "mla":
            attn = _mla_template(cfg)
        elif cfg.attn_kind == "hymba":
            attn = {
                "attn": _attn_template(cfg),
                "ssm": _mamba_template(cfg),
                "norm_attn": ParamSpec((d,), (None,), "zeros"),
                "norm_ssm": ParamSpec((d,), (None,), "zeros"),
            }
        else:
            raise ValueError(cfg.attn_kind)
        mlp = _moe_template(cfg) if cfg.is_moe else _mlp_template(cfg)
        t = {"attn": attn, "mlp": mlp}
    t["norm_attn"] = ParamSpec((d,), (None,), "zeros")
    t["norm_mlp"] = ParamSpec((d,), (None,), "zeros")
    return t


def model_template(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_padded
    stack = jax.tree.map(
        lambda s: ParamSpec((cfg.n_layers,) + s.shape,
                            ("layers",) + s.axes, s.init),
        layer_template(cfg), is_leaf=lambda v: isinstance(v, ParamSpec))
    t = {
        "embed": ParamSpec((V, d), ("vocab", None)),
        "final_norm": ParamSpec((d,), (None,), "zeros"),
        "layers": stack,
    }
    if not cfg.tie_embeddings:
        t["head"] = ParamSpec((d, V), (None, "vocab"))
    return t


def is_spec(v):
    """True for ParamSpec leaves — the tree-flattening is_leaf predicate
    shared with launch/specs and the planted-weight constructor."""
    return isinstance(v, ParamSpec)


def init_params(cfg: ModelConfig, key: jax.Array,
                dtype: Optional[jnp.dtype] = None) -> PyTree:
    dtype = dtype or jnp.dtype(cfg.dtype)
    tmpl = model_template(cfg)
    leaves, treedef = jax.tree.flatten(tmpl, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def make(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "alog":
            # mamba A init: log of 1..d_state per row
            ds = spec.shape[-1]
            a = jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))
            return jnp.broadcast_to(a, spec.shape).astype(jnp.float32)
        scale = 0.02
        return (jax.random.normal(k, spec.shape, jnp.float32)
                * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [make(s, k)
                                        for s, k in zip(leaves, keys)])


def param_axes(cfg: ModelConfig) -> PyTree:
    """Pytree of logical-axes tuples (same structure as params)."""
    return jax.tree.map(lambda s: s.axes, model_template(cfg),
                        is_leaf=is_spec)


def build_window_array(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention window (int32). GLOBAL_WINDOW = full attention."""
    L_ = cfg.n_layers
    w = np.full((L_,), L.GLOBAL_WINDOW, np.int32)
    if cfg.window:
        w[:] = cfg.window
        if cfg.global_every:
            w[cfg.global_every - 1::cfg.global_every] = L.GLOBAL_WINDOW
        for g in cfg.global_layers:
            w[g] = L.GLOBAL_WINDOW
        if not cfg.global_every and not cfg.global_layers:
            pass  # uniform sliding window
    return w


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens=None, embeds=None):
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.attn_kind != "rwkv6":
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype) \
            if cfg.name.startswith("gemma3") else x
    return sc(x, ("batch", "seq", "embed"))


def _layer_full(cfg: ModelConfig, p, x, window, positions, collect_cache,
                collect_hidden: bool = False):
    """One layer, full-sequence. Returns (x, cache_slice_or_None)."""
    h = L.rms_norm(x, p["norm_attn"], cfg.norm_eps)
    cache = None
    if cfg.attn_kind == "gqa":
        attn_out, (k, v) = L.gqa_attn_full(p["attn"], h, cfg, window,
                                           positions)
        if collect_cache:
            cache = {"k": k, "v": v}
    elif cfg.attn_kind == "mla":
        attn_out, (ckv, krope) = L.mla_attn_full(p["attn"], h, cfg, window,
                                                 positions)
        if collect_cache:
            cache = {"c_kv": ckv, "k_rope": krope}
    elif cfg.attn_kind == "hymba":
        attn_out, (k, v), (conv, ssm) = L.hymba_mix_full(
            p["attn"], h, cfg, window, positions)
        if collect_cache:
            cache = {"k": k, "v": v, "conv": conv, "ssm": ssm}
    elif cfg.attn_kind == "rwkv6":
        attn_out, (wkv, tm_prev) = L.rwkv6_mix_full(p["attn"], h, cfg)
        if collect_cache:
            cache = {"wkv": wkv, "tm_prev": tm_prev}
    else:
        raise ValueError(cfg.attn_kind)
    if collect_hidden and cache is not None:
        cache["h"] = h            # post-norm layer input (EA calibration)
    x = x + sc(attn_out, ("batch", "seq", "embed"))

    h2 = L.rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    if cfg.attn_kind == "rwkv6":
        h2_prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        mlp_out = L.rwkv_channel_mix(p["mlp"], h2, h2_prev)
        if collect_cache:
            cache["cm_prev"] = h2[:, -1]
    elif cfg.is_moe:
        mlp_out = L.moe_mlp(p["mlp"], h2, cfg)
    else:
        mlp_out = L.swiglu_mlp(p["mlp"], h2)
    x = x + sc(mlp_out, ("batch", "seq", "embed"))
    return x, cache


def forward(params, cfg: ModelConfig, tokens=None, embeds=None,
            remat: bool = False, collect_cache: bool = False,
            collect_hidden: bool = False, remat_policy: str = "none"):
    """Full-sequence forward. Returns (logits, caches_or_None).

    caches: pytree with per-layer leading dim L (stacked by the layer scan);
    sequence-indexed leaves have length S (pad to store size happens in
    ``prefill``).
    """
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    x = _embed(params, cfg, tokens, embeds)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    windows = jnp.asarray(build_window_array(cfg))

    def body(x, scanned):
        p, window = scanned
        x, cache = _layer_full(cfg, p, x, window, positions, collect_cache,
                               collect_hidden)
        return x, cache

    if remat:
        policies = {
            "none": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        }
        body = jax.checkpoint(body, policy=policies[remat_policy])

    x, caches = lax.scan(body, x, (params["layers"], windows))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = sc(x @ head, ("batch", "seq", "vocab"))
    return logits, caches


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None, quant: bool = False) -> PyTree:
    """quant=True: int8 KV entries + per-(position, head) f32 scales —
    halves decode cache traffic/footprint vs bf16 (beyond-paper opt;
    EXPERIMENTS §Perf)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    Ln = cfg.n_layers
    c: Dict[str, Any] = {
        "lengths": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.attn_kind in ("gqa", "hymba"):
        kv_shape = (Ln, batch, max_len, cfg.n_kv_heads, cfg.d_head)
        kv_dtype = jnp.int8 if quant else dtype
        c["k"] = jnp.zeros(kv_shape, kv_dtype)
        c["v"] = jnp.zeros(kv_shape, kv_dtype)
        if quant:
            s_shape = (Ln, batch, max_len, cfg.n_kv_heads)
            c["k_scale"] = jnp.zeros(s_shape, jnp.float32)
            c["v_scale"] = jnp.zeros(s_shape, jnp.float32)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        c["c_kv"] = jnp.zeros((Ln, batch, max_len, m.kv_lora_rank), dtype)
        c["k_rope"] = jnp.zeros((Ln, batch, max_len, m.qk_rope_dim), dtype)
    if cfg.attn_kind == "hymba":
        di = cfg.ssm.expand * cfg.d_model
        c["conv"] = jnp.zeros((Ln, batch, cfg.ssm.d_conv - 1, di), dtype)
        c["ssm"] = jnp.zeros((Ln, batch, di, cfg.ssm.d_state), jnp.float32)
    if cfg.attn_kind == "rwkv6":
        H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_size
        c["wkv"] = jnp.zeros((Ln, batch, H, hd, hd), jnp.float32)
        c["tm_prev"] = jnp.zeros((Ln, batch, cfg.d_model), dtype)
        c["cm_prev"] = jnp.zeros((Ln, batch, cfg.d_model), dtype)
    return c


def cache_axes(cfg: ModelConfig, quant: bool = False) -> PyTree:
    a: Dict[str, Any] = {"lengths": ("cache_batch",)}
    if cfg.attn_kind in ("gqa", "hymba"):
        kv = ("layers", "cache_batch", "cache_seq", "kv_heads", None)
        a["k"] = kv
        a["v"] = kv
        if quant:
            a["k_scale"] = kv[:-1]
            a["v_scale"] = kv[:-1]
    if cfg.attn_kind == "mla":
        a["c_kv"] = ("layers", "cache_batch", "cache_seq", None)
        a["k_rope"] = ("layers", "cache_batch", "cache_seq", None)
    if cfg.attn_kind == "hymba":
        a["conv"] = ("layers", "cache_batch", None, "ff")
        a["ssm"] = ("layers", "cache_batch", "ff", None)
    if cfg.attn_kind == "rwkv6":
        a["wkv"] = ("layers", "cache_batch", "heads", None, None)
        a["tm_prev"] = ("layers", "cache_batch", None)
        a["cm_prev"] = ("layers", "cache_batch", None)
    return a


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None,
            max_len: Optional[int] = None, lengths=None):
    """Run the full prompt, return (last_logits, cache).

    tokens/embeds are right-padded to S; ``lengths`` (B,) gives true lengths
    (defaults to S). Cache arrays are padded to ``max_len`` (default S).
    """
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    max_len = max_len or S
    logits, caches = forward(params, cfg, tokens, embeds, collect_cache=True)
    lengths = (jnp.full((B,), S, jnp.int32) if lengths is None
               else lengths.astype(jnp.int32))
    cache = init_cache(cfg, B, max_len,
                       dtype=jnp.dtype(cfg.dtype))
    cache["lengths"] = lengths
    for name in ("k", "v", "c_kv", "k_rope"):
        if name in cache:
            src = caches[name]                    # (L,B,S,·,·) seq at axis 2
            cache[name] = lax.dynamic_update_slice_in_dim(
                cache[name], src.astype(cache[name].dtype), 0, axis=2)
    for name in ("conv", "ssm", "wkv", "tm_prev", "cm_prev"):
        if name in cache:
            cache[name] = caches[name].astype(cache[name].dtype)
    # last *valid* position logits per item
    idx = jnp.clip(lengths - 1, 0, S - 1)
    last = jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return last, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _insert_seq(buf, new, pos, uniform: bool):
    """Insert new (B,1,...) at per-item seq position pos (B,) into
    buf (B,S,...)."""
    if uniform:
        return lax.dynamic_update_slice_in_dim(buf, new, pos[0], axis=1)
    B = buf.shape[0]
    return buf.at[jnp.arange(B), pos].set(new[:, 0])


def decode_step(params, cfg: ModelConfig, cache, tokens=None, embeds=None,
                uniform_pos: bool = False, kernels=None):
    """One decode step. tokens: (B, 1) int32 (or embeds (B, 1, d)).

    Returns (logits (B, V), new_cache). The new token sits at position
    cache["lengths"]; lengths are incremented. `kernels` selects the
    attention backend (None defers to STRETTO_KERNELS).
    """
    pos = cache["lengths"]                        # (B,)
    new_len = pos + 1
    x = _embed(params, cfg, tokens, embeds)       # (B,1,d)
    windows = jnp.asarray(build_window_array(cfg))

    scan_cache = {k: v for k, v in cache.items() if k != "lengths"}

    def body(x, scanned):
        p, window, c = scanned
        h = L.rms_norm(x, p["norm_attn"], cfg.norm_eps)
        new_c = dict(c)
        if cfg.attn_kind in ("gqa", "hymba"):
            ap = p["attn"]["attn"] if cfg.attn_kind == "hymba" else p["attn"]
            k_new, v_new = L.gqa_new_kv(ap, h, cfg, new_len)
            quant = "k_scale" in c
            if quant:
                # int8 KV: per-(token, head) absmax scales
                ks = jnp.max(jnp.abs(k_new.astype(jnp.float32)), -1) / 127.0
                vs = jnp.max(jnp.abs(v_new.astype(jnp.float32)), -1) / 127.0
                k_q = jnp.round(k_new / jnp.maximum(ks, 1e-9)[..., None]
                                ).astype(jnp.int8)
                v_q = jnp.round(v_new / jnp.maximum(vs, 1e-9)[..., None]
                                ).astype(jnp.int8)
                new_c["k"] = _insert_seq(c["k"], k_q, pos, uniform_pos)
                new_c["v"] = _insert_seq(c["v"], v_q, pos, uniform_pos)
                new_c["k_scale"] = _insert_seq(c["k_scale"], ks, pos,
                                               uniform_pos)
                new_c["v_scale"] = _insert_seq(c["v_scale"], vs, pos,
                                               uniform_pos)
                k_att, v_att = new_c["k"], new_c["v"]
                k_sc, v_sc = new_c["k_scale"], new_c["v_scale"]
            else:
                new_c["k"] = _insert_seq(c["k"], k_new.astype(c["k"].dtype),
                                         pos, uniform_pos)
                new_c["v"] = _insert_seq(c["v"], v_new.astype(c["v"].dtype),
                                         pos, uniform_pos)
                k_att, v_att = new_c["k"], new_c["v"]
                k_sc = v_sc = None
            if cfg.attn_kind == "gqa":
                # int8 caches flow through with their scales; the kernel
                # (or the ref oracle) dequantizes
                attn_out = L.gqa_attn_decode(p["attn"], h, cfg, window,
                                             k_att, v_att, new_len,
                                             kernels=kernels,
                                             k_scale=k_sc, v_scale=v_sc)
            else:
                if quant:
                    # hymba's mixer is not int8-aware; dequantize up front
                    k_att = (k_att.astype(jnp.bfloat16)
                             * k_sc[..., None].astype(jnp.bfloat16))
                    v_att = (v_att.astype(jnp.bfloat16)
                             * v_sc[..., None].astype(jnp.bfloat16))
                attn_out, new_conv, new_ssm = L.hymba_mix_decode(
                    p["attn"], h, cfg, window, k_att, v_att,
                    new_len, c["conv"], c["ssm"], kernels=kernels)
                new_c["conv"] = new_conv.astype(c["conv"].dtype)
                new_c["ssm"] = new_ssm
        elif cfg.attn_kind == "mla":
            ckv_new, krope_new = L.mla_latents(p["attn"], h, cfg,
                                               (new_len - 1)[:, None])
            new_c["c_kv"] = _insert_seq(
                c["c_kv"], ckv_new.astype(c["c_kv"].dtype), pos, uniform_pos)
            new_c["k_rope"] = _insert_seq(
                c["k_rope"], krope_new.astype(c["k_rope"].dtype), pos,
                uniform_pos)
            attn_out = L.mla_attn_decode(p["attn"], h, cfg, window,
                                         new_c["c_kv"], new_c["k_rope"],
                                         new_len)
        elif cfg.attn_kind == "rwkv6":
            attn_out, new_wkv, new_tm = L.rwkv6_mix_step(
                p["attn"], h, cfg, c["wkv"], c["tm_prev"])
            new_c["wkv"] = new_wkv
            new_c["tm_prev"] = new_tm.astype(c["tm_prev"].dtype)
        else:
            raise ValueError(cfg.attn_kind)
        x = x + attn_out

        h2 = L.rms_norm(x, p["norm_mlp"], cfg.norm_eps)
        if cfg.attn_kind == "rwkv6":
            mlp_out = L.rwkv_channel_mix(p["mlp"], h2,
                                         c["cm_prev"][:, None, :])
            new_c["cm_prev"] = h2[:, 0].astype(c["cm_prev"].dtype)
        elif cfg.is_moe:
            mlp_out = L.moe_mlp(p["mlp"], h2, cfg)
        else:
            mlp_out = L.swiglu_mlp(p["mlp"], h2)
        x = x + mlp_out
        return x, new_c

    x, new_scan_cache = lax.scan(body, x, (params["layers"], windows,
                                           scan_cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ head)[:, 0]
    new_cache = dict(new_scan_cache)
    new_cache["lengths"] = new_len
    return logits, new_cache


def supports_fused_decode(cfg: ModelConfig) -> bool:
    """Fused multi-token decode covers pure-attention caches only; mixer
    archs (hymba/mamba/rwkv) carry sequential recurrent state."""
    return cfg.attn_kind == "gqa"


def decode_multi(params, cfg: ModelConfig, cache, tokens=None, embeds=None,
                 kernels=None):
    """Fused multi-token decode: feed all Lq query tokens in ONE forward
    pass — one attention dispatch per layer instead of Lq sequential
    decode_step scans. tokens: (B, Lq) int32 (or embeds (B, Lq, d)).

    Returns (logits (B, V) for the LAST query token, new_cache). All Lq
    k/v land in the cache at positions lengths .. lengths+Lq-1 and
    attention is causally masked per query token inside the kernel, so
    the logits match the sequential scan (up to float reassociation).
    GQA-only; see supports_fused_decode.
    """
    if not supports_fused_decode(cfg):
        raise ValueError(
            f"decode_multi supports attn_kind='gqa' only, got "
            f"{cfg.attn_kind!r}")
    pos0 = cache["lengths"]                       # (B,)
    x = _embed(params, cfg, tokens, embeds)       # (B, Lq, d)
    B, Lq, _ = x.shape
    new_len = pos0 + Lq
    positions = pos0[:, None] + jnp.arange(Lq)[None, :]
    bidx = jnp.arange(B)[:, None]
    windows = jnp.asarray(build_window_array(cfg))

    scan_cache = {k: v for k, v in cache.items() if k != "lengths"}

    def body(x, scanned):
        p, window, c = scanned
        h = L.rms_norm(x, p["norm_attn"], cfg.norm_eps)
        new_c = dict(c)
        k_new, v_new = L.gqa_new_kv_multi(p["attn"], h, cfg, positions)
        quant = "k_scale" in c
        if quant:
            ks = jnp.max(jnp.abs(k_new.astype(jnp.float32)), -1) / 127.0
            vs = jnp.max(jnp.abs(v_new.astype(jnp.float32)), -1) / 127.0
            k_q = jnp.round(k_new / jnp.maximum(ks, 1e-9)[..., None]
                            ).astype(jnp.int8)
            v_q = jnp.round(v_new / jnp.maximum(vs, 1e-9)[..., None]
                            ).astype(jnp.int8)
            new_c["k"] = c["k"].at[bidx, positions].set(k_q)
            new_c["v"] = c["v"].at[bidx, positions].set(v_q)
            new_c["k_scale"] = c["k_scale"].at[bidx, positions].set(ks)
            new_c["v_scale"] = c["v_scale"].at[bidx, positions].set(vs)
            k_sc, v_sc = new_c["k_scale"], new_c["v_scale"]
        else:
            new_c["k"] = c["k"].at[bidx, positions].set(
                k_new.astype(c["k"].dtype))
            new_c["v"] = c["v"].at[bidx, positions].set(
                v_new.astype(c["v"].dtype))
            k_sc = v_sc = None
        attn_out = L.gqa_attn_decode_multi(
            p["attn"], h, cfg, window, new_c["k"], new_c["v"], new_len,
            kernels=kernels, k_scale=k_sc, v_scale=v_sc)
        x = x + attn_out
        h2 = L.rms_norm(x, p["norm_mlp"], cfg.norm_eps)
        mlp_out = (L.moe_mlp(p["mlp"], h2, cfg) if cfg.is_moe
                   else L.swiglu_mlp(p["mlp"], h2))
        x = x + mlp_out
        return x, new_c

    x, new_scan_cache = lax.scan(body, x, (params["layers"], windows,
                                           scan_cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ head)[:, -1]
    new_cache = dict(new_scan_cache)
    new_cache["lengths"] = new_len
    return logits, new_cache
