"""Layer primitives for the model zoo.

Pure-functional JAX. All mixers share the conventions:
  - activations  x: (B, S, d_model), compute dtype = cfg.dtype (bf16 default)
  - reductions (softmax / norm / recurrent state) run in f32
  - full-sequence paths never materialize (S, S) score matrices: attention is
    blocked with an online softmax (flash-style) so the 32k prefill shapes fit
  - decode paths take a cache pytree and a scalar-or-vector position

The per-layer window size is *data* (an int32 scalar per layer), which lets a
single `lax.scan` over layers express gemma3's 5:1 local:global pattern and
hymba's mixed SWA/global layout. A "global" layer simply carries window=2^30.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ops as KOPS

GLOBAL_WINDOW = 1 << 30   # sentinel: effectively unbounded window

# perf-iteration knobs (set by launch.dryrun --opt ...; see EXPERIMENTS §Perf)
FLASH_BLOCK = 512
MOE_IMPL = "auto"


# ---------------------------------------------------------------------------
# small utilities
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def rope_freqs(d: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, d_head); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]                           # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# blocked (flash-style) attention — pure jnp oracle used for train / prefill
# ---------------------------------------------------------------------------

def _divisor_block(n: int, target: int) -> int:
    """Largest block size <= target that divides n."""
    b = min(target, n)
    while n % b:
        b -= 1
    return b


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: jax.Array, *, block_q: int = 512,
                    block_k: int = 512, causal: bool = True,
                    q_offset: int = 0) -> jax.Array:
    """Blocked causal/windowed attention with online softmax.

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh); GQA groups = H // KV.
    window: int32 scalar (traced ok) — attend to [i - window + 1, i].
    Never materializes (Sq, Sk). Rectangle schedule: every (qi, kj) block pair
    is computed and masked; the triangular schedule is a perf iteration
    (see kernels/ and EXPERIMENTS §Perf).
    """
    B, Sq, H, dh = q.shape
    _, Sk, KV, _ = k.shape
    dv = v.shape[-1]
    G = H // KV
    block_q = _divisor_block(Sq, block_q)
    block_k = _divisor_block(Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    qg = q.reshape(B, Sq, KV, G, dh)
    scale = dh ** -0.5

    def q_block_body(_, qi):
        q_blk = lax.dynamic_slice_in_dim(qg, qi * block_q, block_q, axis=1)
        q_blk = (q_blk.astype(jnp.float32) * scale)
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk = lax.dynamic_slice_in_dim(k, kj * block_k, block_k, axis=1)
            v_blk = lax.dynamic_slice_in_dim(v, kj * block_k, block_k, axis=1)
            k_pos = kj * block_k + jnp.arange(block_k)
            # scores: (B, KV, G, bq, bk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk,
                           k_blk.astype(jnp.float32))
            mask = k_pos[None, :] <= q_pos[:, None] if causal else True
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, block_q, dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return (), out.astype(q.dtype)                 # (B, KV, G, bq, dh)

    _, blocks = lax.scan(q_block_body, (), jnp.arange(nq))
    # blocks: (nq, B, KV, G, bq, dv) -> (B, Sq, H, dv)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, KV, G, Sq, dv)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, H, dv)
    return out


# ---------------------------------------------------------------------------
# GQA attention layer (granite / gemma3 / minitron / llava / musicgen / dbrx)
# ---------------------------------------------------------------------------

def gqa_project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, KV, dh)
    v = (x @ p["wv"]).reshape(B, S, KV, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attn_full(p, x, cfg: ModelConfig, window, positions):
    """Train/prefill path. Returns (attn_out, (k, v)) — caller may cache k/v."""
    q, k, v = gqa_project_qkv(p, x, cfg, positions)
    out = flash_attention(q, k, v, window, block_q=FLASH_BLOCK,
                          block_k=FLASH_BLOCK)
    B, S, _, _ = q.shape
    out = out.reshape(B, S, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], (k, v)


def gqa_attn_decode(p, x, cfg: ModelConfig, window, cache_k, cache_v,
                    lengths, *, kernels=None, k_scale=None, v_scale=None):
    """x: (B, 1, d). cache_[kv]: (B, S, KV, dh) already containing this step's
    k/v at position lengths-1 (the caller updates the cache first).

    Routed through kernels.ops.decode_attention: `kernels` selects the
    attention backend (auto/pallas/interpret/ref; None defers to
    STRETTO_KERNELS). int8 caches pass their per-token scales through and
    are dequantized inside the kernel."""
    B = x.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    positions = (lengths - 1)[:, None]
    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    q = apply_rope(q, positions, cfg.rope_theta)[:, 0]
    q = q.reshape(B, KV, H // KV, dh)
    out = KOPS.decode_attention(q, cache_k, cache_v, lengths, window=window,
                                backend=kernels, k_scale=k_scale,
                                v_scale=v_scale)
    return out.reshape(B, 1, H * dh) @ p["wo"]


def gqa_attn_decode_multi(p, x, cfg: ModelConfig, window, cache_k, cache_v,
                          lengths, *, kernels=None, k_scale=None,
                          v_scale=None):
    """Fused multi-token decode: x: (B, Lq, d), one attention dispatch for
    all Lq query tokens. cache_[kv] already contains the Lq new k/v
    (positions lengths-Lq .. lengths-1); masking inside the kernel is
    causal per query token, so this matches the sequential scan."""
    B, Lq, _ = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    positions = lengths[:, None] - Lq + jnp.arange(Lq)[None, :]
    q = (x @ p["wq"]).reshape(B, Lq, H, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    q = q.reshape(B, Lq, KV, H // KV, dh)
    out = KOPS.decode_query_attention(q, cache_k, cache_v, lengths,
                                      window=window, backend=kernels,
                                      k_scale=k_scale, v_scale=v_scale)
    return out.reshape(B, Lq, H * dh) @ p["wo"]


def gqa_new_kv(p, x, cfg: ModelConfig, lengths):
    """Project this step's k/v for cache insertion. x: (B, 1, d)."""
    B = x.shape[0]
    positions = (lengths - 1)[:, None]
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
    k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def gqa_new_kv_multi(p, x, cfg: ModelConfig, positions):
    """Project Lq steps' k/v for bulk cache insertion. x: (B, Lq, d),
    positions: (B, Lq) absolute positions of the query tokens."""
    B, Lq, _ = x.shape
    k = (x @ p["wk"]).reshape(B, Lq, cfg.n_kv_heads, cfg.d_head)
    v = (x @ p["wv"]).reshape(B, Lq, cfg.n_kv_heads, cfg.d_head)
    k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


# ---------------------------------------------------------------------------
# MLA attention (minicpm3 / deepseek-v2-lite)
# ---------------------------------------------------------------------------
# Cache layout is the *latent* stream: c_kv (B, S, kv_lora) + k_rope
# (B, S, qk_rope_dim) — this is what Stretto's compression ladder operates on
# for MLA archs (Expected-Attention scores over latent rows).

def mla_project_q(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    if m.q_lora_rank:
        q = (x @ p["wq_a"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_latents(p, x, cfg: ModelConfig, positions):
    """Latent stream for caching: c_kv (B,S,r), k_rope (B,S,rope)."""
    m = cfg.mla
    ckv_rope = x @ p["w_kv_a"]                       # (B,S, r + rope)
    c_kv, k_rope = jnp.split(ckv_rope, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions,
                        cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_attn_full(p, x, cfg: ModelConfig, window, positions):
    """Naive (non-absorbed) MLA for train/prefill: expand K/V per head."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = mla_project_q(p, x, cfg, positions)
    c_kv, k_rope = mla_latents(p, x, cfg, positions)
    kv = (c_kv @ p["w_kv_b"]).reshape(B, S, H, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_dim))], axis=-1)
    out = flash_attention(q, k, v, window, block_q=FLASH_BLOCK,
                          block_k=FLASH_BLOCK)
    out = out.reshape(B, S, H * m.v_head_dim)
    return out @ p["wo"], (c_kv, k_rope)


def mla_attn_decode(p, x, cfg: ModelConfig, window, cache_ckv, cache_krope,
                    lengths):
    """Absorbed MLA decode: MQA over the latent cache (no K/V expansion).

    score_h(t,s) = q_nope_h W_uk_h · c_kv_s + q_rope_h · k_rope_s
    out_h       = (softmax · c_kv) W_uv_h
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    positions = (lengths - 1)[:, None]
    q_nope, q_rope = mla_project_q(p, x, cfg, positions)     # (B,1,H,·)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]              # (B,H,·)
    w_kv_b = p["w_kv_b"].reshape(m.kv_lora_rank, H,
                                 m.qk_nope_dim + m.v_head_dim)
    w_uk = w_kv_b[..., :m.qk_nope_dim]                       # (r, H, nope)
    w_uv = w_kv_b[..., m.qk_nope_dim:]                       # (r, H, v)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))             # (B,H,r)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_lat,
                    cache_ckv.astype(jnp.float32))
         + jnp.einsum("bhp,bsp->bhs", q_rope.astype(jnp.float32),
                      cache_krope.astype(jnp.float32))) * scale
    S = cache_ckv.shape[1]
    pos = jnp.arange(S)[None, :]
    mask = (pos < lengths[:, None]) & ((lengths - 1)[:, None] - pos < window)
    s = jnp.where(mask[:, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# dense SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu_mlp(p, x):
    return (silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE MLP — GShard-style dense capacity dispatch (default; shards cleanly as
# all-to-all under EP) and a scatter-based dispatch (perf alternative for
# fine-grained experts; see EXPERIMENTS §Perf).
# ---------------------------------------------------------------------------

def moe_mlp(p, x, cfg: ModelConfig, impl: Optional[str] = None):
    """MoE feed-forward. Two dispatch strategies:

    - "dense": GShard-style one-hot dispatch/combine einsums. Shards
      cleanly (all-to-all under EP) but builds a (T, E, C) tensor —
      O(T^2 k cf d / E) FLOPs and memory. Only viable for small T.
    - "scatter": cumsum position assignment + scatter into per-expert
      buffers — exact expert FLOPs, O(T k d) traffic. The default for
      long sequences (prefill_32k would need a 400+ GB dispatch tensor
      under "dense"; see EXPERIMENTS.md §Perf).
    """
    e = cfg.moe
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    T = B * S
    impl = impl or MOE_IMPL
    if impl == "auto":
        impl = "dense" if T <= 8192 else "scatter"
    logits = (x_flat @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = lax.top_k(probs, e.top_k)                 # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = int(max(4, e.capacity_factor * e.top_k * T / e.n_experts))
    capacity = min(capacity, T)

    if impl == "dense":
        # one-hot dispatch/combine einsums (GShard / Switch style)
        onehot = jax.nn.one_hot(idx, e.n_experts, dtype=jnp.float32)  # (T,k,E)
        # position of each (token, slot) within its expert
        pos = (jnp.cumsum(onehot.reshape(T * e.top_k, e.n_experts), axis=0)
               - onehot.reshape(T * e.top_k, e.n_experts))
        pos = pos.reshape(T, e.top_k, e.n_experts)
        keep = (pos < capacity) & (onehot > 0)
        pos_kept = jnp.where(keep, pos, 0).sum(-1).astype(jnp.int32)  # (T,k)
        keep_tok = keep.any(-1)                                        # (T,k)
        cap_oh = jax.nn.one_hot(pos_kept, capacity, dtype=jnp.float32)
        disp = jnp.einsum("tke,tkc,tk->tec", onehot, cap_oh,
                          keep_tok.astype(jnp.float32))                # (T,E,C)
        comb = jnp.einsum("tec,tke,tk->tec", disp, onehot,
                          gate_vals.astype(jnp.float32))
        xin = jnp.einsum("tec,td->ecd", disp, x_flat.astype(jnp.float32))
        xin = xin.astype(x.dtype)
        h = silu(jnp.einsum("ecd,edf->ecf", xin, p["experts"]["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xin, p["experts"]["w_up"])
        eo = jnp.einsum("ecf,efd->ecd", h, p["experts"]["w_down"])
        y = jnp.einsum("tec,ecd->td", comb, eo.astype(jnp.float32))
        y = y.astype(x.dtype)
    else:
        # row-local scatter dispatch: positions/capacity are computed per
        # batch row (GShard "groups"), so with batch sharded over data the
        # cumsum and scatters stay device-local — no global cumsum gather,
        # no replicated expert-buffer all-reduce (EXPERIMENTS §Perf). The
        # expert matmul shards E over `model`; the only collective left is
        # the standard combine all-reduce of (B_local, S, d).
        k = e.top_k
        cap = int(max(4, e.capacity_factor * k * S / e.n_experts))
        cap = min(cap, S * k)
        idx_r = idx.reshape(B, S * k)                          # (B, S*k)
        gate_r = gate_vals.reshape(B, S * k)
        oh = jax.nn.one_hot(idx_r, e.n_experts, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=1) - oh                      # row-local
        pos = (pos * oh).sum(-1)                               # (B, S*k)
        keep = pos < cap
        safe_pos = jnp.where(keep, pos, cap - 1)
        # dispatch = tiny int32 scatter (slot -> source token) followed by
        # a gather of token rows. Both use *_along_axis so they lower to
        # batched scatter/gather ops that XLA SPMD shards over `data`;
        # fancy-indexed variants were replicated across the global batch.
        # Dropped tokens go to a dump slot (index cap) that is sliced off.
        src_tok = jnp.broadcast_to(
            jnp.arange(S * k, dtype=jnp.int32)[None, :] // k, (B, S * k))
        scat_idx = idx_r * (cap + 1) + jnp.where(keep, safe_pos, cap)
        slot_flat = jnp.full((B, e.n_experts * (cap + 1)), -1, jnp.int32)
        slot_flat = jnp.put_along_axis(slot_flat, scat_idx, src_tok,
                                       axis=1, inplace=False)
        slot_tok = slot_flat.reshape(B, e.n_experts, cap + 1)[:, :, :cap]
        valid = slot_tok >= 0
        # take_along_axis lowers to gathers with explicit batch dims, which
        # XLA SPMD shards over `data`; fancy-indexed gathers were treated
        # as unbatched and replicated the global batch (§Perf B3)
        flat_slot = jnp.clip(slot_tok, 0, S - 1).reshape(B, -1)
        buf = jnp.take_along_axis(x, flat_slot[..., None], axis=1)
        buf = buf.reshape(B, e.n_experts, cap, d)              # (B,E,C,d)
        buf = jnp.where(valid[..., None], buf, 0)
        h = silu(jnp.einsum("becd,edf->becf", buf,
                            p["experts"]["w_gate"])) \
            * jnp.einsum("becd,edf->becf", buf, p["experts"]["w_up"])
        eo = jnp.einsum("becf,efd->becd", h, p["experts"]["w_down"])
        comb_idx = (idx_r * cap + safe_pos)                    # (B, S*k)
        rows = jnp.take_along_axis(
            eo.reshape(B, e.n_experts * cap, d),
            comb_idx[..., None], axis=1)                       # (B, S*k, d)
        w = jnp.where(keep, gate_r, 0.0)
        y = (rows.astype(jnp.float32) * w[..., None]).reshape(
            B, S, k, d).sum(2).astype(x.dtype)
        return (y + (swiglu_mlp(p["shared"], x.reshape(B * S, d))
                     .reshape(B, S, d) if e.n_shared_experts else 0.0))

    if e.n_shared_experts:
        y = y + swiglu_mlp(p["shared"], x_flat)
    return y.reshape(B, S, d)


# ---------------------------------------------------------------------------
# Mamba mixer (hymba's SSM heads). Sequential scan over time (TPU kernel is
# the chunked form; this jnp path keeps peak memory at O(B·d_inner·d_state)).
# ---------------------------------------------------------------------------

def mamba_mix_full(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d). Returns (out, (conv_state, final_state))."""
    s = cfg.ssm
    B, S, d = x.shape
    di = s.expand * d
    xz = x @ p["w_in"]                                   # (B,S,2*di)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi_raw = xi
    # depthwise causal conv, kernel (di, d_conv)
    pad = jnp.pad(xi, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    idx = jnp.arange(S)[:, None] + jnp.arange(s.d_conv)[None, :]
    windows = pad[:, idx]                                # (B,S,K,di)
    xi = silu(jnp.einsum("bskd,dk->bsd", windows, p["conv_w"]) + p["conv_b"])
    dt = jax.nn.softplus((xi @ p["w_dt_a"]) @ p["w_dt_b"] + p["dt_bias"])
    Bm = xi @ p["w_B"]                                   # (B,S,ds)
    Cm = xi @ p["w_C"]                                   # (B,S,ds)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # (di,ds)

    def step(h, inp):
        xi_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None].astype(jnp.float32) * A)        # (B,di,ds)
        dBx = (dt_t * xi_t)[..., None] * B_t[:, None, :]             # (B,di,ds)
        h = h * dA + dBx.astype(jnp.float32)
        y = jnp.einsum("bds,bs->bd", h, C_t.astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((B, di, s.d_state), jnp.float32)
    xs = (jnp.moveaxis(xi, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h_final, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)           # (B,S,di)
    y = y + xi * p["D"]
    y = y * silu(z)
    conv_state = jnp.pad(xi_raw, ((0, 0), (s.d_conv - 1, 0), (0, 0))
                         )[:, S:S + s.d_conv - 1]        # last K-1 pre-conv xi
    return y @ p["w_out"], (conv_state, h_final)


def mamba_mix_step(p, x, cfg: ModelConfig, conv_state, ssm_state):
    """Decode step. x: (B, 1, d). conv_state: (B, d_conv-1, di),
    ssm_state: (B, di, ds) f32."""
    s = cfg.ssm
    B, _, d = x.shape
    di = s.expand * d
    xz = x[:, 0] @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)
    hist = jnp.concatenate([conv_state, xi[:, None, :]], axis=1)  # (B,K,di)
    new_conv = hist[:, 1:]
    xi = silu(jnp.einsum("bkd,dk->bd", hist, p["conv_w"]) + p["conv_b"])
    dt = jax.nn.softplus((xi @ p["w_dt_a"]) @ p["w_dt_b"] + p["dt_bias"])
    B_t = xi @ p["w_B"]
    C_t = xi @ p["w_C"]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)
    dBx = (dt * xi)[..., None] * B_t[:, None, :]
    h = ssm_state * dA + dBx.astype(jnp.float32)
    y = jnp.einsum("bds,bs->bd", h, C_t.astype(jnp.float32)).astype(x.dtype)
    y = y + xi * p["D"]
    y = y * silu(z)
    return (y @ p["w_out"])[:, None, :], new_conv, h


# ---------------------------------------------------------------------------
# Hymba layer: parallel attention heads + mamba heads, outputs mean-fused
# after per-branch RMSNorm (arXiv:2411.13676).
# ---------------------------------------------------------------------------

def hymba_mix_full(p, x, cfg: ModelConfig, window, positions):
    attn_out, kv = gqa_attn_full(p["attn"], x, cfg, window, positions)
    ssm_out, ssm_states = mamba_mix_full(p["ssm"], x, cfg)
    out = 0.5 * (rms_norm(attn_out, p["norm_attn"], cfg.norm_eps)
                 + rms_norm(ssm_out, p["norm_ssm"], cfg.norm_eps))
    return out, kv, ssm_states


def hymba_mix_decode(p, x, cfg: ModelConfig, window, cache_k, cache_v,
                     lengths, conv_state, ssm_state, *, kernels=None):
    attn_out = gqa_attn_decode(p["attn"], x, cfg, window, cache_k, cache_v,
                               lengths, kernels=kernels)
    ssm_out, new_conv, new_ssm = mamba_mix_step(p["ssm"], x, cfg,
                                                conv_state, ssm_state)
    out = 0.5 * (rms_norm(attn_out, p["norm_attn"], cfg.norm_eps)
                 + rms_norm(ssm_out, p["norm_ssm"], cfg.norm_eps))
    return out, new_conv, new_ssm


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay linear recurrence.
# Train path: chunked-parallel form (GLA-style) — O(T/C) state updates.
# Decode path: O(1) state update.
# ---------------------------------------------------------------------------

RWKV_CHUNK = 32
_LOGW_MIN = -8.0 / RWKV_CHUNK   # per-step log-decay clamp for chunk stability


def _rwkv_projections(p, x, x_prev):
    """Token-shifted projections. x: (B,S,d); x_prev: (B,S,d) shifted."""
    sx = x_prev - x
    xr = x + sx * p["mu_r"]
    xk = x + sx * p["mu_k"]
    xv = x + sx * p["mu_v"]
    xw = x + sx * p["mu_w"]
    xg = x + sx * p["mu_g"]
    r = xr @ p["w_r"]
    k = xk @ p["w_k"]
    v = xv @ p["w_v"]
    g = silu(xg @ p["w_g"])
    # data-dependent decay (low-rank): w in (0,1), log clamped for chunking
    logw = -jnp.exp(
        p["w0"] + jnp.tanh(xw @ p["w_dec_a"]) @ p["w_dec_b"]).astype(
        jnp.float32)
    logw = jnp.clip(logw, _LOGW_MIN, -1e-6)
    return r, k, v, g, logw


def rwkv6_mix_full(p, x, cfg: ModelConfig):
    """Chunked-parallel RWKV6 wkv. x: (B,S,d); S % RWKV_CHUNK == 0.
    Returns (out, (final_wkv_state, last_x))."""
    B, S, d = x.shape
    H = cfg.rwkv_n_heads
    hd = cfg.rwkv_head_size
    C = _divisor_block(S, RWKV_CHUNK)
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = _rwkv_projections(p, x, x_prev)
    u = p["u"].reshape(H, hd)

    def heads(t):  # (B,S,d) -> (B, nch, C, H, hd)
        return t.reshape(B, S // C, C, H, hd)

    r, k, v = heads(r), heads(k), heads(v)
    logw = heads(logw.astype(jnp.float32))
    # intra-chunk cumulative decay (inclusive)
    cum = jnp.cumsum(logw, axis=2)                       # (B,N,C,H,hd)
    # decayed queries / inverse-decayed keys, relative to chunk start
    r_f = r.astype(jnp.float32)
    k_f = k.astype(jnp.float32)
    v_f = v.astype(jnp.float32)
    # For wkv, state S has shape (k_dim, v_dim); decay acts on k dim.
    # out_t = r_t · diag(exp(cum_{t-1})) S_0  + intra + bonus
    cum_prev = cum - logw                                # exclusive cumsum
    rq = r_f * jnp.exp(cum_prev)
    kq = k_f * jnp.exp(-cum)
    # intra-chunk: A[t,s] = sum_d rq[t,d] kq[s,d] exp(...) for s < t
    A = jnp.einsum("bnchd,bnshd->bnhcs", rq, kq)         # (B,N,H,C,C)
    tri = jnp.tril(jnp.ones((C, C), jnp.float32), -1)
    A = A * tri
    intra = jnp.einsum("bnhcs,bnshd->bnchd", A, v_f)
    # bonus (current token): (r_t · (u ⊙ k_t)) v_t
    bonus = jnp.einsum("bnchd,hd,bnchd->bnch", r_f, u, k_f)
    intra = intra + bonus[..., None] * v_f
    # inter-chunk: scan over chunks carrying state (B,H,hd,hd)
    chunk_decay = jnp.exp(cum[:, :, -1])                 # (B,N,H,hd)
    # per-chunk key outer-products, pre-decayed to chunk end:
    k_to_end = k_f * jnp.exp(cum[:, :, -1:] - cum)       # (B,N,C,H,hd)

    def chunk_step(state, inp):
        rq_c, v_c, kte_c, dec_c, = inp
        out_c = jnp.einsum("bchd,bhdv->bchv", rq_c, state)
        new_state = state * dec_c[..., None] + jnp.einsum(
            "bchd,bchv->bhdv", kte_c, v_c)
        return new_state, out_c

    state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = (jnp.moveaxis(rq, 1, 0), jnp.moveaxis(v_f, 1, 0),
          jnp.moveaxis(k_to_end, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    state_f, inter = lax.scan(chunk_step, state0, xs)
    inter = jnp.moveaxis(inter, 0, 1)                    # (B,N,C,H,hd)
    wkv = (intra + inter).reshape(B, S, H, hd)
    # per-head groupnorm
    wkv = _headwise_norm(wkv, p["ln_w"], p["ln_b"], cfg.norm_eps)
    out = (wkv.reshape(B, S, d).astype(x.dtype) * g) @ p["w_o"]
    return out, (state_f, x[:, -1])


def rwkv6_mix_step(p, x, cfg: ModelConfig, wkv_state, x_prev):
    """Decode step. x: (B,1,d); wkv_state: (B,H,hd,hd) f32; x_prev: (B,d)."""
    B, _, d = x.shape
    H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_size
    r, k, v, g, logw = _rwkv_projections(p, x, x_prev[:, None, :])
    r = r.reshape(B, H, hd).astype(jnp.float32)
    k = k.reshape(B, H, hd).astype(jnp.float32)
    v = v.reshape(B, H, hd).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, H, hd))
    u = p["u"].reshape(H, hd)
    kv = k[..., :, None] * v[..., None, :]               # (B,H,hd,hd)
    out = jnp.einsum("bhd,bhdv->bhv", r, wkv_state + u[..., None] * kv)
    new_state = wkv_state * w[..., None] + kv
    out = out.reshape(B, 1, H, hd)
    out = _headwise_norm(out, p["ln_w"], p["ln_b"], cfg.norm_eps)
    out = (out.reshape(B, 1, d).astype(x.dtype) * g) @ p["w_o"]
    return out, new_state, x[:, 0]


def _headwise_norm(x, w, b, eps):
    """LayerNorm over the last dim (per head). x: (..., H, hd)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps) * w + b
    return out.astype(x.dtype)


def rwkv_channel_mix(p, x, x_prev):
    """RWKV channel mix (squared-relu FFN with token shift)."""
    sx = x_prev - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
