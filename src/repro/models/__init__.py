from repro.models.transformer import (build_window_array, cache_axes,
                                      decode_step, forward, init_cache,
                                      init_params, param_axes, prefill)

__all__ = ["init_params", "param_axes", "forward", "prefill", "decode_step",
           "init_cache", "cache_axes", "build_window_array"]
