from repro.models.transformer import (build_window_array, cache_axes,
                                      decode_multi, decode_step, forward,
                                      init_cache, init_params, param_axes,
                                      prefill, supports_fused_decode)

__all__ = ["init_params", "param_axes", "forward", "prefill", "decode_step",
           "decode_multi", "supports_fused_decode", "init_cache",
           "cache_axes", "build_window_array"]
