"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

GLOBAL = 1 << 30


def decode_attention_ref(q, k_cache, v_cache, lengths, *,
                         window: int = GLOBAL):
    """q: (B, KV, G, dk); k: (B, S, KV, dk); v: (B, S, KV, dv);
    lengths: (B,). Returns (B, KV, G, dv)."""
    B, KV, G, dk = q.shape
    S = k_cache.shape[1]
    qf = q.astype(jnp.float32) * dk ** -0.5
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)[None, :]
    mask = (pos < lengths[:, None]) & ((lengths - 1)[:, None] - pos < window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_query_attention_ref(q, k_cache, v_cache, lengths, *,
                               window: int = GLOBAL):
    """Fused multi-token query decode oracle.

    q: (B, Lq, KV, G, dk); k: (B, S, KV, dk); v: (B, S, KV, dv);
    lengths: (B,) counts all valid tokens INCLUDING the Lq query tokens
    (their k/v are already in the cache). Query i sits at absolute
    position lengths - Lq + i and attends causally within `window`.
    Returns (B, Lq, KV, G, dv)."""
    B, Lq, KV, G, dk = q.shape
    S = k_cache.shape[1]
    qf = q.astype(jnp.float32) * dk ** -0.5
    s = jnp.einsum("blhgd,bshd->blhgs", qf, k_cache.astype(jnp.float32))
    k_pos = jnp.arange(S)[None, None, :]
    q_pos = (lengths[:, None] - Lq + jnp.arange(Lq)[None, :])[:, :, None]
    mask = (k_pos <= q_pos) & ((q_pos - k_pos) < window)
    s = jnp.where(mask[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("blhgs,bshd->blhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def prefill_attention_ref(q, k, v, *, window: int = GLOBAL,
                          causal: bool = True):
    """q: (B, S, KV, G, dk); k: (B, S, KV, dk); v: (B, S, KV, dv)."""
    B, S, KV, G, dk = q.shape
    qf = q.astype(jnp.float32) * dk ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = (qpos - kpos) < window
    if causal:
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def expected_attention_scores_ref(k_cache, mu, sig2):
    """k: (B, S, KV, dk); mu, sig2: (KV, G, dk) -> (B, S, KV) log-scores."""
    dk = k_cache.shape[-1]
    scale = dk ** -0.5
    kf = k_cache.astype(jnp.float32)
    lin = jnp.einsum("bshd,hgd->bshg", kf, mu.astype(jnp.float32))
    quad = jnp.einsum("bshd,hgd->bshg", kf * kf, sig2.astype(jnp.float32))
    return jnp.mean(lin * scale + 0.5 * quad * scale * scale, axis=-1)
