"""Pallas TPU kernels for the paper's compute hot-spots.

  decode_attention   — flash-decode over padded variable-length compressed
                       KV caches (bf16 + fused-dequant int8); the hot loop
                       of Stretto's prefill-skip operators
  prefill_attention  — causal/windowed flash attention (offline cache
                       build + train/prefill TPU target)
  expected_attention — query-agnostic Expected-Attention compression scores

Each kernel ships with a pure-jnp oracle (ref.py) and a jit'd dispatch
wrapper (ops.py); tests sweep shapes/dtypes in interpret mode.
"""
