"""Backend-selectable public wrappers for the Pallas kernels.

Every wrapper takes `backend`, one of:

  auto       compiled Pallas on TPU, jnp oracle elsewhere (default)
  pallas     force the Pallas kernel (interpret mode off-TPU, so the
             lowering is still exercised on CPU)
  interpret  force Pallas interpret mode (CI's lowering check)
  ref        force the pure-jnp oracle (bit-stable CPU baseline)

`backend=None` defers to the STRETTO_KERNELS environment variable, read
at call time (not import time) so tests and deployments can flip it
without reimporting. The serving engine resolves the backend once per
jitted flush function and passes it explicitly.

int8 KV caches are handled here too: Pallas backends dequantize
in-register inside the kernel, while the ref backend dequantizes up
front in float32 — same math, materialized differently.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pl
from repro.kernels.decode_attention import \
    decode_query_attention as _query_pl
from repro.kernels.expected_attention import \
    expected_attention_scores as _ea_pl
from repro.kernels.prefill_attention import prefill_attention as _prefill_pl

GLOBAL = 1 << 30
VALID_BACKENDS = ("auto", "pallas", "interpret", "ref")
ENV_VAR = "STRETTO_KERNELS"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend=None) -> str:
    """Normalize a backend choice: explicit arg wins, else STRETTO_KERNELS
    (read now, not at import), else 'auto'."""
    if backend is None or backend == "":
        backend = os.environ.get(ENV_VAR, "auto") or "auto"
    if backend not in VALID_BACKENDS:
        raise ValueError(
            f"unknown kernels backend {backend!r}; expected one of "
            f"{VALID_BACKENDS}")
    return backend


def _dequant(x, scale):
    import jax.numpy as jnp
    return x.astype(jnp.float32) * scale[..., None]


def decode_attention(q, k_cache, v_cache, lengths, *, window=GLOBAL,
                     backend=None, block_s: int = 128,
                     k_scale=None, v_scale=None):
    """Single-query flash-decode; (B, KV, G, dk) -> (B, KV, G, dv)."""
    backend = resolve_backend(backend)
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        if k_scale is not None:
            k_cache = _dequant(k_cache, k_scale)
            v_cache = _dequant(v_cache, v_scale)
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                        window=window)
    interpret = (backend == "interpret") or not _on_tpu()
    return _decode_pl(q, k_cache, v_cache, lengths, window=window,
                      block_s=block_s, interpret=interpret,
                      k_scale=k_scale, v_scale=v_scale)


def decode_query_attention(q, k_cache, v_cache, lengths, *, window=GLOBAL,
                           backend=None, block_s: int = 128,
                           k_scale=None, v_scale=None):
    """Fused multi-token query decode; (B, Lq, KV, G, dk) ->
    (B, Lq, KV, G, dv). `lengths` includes the Lq query tokens."""
    backend = resolve_backend(backend)
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        if k_scale is not None:
            k_cache = _dequant(k_cache, k_scale)
            v_cache = _dequant(v_cache, v_scale)
        return ref.decode_query_attention_ref(q, k_cache, v_cache, lengths,
                                              window=window)
    interpret = (backend == "interpret") or not _on_tpu()
    return _query_pl(q, k_cache, v_cache, lengths, window=window,
                     block_s=block_s, interpret=interpret,
                     k_scale=k_scale, v_scale=v_scale)


def prefill_attention(q, k, v, *, window=GLOBAL, causal: bool = True,
                      backend=None):
    backend = resolve_backend(backend)
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.prefill_attention_ref(q, k, v, window=window,
                                         causal=causal)
    interpret = (backend == "interpret") or not _on_tpu()
    return _prefill_pl(q, k, v, window=window, causal=causal,
                       interpret=interpret)


def expected_attention_scores(k_cache, mu, sig2, *, backend=None):
    backend = resolve_backend(backend)
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.expected_attention_scores_ref(k_cache, mu, sig2)
    interpret = (backend == "interpret") or not _on_tpu()
    return _ea_pl(k_cache, mu, sig2, interpret=interpret)
