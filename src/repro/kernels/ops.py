"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode or fall back to
the jnp oracle; on TPU the compiled Pallas path is used. `backend` can be
forced for tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pl
from repro.kernels.expected_attention import \
    expected_attention_scores as _ea_pl
from repro.kernels.prefill_attention import prefill_attention as _prefill_pl

GLOBAL = 1 << 30


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, lengths, *, window: int = GLOBAL,
                     backend: str = "auto"):
    """backend: auto | pallas | interpret | ref"""
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                        window=window)
    interpret = (backend == "interpret") or not _on_tpu()
    return _decode_pl(q, k_cache, v_cache, lengths, window=window,
                      interpret=interpret)


def prefill_attention(q, k, v, *, window: int = GLOBAL, causal: bool = True,
                      backend: str = "auto"):
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.prefill_attention_ref(q, k, v, window=window,
                                         causal=causal)
    interpret = (backend == "interpret") or not _on_tpu()
    return _prefill_pl(q, k, v, window=window, causal=causal,
                       interpret=interpret)


def expected_attention_scores(k_cache, mu, sig2, *, backend: str = "auto"):
    if backend == "ref" or (backend == "auto" and not _on_tpu()):
        return ref.expected_attention_scores_ref(k_cache, mu, sig2)
    interpret = (backend == "interpret") or not _on_tpu()
    return _ea_pl(k_cache, mu, sig2, interpret=interpret)
