"""Pallas TPU kernel for Expected-Attention compression scores.

Query-agnostic KV-cache compression (Devoto et al. 2025, used by Stretto §5):
score each cached position by its expected attention weight under the
model's *future-query distribution* q ~ N(mu_h, diag(sig2_h)):

    E_q[exp(q . k / sqrt(d))] = exp(mu_h . k / sqrt(d)
                                    + 0.5 * (k*k) . sig2_h / d)

aggregated (mean) over the query heads h attached to the KV head. Offline,
the top (1 - ratio) fraction of positions per item is kept.

The kernel is two MXU matmuls per tile: K (bs, dk) x mu^T (dk, H) and
K^2 (bs, dk) x sig2^T (dk, H), a log-domain add, and a mean over H.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ea_kernel(k_ref, mu_ref, sig2_ref, o_ref, *, scale: float):
    k = k_ref[0, :, 0].astype(jnp.float32)                 # (bs, dk)
    mu = mu_ref[0].astype(jnp.float32)                     # (G, dk)
    sig2 = sig2_ref[0].astype(jnp.float32)                 # (G, dk)
    lin = jax.lax.dot_general(k, mu, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    quad = jax.lax.dot_general(k * k, sig2, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    log_score = lin * scale + 0.5 * quad * (scale * scale)  # (bs, G)
    o_ref[0, :, 0] = jnp.mean(log_score, axis=1)


def expected_attention_scores(k_cache: jax.Array, mu: jax.Array,
                              sig2: jax.Array, *, block_s: int = 256,
                              interpret: bool = False) -> jax.Array:
    """k_cache: (B, S, KV, dk); mu, sig2: (KV, G, dk) query-head stats.

    Returns log-scores (B, S, KV) — higher means more worth keeping.
    """
    B, S, KV, dk = k_cache.shape
    G = mu.shape[1]
    block_s = min(block_s, S)
    if S % block_s:
        raise ValueError(f"S={S} not a multiple of block_s={block_s}")
    scale = dk ** -0.5

    kernel = functools.partial(_ea_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, S // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, 1, dk), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, G, dk), lambda b, h, s: (h, 0, 0)),
            pl.BlockSpec((1, G, dk), lambda b, h, s: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, 1), lambda b, h, s: (b, s, h)),
        out_shape=jax.ShapeDtypeStruct((B, S, KV), jnp.float32),
        interpret=interpret,
    )(k_cache, mu, sig2)
