"""Pallas TPU causal/windowed flash attention (prefill & training target).

Used offline to build KV caches (paper §5: the one-time prefill over the
corpus) and as the TPU replacement for the jnp blocked-attention oracle in
train/prefill steps.

Grid (B, KV, nq, nk): nk iterates innermost/sequentially; online-softmax
state lives in VMEM scratch per q-block. Fully-masked (kj, qi) pairs —
above the causal diagonal or outside the sliding window — are skipped with
@pl.when, so compute for causal attention is ~half the rectangle and for
windowed attention proportional to the band (the paper's gemma3-style local
layers). Shapes: block_q x block_k multiples of 128 for the MXU; G query
heads per KV head ride the sublane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
GLOBAL = 1 << 30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                    block_q: int, block_k: int, n_k: int, window: int,
                    scale: float, causal: bool):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = kj * block_k
    # live iff some (q, k) pair in the tile satisfies the mask
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    live = jnp.logical_and(live, q_start - (k_start + block_k - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32) * scale    # (bq, G, dk)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (bk, dk)
        v = v_ref[0, :, 0].astype(jnp.float32)            # (bk, dv)
        bq, G, dk = q.shape
        q2 = q.reshape(bq * G, dk)
        s = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s.reshape(bq, G, -1)                          # (bq, G, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (bq, 1, 1), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, s.shape[-1]), 2)
        mask = (q_pos - k_pos) < window
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, G, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.reshape(bq * G, -1), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, G, -1)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0] = out.astype(o_ref.dtype)


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      window: int = GLOBAL, causal: bool = True,
                      block_q: int = 256, block_k: int = 256,
                      interpret: bool = False) -> jax.Array:
    """q: (B, S, KV, G, dk); k: (B, S, KV, dk); v: (B, S, KV, dv).
    Returns (B, S, KV, G, dv)."""
    B, S, KV, G, dk = q.shape
    dv = v.shape[-1]
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    if S % block_q or S % block_k:
        raise ValueError(f"S={S} not a multiple of blocks")
    n_q, n_k = S // block_q, S // block_k
    scale = dk ** -0.5

    kernel = functools.partial(
        _prefill_kernel, block_q=block_q, block_k=block_k, n_k=n_k,
        window=window, scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, G, dk),
                         lambda b, h, i, j: (b, i, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, dk),
                         lambda b, h, i, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, dv),
                         lambda b, h, i, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, G, dv),
                               lambda b, h, i, j: (b, i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, KV, G, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, G, 1), jnp.float32),
            pltpu.VMEM((block_q, G, 1), jnp.float32),
            pltpu.VMEM((block_q, G, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
