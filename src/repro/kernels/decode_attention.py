"""Pallas TPU flash-decode kernels over (compressed) KV caches.

The hot loop of Stretto's KV-cache-enabled operators: query tokens per
item attend to a precomputed, possibly compressed, right-padded cache.

Two entry points share the online-softmax machinery:

  decode_attention        one query token per item (classic flash-decode)
  decode_query_attention  Lq query tokens per item in ONE dispatch — the
                          fused operator-query path: the serving engine
                          feeds the whole fixed query token list at once
                          instead of scanning tokens one at a time

  q        (B, KV, G, dk) / (B, Lq, KV, G, dk)   grouped GQA layout
  k_cache  (B, S, KV, dk)
  v_cache  (B, S, KV, dv)    dv may differ from dk (absorbed MLA: dv = r)
  lengths  (B,) int32        valid prefix per item (compressed lengths)
  window   int or traced int32 scalar; GLOBAL = full attention

`window` is carried as a (1,) int32 *input* (not a static closure
constant): the model's per-layer window is data in the layer scan
(gemma3's local:global pattern), so the kernel must accept a traced
value without retracing per layer.

Grid (B, KV, S/block_s): the KV-length axis iterates innermost and
sequentially on TPU, so the online-softmax state (m, l, acc) lives in VMEM
scratch across iterations — the TPU-idiomatic analogue of FlashDecoding's
split-K scheme. K/V tiles stream HBM->VMEM via BlockSpec; the (G, dk) x
(dk, block_s) score matmul and the (G, block_s) x (block_s, dv) accumulate
run on the MXU with dk, dv in {64, 128, 256+} and block_s a multiple of 128.

Per-item `lengths` masking makes padded batches exact — this is what lets
the serving engine batch caches of different compressed lengths. int8
variants take per-(token, head) scales (B, S, KV) and dequantize
in-register after the VMEM load, so HBM streams 1 byte/element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
GLOBAL = 1 << 30


def _window_arg(window) -> jax.Array:
    """Normalize the window kwarg (python int or traced scalar) to the
    (1,) int32 kernel input."""
    return jnp.asarray(window, jnp.int32).reshape(1)


# ---------------------------------------------------------------------------
# single-query flash-decode
# ---------------------------------------------------------------------------

def _decode_kernel(len_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, block_s: int, n_s: int,
                   scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, dk)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, dk)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (bs, dv)
    _decode_core(len_ref, win_ref, q, k, v, o_ref, m_ref, l_ref, acc_ref,
                 block_s=block_s, n_s=n_s, s_idx=s_idx)


def _decode_kernel_int8(len_ref, win_ref, q_ref, k_ref, v_ref, ks_ref,
                        vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        block_s: int, n_s: int, scale: float):
    """int8 KV variant: dequantization happens in-register after the VMEM
    load, so HBM traffic is 1 byte/element + per-token scales (the
    beyond-paper optimization measured in EXPERIMENTS §Perf)."""
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    ks = ks_ref[0, :, 0].astype(jnp.float32)             # (bs,)
    vs = vs_ref[0, :, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32) * ks[:, None]
    v = v_ref[0, :, 0].astype(jnp.float32) * vs[:, None]
    _decode_core(len_ref, win_ref, q, k, v, o_ref, m_ref, l_ref, acc_ref,
                 block_s=block_s, n_s=n_s, s_idx=s_idx)


def _decode_core(len_ref, win_ref, q, k, v, o_ref, m_ref, l_ref, acc_ref, *,
                 block_s: int, n_s: int, s_idx):

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    length = len_ref[0]
    window = win_ref[0]
    pos = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1)
    mask = (pos < length) & ((length - 1 - pos) < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (G, bs)
    alpha = jnp.exp(m_prev - m_new)                       # (G, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (G, dv)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, window=GLOBAL,
                     block_s: int = 128, interpret: bool = False,
                     k_scale: jax.Array = None, v_scale: jax.Array = None
                     ) -> jax.Array:
    """Flash-decode. Returns (B, KV, G, dv).

    With k_scale/v_scale (B, S, KV) given, k_cache/v_cache are int8 and are
    dequantized in-register (HBM streams 1 B/elem)."""
    B, KV, G, dk = q.shape
    _, S, _, dv = v_cache.shape
    block_s = min(block_s, S)
    if S % block_s:
        raise ValueError(f"S={S} must be a multiple of block_s={block_s}")
    n_s = S // block_s
    scale = dk ** -0.5
    quant = k_scale is not None

    in_specs = [
        pl.BlockSpec((1,), lambda b, h, s: (b,)),
        pl.BlockSpec((1,), lambda b, h, s: (0,)),
        pl.BlockSpec((1, 1, G, dk), lambda b, h, s: (b, h, 0, 0)),
        pl.BlockSpec((1, block_s, 1, dk), lambda b, h, s: (b, s, h, 0)),
        pl.BlockSpec((1, block_s, 1, dv), lambda b, h, s: (b, s, h, 0)),
    ]
    args = [lengths, _window_arg(window), q, k_cache, v_cache]
    if quant:
        kern = functools.partial(_decode_kernel_int8, block_s=block_s,
                                 n_s=n_s, scale=scale)
        in_specs += [
            pl.BlockSpec((1, block_s, 1), lambda b, h, s: (b, s, h)),
            pl.BlockSpec((1, block_s, 1), lambda b, h, s: (b, s, h)),
        ]
        args += [k_scale, v_scale]
    else:
        kern = functools.partial(_decode_kernel, block_s=block_s, n_s=n_s,
                                 scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, KV, n_s),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, dv), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# fused multi-token query decode
# ---------------------------------------------------------------------------

def _query_kernel(len_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, block_s: int, n_s: int,
                  n_q: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32) * scale       # (Lq, G, dk)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, dk)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (bs, dv)
    _query_core(len_ref, win_ref, q, k, v, o_ref, m_ref, l_ref, acc_ref,
                block_s=block_s, n_s=n_s, n_q=n_q, s_idx=s_idx)


def _query_kernel_int8(len_ref, win_ref, q_ref, k_ref, v_ref, ks_ref,
                       vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                       block_s: int, n_s: int, n_q: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32) * scale
    ks = ks_ref[0, :, 0].astype(jnp.float32)
    vs = vs_ref[0, :, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32) * ks[:, None]
    v = v_ref[0, :, 0].astype(jnp.float32) * vs[:, None]
    _query_core(len_ref, win_ref, q, k, v, o_ref, m_ref, l_ref, acc_ref,
                block_s=block_s, n_s=n_s, n_q=n_q, s_idx=s_idx)


def _query_core(len_ref, win_ref, q, k, v, o_ref, m_ref, l_ref, acc_ref, *,
                block_s: int, n_s: int, n_q: int, s_idx):
    lq, G, dk = q.shape
    q2 = q.reshape(lq * G, dk)
    s = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(lq, G, block_s)                         # (Lq, G, bs)
    length = len_ref[0]
    window = win_ref[0]
    # query i sits at absolute position length - n_q + i; causal masking
    # against the cache positions keeps the fused pass equivalent to the
    # sequential per-token scan (token i never sees tokens > i)
    k_pos = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, block_s), 2)
    q_pos = length - n_q + jax.lax.broadcasted_iota(
        jnp.int32, (lq, 1, 1), 0)
    mask = (k_pos <= q_pos) & ((q_pos - k_pos) < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (Lq, G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.reshape(lq * G, block_s), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(lq, G, -1)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0] = out.astype(o_ref.dtype)


def decode_query_attention(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, lengths: jax.Array, *,
                           window=GLOBAL, block_s: int = 128,
                           interpret: bool = False,
                           k_scale: jax.Array = None,
                           v_scale: jax.Array = None) -> jax.Array:
    """Fused multi-token query flash-decode. Returns (B, Lq, KV, G, dv).

    q: (B, Lq, KV, G, dk). `lengths` counts ALL valid tokens *including*
    the Lq query tokens (the cache already holds their k/v): query i's
    absolute position is lengths - Lq + i, and masking is causal per
    query token — one kernel dispatch replaces Lq sequential decode
    dispatches. With k_scale/v_scale (B, S, KV), the cache is int8 and
    dequantized in-register."""
    B, Lq, KV, G, dk = q.shape
    _, S, _, dv = v_cache.shape
    block_s = min(block_s, S)
    if S % block_s:
        raise ValueError(f"S={S} must be a multiple of block_s={block_s}")
    n_s = S // block_s
    scale = dk ** -0.5
    quant = k_scale is not None

    in_specs = [
        pl.BlockSpec((1,), lambda b, h, s: (b,)),
        pl.BlockSpec((1,), lambda b, h, s: (0,)),
        pl.BlockSpec((1, Lq, 1, G, dk), lambda b, h, s: (b, 0, h, 0, 0)),
        pl.BlockSpec((1, block_s, 1, dk), lambda b, h, s: (b, s, h, 0)),
        pl.BlockSpec((1, block_s, 1, dv), lambda b, h, s: (b, s, h, 0)),
    ]
    args = [lengths, _window_arg(window), q, k_cache, v_cache]
    if quant:
        kern = functools.partial(_query_kernel_int8, block_s=block_s,
                                 n_s=n_s, n_q=Lq, scale=scale)
        in_specs += [
            pl.BlockSpec((1, block_s, 1), lambda b, h, s: (b, s, h)),
            pl.BlockSpec((1, block_s, 1), lambda b, h, s: (b, s, h)),
        ]
        args += [k_scale, v_scale]
    else:
        kern = functools.partial(_query_kernel, block_s=block_s, n_s=n_s,
                                 n_q=Lq, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, KV, n_s),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Lq, 1, G, dv),
                               lambda b, h, s: (b, 0, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Lq, KV, G, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Lq, G, 1), jnp.float32),
            pltpu.VMEM((Lq, G, 1), jnp.float32),
            pltpu.VMEM((Lq, G, dv), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
