"""Logical-axis sharding rules.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", ...). A ``Rules`` mapping resolves logical names to mesh axes at jit
time. When no rules are active (single-device smoke tests), all constraints
are no-ops — the same model code runs everywhere.

Mesh layout (production):
    single-pod: (data=16, model=16)
    multi-pod:  (pod=2, data=16, model=16)

Parallelism mapping:
    DP   : batch            -> ("pod", "data")
    TP   : heads / ff / vocab -> "model"
    EP   : expert           -> "model"
    FSDP : embed (param d_model rows of big matrices) -> "data"  (optional)
    SP   : cache_seq        -> "data" for long-context decode (batch=1)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# logical axis -> mesh axes
DEFAULT_RULES: Dict[str, Axes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,          # d_model dim of activations (replicated)
    "vocab": "model",
    "heads": "model",       # fused head*d_head projection columns
    "kv_heads": "model",    # KV-head dim of decode caches
    "ff": "model",
    "expert": "model",
    "ffe": None,            # per-expert FFN width; "model" under 2D EP
    "kv_lora": None,
    "cache_seq": None,      # set to "data" for long_500k SP decode
    "cache_batch": ("pod", "data"),
    "layers": None,
    "fsdp": None,           # set to "data" to FSDP-shard big param rows
    "opt_fsdp": "data",     # ZeRO-1: Adam moments sharded over data
}


class _State(threading.local):
    def __init__(self):
        self.rules: Optional[Dict[str, Axes]] = None
        self.mesh_axes: Tuple[str, ...] = ()


_STATE = _State()


@contextlib.contextmanager
def use_rules(rules: Dict[str, Axes], mesh: "jax.sharding.Mesh"):
    prev = (_STATE.rules, _STATE.mesh_axes)
    _STATE.rules = rules
    _STATE.mesh_axes = tuple(mesh.axis_names)
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh_axes = prev


def make_rules(**overrides) -> Dict[str, Axes]:
    r = dict(DEFAULT_RULES)
    r.update(overrides)
    return r


def resolve(axes: Tuple[Optional[str], ...]) -> P:
    """Logical axes tuple -> PartitionSpec under the active rules."""
    rules, mesh_axes = _STATE.rules, _STATE.mesh_axes
    assert rules is not None
    out = []
    for a in axes:
        m = rules.get(a) if a is not None else None
        if isinstance(m, tuple):
            m = tuple(x for x in m if x in mesh_axes) or None
            if m is not None and len(m) == 1:
                m = m[0]
        elif isinstance(m, str) and m not in mesh_axes:
            m = None
        out.append(m)
    while out and out[-1] is None:   # trailing Nones are implicit
        out.pop()
    return P(*out)


def sc(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """with_sharding_constraint on logical axes; no-op without active rules."""
    if _STATE.rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, resolve(axes))


def replicated_on(dev: "jax.Device") -> "jax.sharding.NamedSharding":
    """A NamedSharding that replicates onto exactly one device.

    Used by the runtime MeshDispatcher to pin one shard's engine state on
    its device slice: a 1x1 sub-mesh of `dev` with the production axis
    names, with the placement resolved through the same logical-axis rule
    machinery the big meshes use ("embed" rows of params/KV profiles are
    replicated, so this comes out as P() — everything on `dev`)."""
    import numpy as np
    sub = jax.sharding.Mesh(np.asarray([dev]).reshape(1, 1),
                            ("data", "model"))
    with use_rules(make_rules(), sub):
        return jax.sharding.NamedSharding(sub, resolve(("embed", "embed")))


def pspec_tree(axes_tree):
    """Map a pytree whose leaves are logical-axes tuples to PartitionSpecs.
    Requires active rules (call inside ``use_rules``)."""
    return jax.tree.map(
        lambda axes: resolve(axes), axes_tree,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(isinstance(x, (str, type(None))) for x in v))
