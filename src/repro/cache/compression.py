"""Query-agnostic KV-cache compression (Expected Attention; paper §5).

Offline pipeline:
  1. calibrate_query_stats — run the model over calibration items, capture
     per-layer hidden states, re-project them to queries, and fit per-head
     Gaussians N(mu, diag(sig2)) of the *future query* distribution.
  2. score positions with kernels.ops.expected_attention_scores.
  3. keep the top (1 - ratio) positions per item per layer (query-agnostic:
     the same compressed cache serves every semantic operator — the paper's
     reusability requirement).

Applicability: gqa/hymba compress k/v; mla compresses *latent rows*
([c_kv ; k_rope] scored against absorbed-query stats); rwkv6 has no
positional cache — inapplicable (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as KOPS
from repro.models import forward
from repro.models import layers as L


class QueryStats(NamedTuple):
    mu: jax.Array     # (L, KV, G, dk)
    sig2: jax.Array   # (L, KV, G, dk)


def calibrate_query_stats(params, cfg: ModelConfig, tokens=None,
                          embeds=None, tail_frac: float = 0.5) -> QueryStats:
    """Fit per-layer, per-head query Gaussians from calibration data.

    Future operator queries arrive *after* the document, so we fit on the
    trailing `tail_frac` positions' projected queries.
    """
    _, caches = forward(params, cfg, tokens=tokens, embeds=embeds,
                        collect_cache=True, collect_hidden=True)
    h = caches["h"]                                # (L, B, S, d)
    Ln, B, S, d = h.shape
    t0 = int(S * (1.0 - tail_frac))
    h = h[:, :, t0:, :]

    if cfg.attn_kind in ("gqa", "hymba"):
        wq = (params["layers"]["attn"]["attn"]["wq"]
              if cfg.attn_kind == "hymba"
              else params["layers"]["attn"]["wq"])     # (L, d, H*dh)
        q = jnp.einsum("lbsd,lde->lbse", h, wq)
        KV, G, dk = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.d_head
        q = q.reshape(Ln, -1, KV, G, dk)
        mu = q.mean(axis=1)
        sig2 = q.var(axis=1)
        return QueryStats(mu, sig2)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        ap = params["layers"]["attn"]
        if m.q_lora_rank:
            q = jnp.einsum("lbsd,lde,lef->lbsf", h, ap["wq_a"], ap["wq_b"])
        else:
            q = jnp.einsum("lbsd,lde->lbse", h, ap["wq"])
        H = cfg.n_heads
        q = q.reshape(Ln, -1, H, m.qk_nope_dim + m.qk_rope_dim)
        q_nope = q[..., :m.qk_nope_dim]
        q_rope = q[..., m.qk_nope_dim:]
        # absorbed query: q_lat = q_nope @ W_uk  (r-dim, per head)
        w_kv_b = ap["w_kv_b"].reshape(Ln, m.kv_lora_rank, H,
                                      m.qk_nope_dim + m.v_head_dim)
        w_uk = w_kv_b[..., :m.qk_nope_dim]              # (L, r, H, nope)
        q_lat = jnp.einsum("lthn,lrhn->ltrh", q_nope, w_uk)
        q_lat = jnp.moveaxis(q_lat, -1, -2)             # (L, T, H, r)
        q_full = jnp.concatenate([q_lat, q_rope], axis=-1)
        mu = q_full.mean(axis=1)[:, None]               # (L, 1, H, r+rope)
        sig2 = q_full.var(axis=1)[:, None]
        return QueryStats(mu, sig2)
    raise ValueError(f"no positional cache to compress for {cfg.attn_kind}")


def _cache_keys(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.attn_kind in ("gqa", "hymba"):
        return ("k", "v")
    if cfg.attn_kind == "mla":
        return ("c_kv", "k_rope")
    return ()


def score_positions(cfg: ModelConfig, cache: Dict[str, Any],
                    stats: QueryStats, length: int) -> jax.Array:
    """Per-layer keep-scores for one item. cache leaves: (L, 1, S, ...).
    Returns (L, S) — -inf beyond `length`."""
    if cfg.attn_kind in ("gqa", "hymba"):
        k = cache["k"]                                  # (L, 1, S, KV, dk)
        Ln, _, S, KV, dk = k.shape
        scores = jax.vmap(
            lambda kl, mul, sl: KOPS.expected_attention_scores(kl, mul, sl)
        )(k, stats.mu, stats.sig2)                      # (L, 1, S, KV)
        scores = scores[:, 0].mean(-1)                  # (L, S)
    else:  # mla: score latent rows [c_kv ; k_rope]
        lat = jnp.concatenate([cache["c_kv"], cache["k_rope"]], axis=-1)
        Ln, _, S, r = lat.shape
        lat4 = lat.reshape(Ln, 1, S, 1, r)              # (L, 1, S, KV=1, r)
        scores = jax.vmap(
            lambda kl, mul, sl: KOPS.expected_attention_scores(kl, mul, sl)
        )(lat4, stats.mu, stats.sig2)
        scores = scores[:, 0, :, 0]                     # (L, S)
    pos = jnp.arange(scores.shape[-1])[None, :]
    return jnp.where(pos < length, scores, -jnp.inf)


def compress_item_cache(cfg: ModelConfig, cache: Dict[str, Any],
                        stats: QueryStats, ratio: float, length: int
                        ) -> Tuple[Dict[str, np.ndarray], int]:
    """Compress one item's cache (batch dim 1) to keep (1-ratio) tokens.

    Returns (numpy cache dict with seq length S', new_length). Kept
    positions stay in original order (per layer, positions may differ)."""
    if ratio <= 0.0 or not _cache_keys(cfg):
        out = {k: np.asarray(v[:, 0]) for k, v in cache.items()
               if k in _cache_keys(cfg)}
        out = {k: v[:, :length] for k, v in out.items()}
        _add_states(cfg, cache, out)
        return out, length
    keep = max(4, int(round((1.0 - ratio) * length)))
    scores = score_positions(cfg, cache, stats, length)   # (L, S)
    _, idx = jax.lax.top_k(scores, keep)                  # (L, keep)
    idx = jnp.sort(idx, axis=-1)
    out = {}
    for key in _cache_keys(cfg):
        arr = cache[key][:, 0]                            # (L, S, ...)
        out[key] = np.asarray(jnp.take_along_axis(
            arr, idx.reshape(idx.shape + (1,) * (arr.ndim - 2)), axis=1))
    _add_states(cfg, cache, out)
    return out, keep


def quantize_kv(arrays: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """int8 rung of the compression ladder (gqa/hymba caches only).

    Takes a compressed numpy cache dict with k/v of shape (L, S', KV, dh)
    and returns int8 k/v plus per-(layer, token, head) absmax scales
    (L, S', KV) float32 — the exact layout `init_cache(..., quant=True)`
    uses and `_decode_kernel_int8` consumes. Dequantization is
    x_int8 * scale, matching decode_step's on-the-fly quantization of
    fresh query tokens, so stored context and new tokens share one
    numeric scheme. Non-k/v entries (hymba conv/ssm states) pass through
    untouched.
    """
    out = dict(arrays)
    for key in ("k", "v"):
        if key not in arrays:
            continue
        x = np.asarray(arrays[key], np.float32)           # (L, S', KV, dh)
        scale = np.max(np.abs(x), axis=-1) / 127.0        # (L, S', KV)
        q = np.round(x / np.maximum(scale, 1e-9)[..., None]).astype(np.int8)
        out[key] = q
        out[f"{key}_scale"] = scale.astype(np.float32)
    return out


def _add_states(cfg: ModelConfig, cache, out):
    """Hymba carries O(1) SSM/conv states alongside the compressible
    attention cache; they are copied through untouched."""
    for key in ("conv", "ssm"):
        if key in cache:
            out[key] = np.asarray(cache[key][:, 0])


def prune_dominated(profiles):
    """Drop profiles strictly worse in quality with no cost/storage gain
    (paper §5 offline phase). profiles: list of dicts with keys
    'ratio', 'quality', 'cost'."""
    kept = []
    for p in profiles:
        dominated = any(
            (q["quality"] >= p["quality"] and q["cost"] <= p["cost"]
             and (q["quality"] > p["quality"] or q["cost"] < p["cost"]))
            for q in profiles if q is not p)
        if not dominated:
            kept.append(p)
    return kept
