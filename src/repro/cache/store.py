"""On-disk KV-cache repository (paper §5, Fig. 4).

One *profile* = (model_name, compression ratio, optional int8
quantization). The store holds one compressed cache per (profile, item)
as an .npz shard, written once in the offline phase and memory-mapped at
query time. `load_batch` re-pads a set of items to the max compressed
length in the batch — the paper's batching scheme — and returns a
decode-ready cache pytree.

Alongside the shards, each profile directory carries an append-only
`_meta.jsonl` recording per-item byte sizes at `save` time, so batch
sizing (`ServingEngine.max_batch_for`) reads one small line instead of
decompressing a full .npz shard per flush.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

META_FILE = "_meta.jsonl"


@dataclass(frozen=True)
class Profile:
    model_name: str
    ratio: float
    quant: bool = False

    @property
    def tag(self) -> str:
        base = f"{self.model_name}__r{int(round(self.ratio * 100)):02d}"
        return base + ("__q8" if self.quant else "")


class CacheStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._mem: Dict[Tuple[str, int], Dict[str, np.ndarray]] = {}
        # per-profile {item_id: nbytes}, mirrored in _meta.jsonl on disk
        self._meta: Dict[str, Dict[int, int]] = {}
        # monotonic telemetry: bytes of cached KV arrays handed to decode
        # batches. The global counter is the store-wide total; the
        # thread-local twin counts only bytes loaded by the calling thread,
        # which is what the runtime's StageStats reads deltas of — each
        # stage flush runs entirely on one dispatcher thread, so
        # thread-local deltas stay exact when flushes overlap (the global
        # counter's deltas would double-count concurrent loads)
        self.bytes_loaded = 0
        self._tl = threading.local()
        self._bytes_lock = threading.Lock()

    @property
    def bytes_loaded_local(self) -> int:
        """KV bytes materialized by the *calling thread* (monotonic)."""
        return getattr(self._tl, "bytes_loaded", 0)

    def _path(self, profile: Profile, item_id: int) -> str:
        d = os.path.join(self.root, profile.tag)
        return os.path.join(d, f"{item_id}.npz")

    def _meta_path(self, profile: Profile) -> str:
        return os.path.join(self.root, profile.tag, META_FILE)

    def save(self, profile: Profile, item_id: int,
             arrays: Dict[str, np.ndarray], length: int):
        path = self._path(profile, item_id)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        arrs = {k: np.asarray(v) for k, v in arrays.items()}
        np.savez(path, __length__=np.int32(length), **arrs)
        self._mem[(profile.tag, item_id)] = {
            "__length__": np.int32(length), **arrs}
        nbytes = sum(a.nbytes for a in arrs.values())
        with open(self._meta_path(profile), "a") as f:
            f.write(json.dumps({"id": item_id, "nbytes": nbytes,
                                "length": int(length)}) + "\n")
        self._meta.setdefault(profile.tag, {})[item_id] = nbytes

    def _load_meta(self, profile: Profile) -> Dict[int, int]:
        """Per-item nbytes for a profile; last write wins (append-only)."""
        if profile.tag not in self._meta:
            meta: Dict[int, int] = {}
            p = self._meta_path(profile)
            if os.path.exists(p):
                with open(p) as f:
                    for line in f:
                        line = line.strip()
                        if not line:
                            continue
                        rec = json.loads(line)
                        meta[int(rec["id"])] = int(rec["nbytes"])
            self._meta[profile.tag] = meta
        return self._meta[profile.tag]

    def item_nbytes(self, profile: Profile,
                    item_id: Optional[int] = None) -> Optional[int]:
        """Cache bytes for one stored item (any item if id is None),
        served from profile metadata — no shard decompression. Falls back
        to loading the shard for stores written before metadata existed."""
        meta = self._load_meta(profile)
        if item_id is None:
            if meta:
                return next(iter(meta.values()))
            item_id = self.any_item_id(profile)
            if item_id is None:
                return None
        if item_id in meta:
            return meta[item_id]
        if not self.has(profile, item_id):
            return None
        shard = self.load(profile, item_id)
        nbytes = sum(a.nbytes for k, a in shard.items()
                     if k != "__length__")
        meta[item_id] = nbytes
        return nbytes

    def load(self, profile: Profile, item_id: int) -> Dict[str, np.ndarray]:
        key = (profile.tag, item_id)
        if key not in self._mem:
            with np.load(self._path(profile, item_id)) as z:
                self._mem[key] = {k: z[k] for k in z.files}
        return self._mem[key]

    def has(self, profile: Profile, item_id: int) -> bool:
        return ((profile.tag, item_id) in self._mem
                or os.path.exists(self._path(profile, item_id)))

    def any_item_id(self, profile: Profile) -> Optional[int]:
        """Any stored item id for this profile (None if nothing stored);
        used to measure per-item cache bytes for batch sizing."""
        for tag, item_id in self._mem:
            if tag == profile.tag:
                return item_id
        d = os.path.join(self.root, profile.tag)
        if os.path.isdir(d):
            for f in os.listdir(d):
                if f.endswith(".npz"):
                    return int(f[:-len(".npz")])
        return None

    def storage_bytes(self, profile: Profile) -> int:
        d = os.path.join(self.root, profile.tag)
        if not os.path.isdir(d):
            return 0
        return sum(os.path.getsize(os.path.join(d, f))
                   for f in os.listdir(d) if f.endswith(".npz"))

    def load_batch(self, cfg: ModelConfig, profile: Profile,
                   item_ids: Sequence[int], pad_to_multiple: int = 32,
                   headroom: int = 0, n_real: Optional[int] = None
                   ) -> Tuple[Dict[str, Any], np.ndarray]:
        """Assemble a right-padded decode cache for a batch of items.

        Returns (cache pytree with leaves (L, B, S_max, ...) + 'lengths',
        lengths array). Padding to the max compressed length in the batch
        is the paper's execution-time batching scheme. `headroom` reserves
        slots for the operator query + generated tokens. For quantized
        profiles the shards carry int8 k/v plus (L, S', KV) float32
        scales; scales pad along S like the caches so the decode kernel's
        grid stays aligned.

        `n_real` bounds the bytes-loaded telemetry to the first n_real
        entries: callers that replicate an item to round the batch up to
        a shape bucket (see ServingEngine) pass the un-padded count, so
        the counter measures the cache bytes the *scored tuples* needed —
        an exact quantity independent of how flushes were grouped — not
        the padding replicas.
        """
        shards = [self.load(profile, i) for i in item_ids]
        n_count = len(shards) if n_real is None else min(n_real, len(shards))
        loaded = sum(a.nbytes for s in shards[:n_count]
                     for k, a in s.items() if k != "__length__")
        with self._bytes_lock:
            self.bytes_loaded += loaded
        self._tl.bytes_loaded = self.bytes_loaded_local + loaded
        lengths = np.array([int(s["__length__"]) for s in shards], np.int32)
        smax = int(lengths.max()) + headroom
        smax = ((smax + pad_to_multiple - 1) // pad_to_multiple
                * pad_to_multiple)
        cache: Dict[str, Any] = {}
        seq_keys = {"k", "v", "c_kv", "k_rope", "k_scale", "v_scale"}
        for key in shards[0]:
            if key == "__length__":
                continue
            per = []
            for s in shards:
                a = s[key]
                if key in seq_keys:   # (L, S', ...) -> pad S' to smax
                    pad = [(0, 0)] * a.ndim
                    pad[1] = (0, smax - a.shape[1])
                    a = np.pad(a, pad)
                per.append(a)
            stacked = np.stack(per, axis=1)       # (L, B, ...)
            cache[key] = jnp.asarray(stacked)
        cache["lengths"] = jnp.asarray(lengths)
        return cache, lengths
