"""EXPLAIN for semantic queries: structured plan report + table renderer.

`SemFrame.explain()` returns an ExplainReport — the logical plan, the
physical cascade in execution order (thresholds, expected coalesced batch,
batch-aware per-tuple cost), the planner's Bayesian quality bounds and
feasibility verdict, and the execution configuration the session would
run it with. `str(report)` renders the table; `.rows()` gives the stage
table as dicts for programmatic use.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.logical import Query, RelFilter, SemFilter, SemMap
from repro.core.physical import PhysicalPlan


@dataclass(frozen=True)
class ExplainStage:
    """One physical cascade stage, in execution order."""
    order: int                 # position in the execution schedule
    logical_idx: int           # which logical operator it implements
    stage: int                 # position within that operator's cascade
    op_name: str               # physical operator (model @ compression)
    kind: str                  # "filter" | "map"
    is_gold: bool
    thr_lo: float              # reject below (filters) / n.a. (maps)
    thr_hi: float              # accept above / commit above (maps)
    cost_per_tuple_s: float    # batch-aware effective per-tuple cost
    exp_batch: float           # expected coalesced flush size (0: n/a)

    def as_dict(self) -> Dict[str, Any]:
        return {"order": self.order, "logical_idx": self.logical_idx,
                "stage": self.stage, "op_name": self.op_name,
                "kind": self.kind, "is_gold": self.is_gold,
                "thr_lo": self.thr_lo, "thr_hi": self.thr_hi,
                "cost_per_tuple_s": self.cost_per_tuple_s,
                "exp_batch": self.exp_batch}


def _describe_node(node) -> str:
    if isinstance(node, SemFilter):
        return f"SemFilter {node.text!r} (task {node.task_id})"
    if isinstance(node, SemMap):
        return (f"SemMap {node.text!r} (task {node.task_id} "
                f"-> {node.out_column!r})")
    if isinstance(node, RelFilter):
        return f"RelFilter {node.column} {node.op} {node.value!r}"
    return repr(node)


@dataclass(frozen=True)
class ExplainReport:
    """Structured EXPLAIN output for one (query, corpus, session)."""
    n_items: int
    target_recall: float
    target_precision: float
    logical: Tuple[str, ...]            # declared plan, user order
    relational: Tuple[str, ...]         # pulled-up relational prefilters
    stages: Tuple[ExplainStage, ...]    # physical cascade, execution order
    est_cost_s: float                   # planner's full-corpus estimate
    recall_bound: float                 # Bayesian lower bounds the plan
    precision_bound: float              # certifies at the credibility level
    feasible: bool                      # targets attainable on the sample
    planning_time_s: float
    backend: str                        # runtime backend name
    dispatcher: str                     # session execution defaults
    partition_size: Optional[int]
    coalesce: Optional[int]

    @classmethod
    def from_plan(cls, session, query: Query, items: Sequence[Any],
                  plan: PhysicalPlan) -> "ExplainReport":
        from repro.runtime.dispatch import DEFAULT_COALESCE, effective_spec
        cfg = session.config
        stages = tuple(
            ExplainStage(
                order=i, logical_idx=st.logical_idx, stage=st.stage,
                op_name=st.op_name, kind="map" if st.is_map else "filter",
                is_gold=st.is_gold, thr_lo=st.thr_lo, thr_hi=st.thr_hi,
                cost_per_tuple_s=st.cost, exp_batch=st.exp_batch)
            for i, st in enumerate(plan.stages))
        return cls(
            n_items=len(items),
            target_recall=query.target_recall,
            target_precision=query.target_precision,
            logical=tuple(_describe_node(n) for n in query.nodes),
            relational=tuple(_describe_node(r) for r in plan.relational),
            stages=stages,
            est_cost_s=plan.est_cost,
            recall_bound=plan.recall_bound,
            precision_bound=plan.precision_bound,
            feasible=plan.feasible,
            planning_time_s=plan.planning_time_s,
            backend=getattr(session.backend, "name", "backend"),
            dispatcher=effective_spec(cfg.dispatcher),
            partition_size=cfg.partition_size,
            coalesce=cfg.coalesce if cfg.coalesce is not None
            else DEFAULT_COALESCE)

    def rows(self) -> List[Dict[str, Any]]:
        """The stage table as dicts (execution order)."""
        return [s.as_dict() for s in self.stages]

    # ---------------- rendering ----------------

    def render(self) -> str:
        head = (f"EXPLAIN — {len(self.logical)} operators over "
                f"{self.n_items} items, guarantees R>={self.target_recall} "
                f"P>={self.target_precision}")
        out = [head, "logical plan (declared order):"]
        out += [f"  {i}: {d}" for i, d in enumerate(self.logical)]
        if self.relational:
            out.append("relational prefilters (pulled up, run first):")
            out += [f"  {d}" for d in self.relational]
        verdict = "feasible" if self.feasible else "INFEASIBLE on sample"
        out.append(
            f"physical cascade ({verdict}, est_cost={self.est_cost_s:.2f}s,"
            f" bounds R>={self.recall_bound:.3f} "
            f"P>={self.precision_bound:.3f}, "
            f"planned in {self.planning_time_s:.2f}s):")
        cols = [("#", 2), ("op", 24), ("L/s", 5), ("kind", 6),
                ("thr_lo", 7), ("thr_hi", 7), ("cost/t", 9), ("batch", 6)]
        out.append("  " + " ".join(f"{name:>{w}}" for name, w in cols))
        for s in self.stages:
            gold = " [gold]" if s.is_gold else ""
            out.append("  " + " ".join([
                f"{s.order:>2}",
                f"{s.op_name + gold:>24}",
                f"{f'{s.logical_idx}/{s.stage}':>5}",
                f"{s.kind:>6}",
                "     --" if s.is_gold else f"{s.thr_lo:>+7.2f}",
                "     --" if s.is_gold else f"{s.thr_hi:>+7.2f}",
                f"{s.cost_per_tuple_s * 1e3:>7.2f}ms",
                f"{s.exp_batch:>6.0f}" if s.exp_batch else "    --",
            ]))
        psize = self.partition_size if self.partition_size is not None \
            else "whole-corpus"
        out.append(
            f"execution: backend={self.backend} "
            f"dispatcher={self.dispatcher} "
            f"partition_size={psize} "
            f"coalesce={self.coalesce}")
        return "\n".join(out)

    def __str__(self) -> str:
        return self.render()
