"""EXPLAIN / EXPLAIN ANALYZE: structured plan report + table renderer.

`SemFrame.explain()` returns an ExplainReport — the logical plan, the
physical cascade in execution order (thresholds, expected coalesced batch,
batch-aware per-tuple cost), the planner's Bayesian quality bounds and
feasibility verdict, and the execution configuration the session would
run it with. `str(report)` renders the table; `.rows()` gives the stage
table as dicts for programmatic use.

`QueryResult.explain_analyze()` re-renders the same report with the
*measured* execution telemetry (`with_measured`) in columns next to the
planned numbers: per-stage measured per-tuple cost, mean flush batch,
tuples scored and KV bytes, plus the run's `runtime_s` (summed operator
time) and `wall_s` (elapsed wall clock) — the planned-vs-measured
comparison that makes cost-model drift visible instead of latent.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.logical import (Query, RelFilter, SemAgg, SemFilter,
                                SemJoin, SemMap, SemTopK)
from repro.core.physical import TREE_ROLES, PhysicalPlan, TreePlan


@dataclass(frozen=True)
class ExplainStage:
    """One physical cascade stage, in execution order. The ``meas_*``
    fields are None for a plain EXPLAIN and filled by EXPLAIN ANALYZE
    (``ExplainReport.with_measured``); a stage the executed cascade never
    flushed keeps them None."""
    order: int                 # position in the execution schedule
    logical_idx: int           # which logical operator it implements
    stage: int                 # position within that operator's cascade
    op_name: str               # physical operator (model @ compression)
    kind: str                  # "filter" | "map"
    is_gold: bool
    thr_lo: float              # reject below (filters) / n.a. (maps)
    thr_hi: float              # accept above / commit above (maps)
    cost_per_tuple_s: float    # batch-aware effective per-tuple cost
    exp_batch: float           # expected coalesced flush size (0: n/a)
    engine: str = ""           # engine the planner placed this stage on
    #                            ("" for single-engine sessions)
    meas_cost_per_tuple_s: Optional[float] = None   # measured wall/tuple
    meas_batch: Optional[float] = None     # measured mean flush size
    meas_tuples: Optional[int] = None      # tuples actually scored
    meas_kv_bytes: Optional[int] = None    # exact KV bytes materialized
    meas_batches: Optional[int] = None     # flushes executed

    def as_dict(self) -> Dict[str, Any]:
        out = {"order": self.order, "logical_idx": self.logical_idx,
               "stage": self.stage, "op_name": self.op_name,
               "kind": self.kind, "is_gold": self.is_gold,
               "engine": self.engine,
               "thr_lo": self.thr_lo, "thr_hi": self.thr_hi,
               "cost_per_tuple_s": self.cost_per_tuple_s,
               "exp_batch": self.exp_batch}
        if self.meas_tuples is not None:
            out.update({"meas_cost_per_tuple_s": self.meas_cost_per_tuple_s,
                        "meas_batch": self.meas_batch,
                        "meas_tuples": self.meas_tuples,
                        "meas_kv_bytes": self.meas_kv_bytes,
                        "meas_batches": self.meas_batches})
        return out


def _describe_node(node) -> str:
    # subclass checks first: SemTopK is a SemFilter, SemAgg a SemMap
    if isinstance(node, SemTopK):
        return f"SemTopK k={node.k} {node.text!r} (task {node.task_id})"
    if isinstance(node, SemAgg):
        grp = f" group_by={node.group_by!r}" if node.group_by else ""
        return (f"SemAgg {node.how}{grp} {node.text!r} "
                f"(task {node.task_id} -> {node.out_column!r})")
    if isinstance(node, SemJoin):
        on = f", on={node.on!r}" if node.on else ""
        return f"SemJoin {node.text!r} (task {node.task_id}{on})"
    if isinstance(node, SemFilter):
        return f"SemFilter {node.text!r} (task {node.task_id})"
    if isinstance(node, SemMap):
        return (f"SemMap {node.text!r} (task {node.task_id} "
                f"-> {node.out_column!r})")
    if isinstance(node, RelFilter):
        return f"RelFilter {node.column} {node.op} {node.value!r}"
    return repr(node)


@dataclass(frozen=True)
class ExplainReport:
    """Structured EXPLAIN output for one (query, corpus, session)."""
    n_items: int
    target_recall: float
    target_precision: float
    logical: Tuple[str, ...]            # declared plan, user order
    relational: Tuple[str, ...]         # pulled-up relational prefilters
    stages: Tuple[ExplainStage, ...]    # physical cascade, execution order
    est_cost_s: float                   # planner's full-corpus estimate
    recall_bound: float                 # Bayesian lower bounds the plan
    precision_bound: float              # certifies at the credibility level
    feasible: bool                      # targets attainable on the sample
    planning_time_s: float
    backend: str                        # runtime backend name
    dispatcher: str                     # session execution defaults
    partition_size: Optional[int]
    coalesce: Optional[int]
    # RelFilters the checked pushdown could NOT move ahead of the LLM
    # stages (they reference a SemMap's output column, or sit behind a
    # SemTopK/SemAgg barrier) — executed as post-filters
    post_relational: Tuple[str, ...] = ()
    # measured execution summary — None until with_measured() (ANALYZE)
    measured_runtime_s: Optional[float] = None    # summed operator time
    measured_wall_s: Optional[float] = None       # elapsed wall clock
    measured_partitions: Optional[int] = None
    measured_dispatcher: Optional[str] = None     # what actually ran it
    measured_workers: Optional[int] = None
    # transfer overlap telemetry (serving engines only): H2D copy time
    # the engine hid behind decode compute, and KV cache bytes the jitted
    # decode donated back to XLA — None until ANALYZE
    measured_h2d_overlap_s: Optional[float] = None
    measured_donated_bytes: Optional[int] = None
    # per-engine measured totals (engine, wall_s, n_tuples, n_llm_calls,
    # kv_bytes) — exact partition of the run totals; empty until ANALYZE,
    # rendered only for pooled (multi-engine-tagged) executions
    measured_engines: Tuple[Tuple[str, float, int, int, int], ...] = ()
    # cross-query coalescing telemetry: flushes of this query that rode a
    # merged engine batch, and the summed width of those shared batches —
    # zero unless the run went through the QueryScheduler's FlushHub
    measured_shared_batches: Optional[int] = None
    measured_shared_width: Optional[int] = None
    # scheduler footer (key, value) pairs attached by with_scheduler()
    # when the result came through concurrent admission
    scheduler_info: Tuple[Tuple[str, Any], ...] = ()
    # remote footer (key, value) pairs attached by with_remote() when the
    # run touched remote engine members (wire calls, retries, fallbacks,
    # rtt percentiles, bytes on wire)
    remote_info: Tuple[Tuple[str, Any], ...] = ()

    @property
    def analyzed(self) -> bool:
        """True once measured execution telemetry has been attached."""
        return self.measured_runtime_s is not None

    @classmethod
    def from_plan(cls, session, query: Query, items: Sequence[Any],
                  plan: PhysicalPlan) -> "ExplainReport":
        from repro.runtime.dispatch import DEFAULT_COALESCE, effective_spec
        cfg = session.config
        stages = tuple(
            ExplainStage(
                order=i, logical_idx=st.logical_idx, stage=st.stage,
                op_name=st.op_name, kind="map" if st.is_map else "filter",
                is_gold=st.is_gold, thr_lo=st.thr_lo, thr_hi=st.thr_hi,
                cost_per_tuple_s=st.cost, exp_batch=st.exp_batch,
                engine=getattr(st, "engine", ""))
            for i, st in enumerate(plan.stages))
        return cls(
            n_items=len(items),
            target_recall=query.target_recall,
            target_precision=query.target_precision,
            logical=tuple(_describe_node(n) for n in query.nodes),
            relational=tuple(_describe_node(r) for r in plan.relational),
            stages=stages,
            est_cost_s=plan.est_cost,
            recall_bound=plan.recall_bound,
            precision_bound=plan.precision_bound,
            feasible=plan.feasible,
            planning_time_s=plan.planning_time_s,
            backend=getattr(session.backend, "name", "backend"),
            dispatcher=effective_spec(cfg.dispatcher),
            partition_size=cfg.partition_size,
            coalesce=cfg.coalesce if cfg.coalesce is not None
            else DEFAULT_COALESCE,
            post_relational=tuple(
                f"{_describe_node(r)} "
                + (f"[over map L{li}'s extracted value]" if li is not None
                   else "[post-barrier row filter]")
                for r, li in getattr(plan, "post_relational", ())))

    def with_measured(self, result) -> "ExplainReport":
        """EXPLAIN ANALYZE: a new report with the measured per-stage
        telemetry of `result` (a RuntimeResult) filled in next to the
        planned columns. Stages are matched by (logical_idx, stage,
        op_name) — the StageStats identity key — so a stage the cascade
        never flushed keeps its measured fields None and renders as
        ``--``."""
        by_key = {(sg.logical_idx, sg.stage, sg.op_name): sg
                  for sg in result.stage_stats}
        stages = []
        for s in self.stages:
            sg = by_key.get((s.logical_idx, s.stage, s.op_name))
            if sg is None or not sg.n_batches:
                stages.append(s)
                continue
            stages.append(replace(
                s,
                meas_cost_per_tuple_s=sg.wall_s / max(sg.n_tuples, 1),
                meas_batch=sg.mean_batch,
                meas_tuples=sg.n_tuples,
                meas_kv_bytes=sg.kv_bytes,
                meas_batches=sg.n_batches))
        # the execution line must describe the run that produced these
        # measurements, not the session defaults — per-call overrides
        # (dispatcher / partition_size / coalesce) are carried on the
        # RuntimeResult (coalesce is always recorded by the runtime, so
        # its presence marks a result with recorded execution config)
        exec_cfg = {}
        if result.coalesce is not None:
            exec_cfg = {"dispatcher": f"{result.dispatcher}",
                        "partition_size": result.partition_size,
                        "coalesce": result.coalesce}
        from repro.runtime.executor import stage_stats_by_engine
        per_engine = tuple(
            (eng, d["wall_s"], d["n_tuples"], d["n_llm_calls"],
             d["kv_bytes"])
            for eng, d in sorted(
                stage_stats_by_engine(result.stage_stats).items()))
        return replace(
            self, stages=tuple(stages),
            measured_runtime_s=result.runtime_s,
            measured_wall_s=result.wall_s,
            measured_partitions=result.n_partitions,
            measured_dispatcher=result.dispatcher,
            measured_workers=result.n_workers,
            measured_h2d_overlap_s=sum(
                getattr(sg, "h2d_overlap_s", 0.0)
                for sg in result.stage_stats),
            measured_donated_bytes=sum(
                getattr(sg, "donated_bytes", 0)
                for sg in result.stage_stats),
            measured_engines=per_engine,
            measured_shared_batches=sum(
                getattr(sg, "shared_batches", 0)
                for sg in result.stage_stats),
            measured_shared_width=sum(
                getattr(sg, "shared_width", 0)
                for sg in result.stage_stats),
            **exec_cfg)

    def with_scheduler(self, sched) -> "ExplainReport":
        """Attach per-query scheduler telemetry (a QueryTelemetry from
        repro.scheduler) so ANALYZE renders a "scheduler:" footer: tenant
        and tier, queue wait, slot occupancy, and how much of this query's
        work rode cross-query coalesced batches."""
        info = sched.as_dict() if hasattr(sched, "as_dict") else dict(sched)
        return replace(self, scheduler_info=tuple(info.items()))

    def with_remote(self, remote) -> "ExplainReport":
        """Attach per-run remote-engine telemetry (the RuntimeResult's
        `remote` dict from repro.remote.client.remote_run_info) so
        ANALYZE renders a "remote:" footer: wire calls, retries,
        fallbacks, rtt_ms p50/p95, and bytes on wire — per engine."""
        return replace(self, remote_info=tuple(dict(remote).items()))

    def rows(self) -> List[Dict[str, Any]]:
        """The stage table as dicts (execution order)."""
        return [s.as_dict() for s in self.stages]

    # ---------------- rendering ----------------

    def render(self) -> str:
        verb = "EXPLAIN ANALYZE" if self.analyzed else "EXPLAIN"
        head = (f"{verb} — {len(self.logical)} operators over "
                f"{self.n_items} items, guarantees R>={self.target_recall} "
                f"P>={self.target_precision}")
        out = [head, "logical plan (declared order):"]
        out += [f"  {i}: {d}" for i, d in enumerate(self.logical)]
        if self.relational:
            out.append("relational prefilters (pushed down, run first):")
            out += [f"  {d}" for d in self.relational]
        if self.post_relational:
            out.append("post-filters (pinned — pushdown illegal):")
            out += [f"  {d}" for d in self.post_relational]
        verdict = "feasible" if self.feasible else "INFEASIBLE on sample"
        out.append(
            f"physical cascade ({verdict}, est_cost={self.est_cost_s:.2f}s,"
            f" bounds R>={self.recall_bound:.3f} "
            f"P>={self.precision_bound:.3f}, "
            f"planned in {self.planning_time_s:.2f}s):")
        # the engine column appears as soon as any stage carries a pool
        # placement; single-engine sessions keep the pre-pool table shape
        engines = any(s.engine for s in self.stages)
        cols = [("#", 2), ("op", 24)]
        if engines:
            eng_w = max(6, max(len(s.engine) for s in self.stages))
            cols += [("engine", eng_w)]
        cols += [("L/s", 5), ("kind", 6),
                 ("thr_lo", 7), ("thr_hi", 7), ("cost/t", 9), ("batch", 6)]
        if self.analyzed:
            # measured columns, planned-vs-measured side by side
            cols += [("meas/t", 9), ("mbatch", 6), ("tuples", 7),
                     ("kvMB", 7)]
        out.append("  " + " ".join(f"{name:>{w}}" for name, w in cols))
        for s in self.stages:
            gold = " [gold]" if s.is_gold else ""
            # pooled operator names carry the engine prefix; the table
            # shows the placement in its own column instead of twice
            op = s.op_name
            if s.engine and op.startswith(s.engine + "/"):
                op = op[len(s.engine) + 1:]
            row = [
                f"{s.order:>2}",
                f"{op + gold:>24}",
            ]
            if engines:
                row.append(f"{s.engine or '--':>{eng_w}}")
            row += [
                f"{f'{s.logical_idx}/{s.stage}':>5}",
                f"{s.kind:>6}",
                "     --" if s.is_gold else f"{s.thr_lo:>+7.2f}",
                "     --" if s.is_gold else f"{s.thr_hi:>+7.2f}",
                f"{s.cost_per_tuple_s * 1e3:>7.2f}ms",
                f"{s.exp_batch:>6.0f}" if s.exp_batch else "    --",
            ]
            if self.analyzed:
                if s.meas_tuples is None:
                    row += ["       --", "    --", "     --", "     --"]
                else:
                    row += [
                        f"{s.meas_cost_per_tuple_s * 1e3:>7.2f}ms",
                        f"{s.meas_batch:>6.1f}",
                        f"{s.meas_tuples:>7d}",
                        f"{s.meas_kv_bytes / 1e6:>7.1f}",
                    ]
            out.append("  " + " ".join(row))
        psize = self.partition_size if self.partition_size is not None \
            else "whole-corpus"
        out.append(
            f"execution: backend={self.backend} "
            f"dispatcher={self.dispatcher} "
            f"partition_size={psize} "
            f"coalesce={self.coalesce}")
        if self.analyzed:
            out.append(
                f"measured: runtime_s={self.measured_runtime_s:.2f} "
                f"(operator-time sum) wall_s={self.measured_wall_s:.2f} "
                f"(elapsed) partitions={self.measured_partitions} "
                f"dispatcher={self.measured_dispatcher}"
                f":{self.measured_workers}")
            if self.measured_h2d_overlap_s or self.measured_donated_bytes:
                out.append(
                    f"transfers: h2d_overlap_s="
                    f"{self.measured_h2d_overlap_s:.3f} (H2D hidden "
                    f"behind decode) donated_MB="
                    f"{self.measured_donated_bytes / 1e6:.1f} "
                    f"(KV buffers returned to XLA)")
            if any(eng for eng, *_ in self.measured_engines):
                for eng, wall, tuples, llm, kv in self.measured_engines:
                    out.append(
                        f"  engine {eng or '--'}: wall_s={wall:.2f} "
                        f"tuples={tuples} llm_calls={llm} "
                        f"kvMB={kv / 1e6:.1f}")
            if self.remote_info:
                info = dict(self.remote_info)
                out.append(
                    f"remote: calls={info.get('calls', 0)} "
                    f"retries={info.get('retries', 0)} "
                    f"fallbacks={info.get('fallbacks', 0)} "
                    f"rtt_ms p50={info.get('rtt_ms_p50', 0.0)} "
                    f"p95={info.get('rtt_ms_p95', 0.0)} "
                    f"wire_kb={info.get('wire_kb', 0.0)}")
                for eng, d in sorted((info.get("engines") or {}).items()):
                    out.append(
                        f"  remote {eng}: calls={d.get('calls', 0)} "
                        f"retries={d.get('retries', 0)} "
                        f"fallbacks={d.get('fallbacks', 0)} "
                        f"wire_kb={d.get('wire_kb', 0.0)}")
            if self.scheduler_info:
                info = dict(self.scheduler_info)
                tenant = info.pop("tenant", "default")
                tier = info.pop("tier", "standard")
                out.append(f"scheduler: tenant={tenant} ({tier})")
                keys = ("queue_wait_s", "run_wall_s", "slots",
                        "shared_batches", "shared_width")
                parts = []
                for k in keys:
                    v = info.pop(k, None)
                    if v is None:
                        continue
                    parts.append(f"{k}={v:.3f}" if isinstance(v, float)
                                 else f"{k}={v}")
                parts += [f"{k}={v}" for k, v in info.items()
                          if k not in ("query_id", "weight")]
                if parts:
                    out.append("  " + " ".join(parts))
            elif self.measured_shared_batches:
                out.append(
                    f"scheduler: shared_batches="
                    f"{self.measured_shared_batches} shared_width="
                    f"{self.measured_shared_width} (flushes merged with "
                    f"concurrent queries)")
        return "\n".join(out)

    def __str__(self) -> str:
        return self.render()


@dataclass(frozen=True)
class TreeExplainReport:
    """Tree-shaped EXPLAIN for a planned semantic join.

    One section per role pipeline (left side, right side, pair cascade)
    rendered under a tree spine, around the *joint* header: the
    query-level bounds the grouped relaxation certifies and the budget
    split — each role's achieved sample-level (recall, precision) under
    the jointly chosen thresholds, i.e. where the query's error budget
    actually went. `JoinResult.explain_analyze()` re-renders it with
    each role's measured execution telemetry (`with_measured`)."""
    n_left: int
    n_right: int
    est_pairs: int
    join_desc: str
    target_recall: float
    target_precision: float
    recall_bound: float                 # joint Bayesian lower bounds
    precision_bound: float
    feasible: bool
    est_cost_s: float
    planning_time_s: float
    # (role, sample_recall, sample_precision) — the budget allocation
    split: Tuple[Tuple[str, float, float], ...]
    sections: Tuple[Tuple[str, ExplainReport], ...]
    measured_runtime_s: Optional[float] = None
    measured_wall_s: Optional[float] = None
    measured_pairs: Optional[int] = None      # pairs actually scored
    measured_accepted: Optional[int] = None   # pairs in the result

    @property
    def analyzed(self) -> bool:
        return self.measured_runtime_s is not None

    @classmethod
    def from_plan(cls, session, plan: TreePlan, n_left: int,
                  n_right: int) -> "TreeExplainReport":
        n_role = {"left": n_left, "right": n_right, "pair": plan.est_pairs}
        sections = tuple(
            (role, ExplainReport.from_plan(session, plan.queries[role],
                                           range(n_role[role]),
                                           plan.roles[role]))
            for role in TREE_ROLES)
        q = plan.queries["pair"]
        return cls(
            n_left=n_left, n_right=n_right, est_pairs=plan.est_pairs,
            join_desc=_describe_node(plan.join),
            target_recall=q.target_recall,
            target_precision=q.target_precision,
            recall_bound=plan.recall_bound,
            precision_bound=plan.precision_bound,
            feasible=plan.feasible, est_cost_s=plan.est_cost,
            planning_time_s=plan.planning_time_s,
            split=tuple((r, *plan.split[r]) for r in TREE_ROLES
                        if r in plan.split),
            sections=sections)

    def with_measured(self, result) -> "TreeExplainReport":
        """EXPLAIN ANALYZE for a tree: each role section gets its own
        run's measured telemetry (`result` is a runtime TreeResult)."""
        sections = tuple((role, rep.with_measured(result.roles[role]))
                         for role, rep in self.sections)
        return replace(self, sections=sections,
                       measured_runtime_s=result.runtime_s,
                       measured_wall_s=result.wall_s,
                       measured_pairs=len(result.pair_items),
                       measured_accepted=len(result.pair_ids))

    def rows(self) -> List[Dict[str, Any]]:
        """Every role's stage table as dicts, with a `role` column."""
        return [dict(r, role=role)
                for role, rep in self.sections for r in rep.rows()]

    def render(self) -> str:
        verb = "EXPLAIN ANALYZE" if self.analyzed else "EXPLAIN"
        verdict = "feasible" if self.feasible else "INFEASIBLE on sample"
        out = [
            f"{verb} — semantic join tree over {self.n_left} x "
            f"{self.n_right} items, guarantees R>={self.target_recall} "
            f"P>={self.target_precision}",
            self.join_desc,
            f"joint bounds R>={self.recall_bound:.3f} "
            f"P>={self.precision_bound:.3f} ({verdict}), "
            f"est_cost={self.est_cost_s:.2f}s, "
            f"est_pairs~{self.est_pairs}, "
            f"planned in {self.planning_time_s:.2f}s",
            "budget split across pipelines (sample R/P at the jointly "
            "chosen thresholds):",
        ]
        out += [f"  {role:>5}: R={rec:.3f} P={prec:.3f}"
                for role, rec, prec in self.split]
        for i, (role, rep) in enumerate(self.sections):
            last = i == len(self.sections) - 1
            head, bar = ("└─ ", "   ") if last else ("├─ ", "│  ")
            if role == "pair":
                out.append(f"{head}pair (~{self.est_pairs} blocked "
                           f"survivor pairs)")
            else:
                n = self.n_left if role == "left" else self.n_right
                out.append(f"{head}{role} ({n} items)")
            out += [bar + line for line in rep.render().splitlines()]
        if self.analyzed:
            out.append(
                f"measured: runtime_s={self.measured_runtime_s:.2f} "
                f"(operator-time sum) wall_s={self.measured_wall_s:.2f} "
                f"(elapsed, 3 runs + pairing) "
                f"pairs_scored={self.measured_pairs} "
                f"accepted={self.measured_accepted}")
        return "\n".join(out)

    def __str__(self) -> str:
        return self.render()
